"""Data pipeline: determinism, shard independence, ListOps correctness."""
import numpy as np

from repro.data import ZipfLM, HierarchicalLM, ListOps, Prefetcher
from repro.data.listops import PAD, DIGIT0, OPS, CLOSE, NUM_CLASSES


def test_zipf_deterministic_per_step_and_host():
    a = ZipfLM(vocab_size=100, seq_len=32, batch_per_host=4, seed=1)
    b = ZipfLM(vocab_size=100, seq_len=32, batch_per_host=4, seed=1)
    np.testing.assert_array_equal(a.batch(3)["tokens"], b.batch(3)["tokens"])
    assert not np.array_equal(a.batch(3)["tokens"], a.batch(4)["tokens"])
    h1 = ZipfLM(vocab_size=100, seq_len=32, batch_per_host=4, seed=1,
                host_id=1)
    assert not np.array_equal(a.batch(3)["tokens"], h1.batch(3)["tokens"])
    toks = a.batch(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < 100


def test_hierarchical_lm_long_range_structure():
    src = HierarchicalLM(vocab_size=64, seq_len=256, batch_per_host=8,
                         seed=0)
    toks = src.batch(0)["tokens"]
    assert toks.shape == (8, 256)
    assert toks.min() >= 0 and toks.max() < 64


def _eval_listops(tokens):
    """Independent evaluator over the token encoding."""
    pos = 0

    def parse():
        nonlocal pos
        t = int(tokens[pos])
        pos += 1
        if DIGIT0 <= t < DIGIT0 + 10:
            return t - DIGIT0
        name = {v: k for k, v in OPS.items()}[t]
        vals = []
        while int(tokens[pos]) != CLOSE:
            vals.append(parse())
        pos += 1
        if name == "MIN":
            return min(vals)
        if name == "MAX":
            return max(vals)
        if name == "MED":
            return int(np.median(vals))
        return sum(vals) % 10

    return parse()


def test_listops_labels_match_independent_evaluator():
    src = ListOps(seq_len=256, batch_per_host=16, seed=3)
    batch = src.batch(0)
    for b in range(16):
        toks = batch["tokens"][b]
        n = int(batch["mask"][b].sum())
        assert toks[n:].max(initial=0) == PAD
        assert _eval_listops(toks[:n]) == batch["label"][b]
    assert batch["label"].min() >= 0
    assert batch["label"].max() < NUM_CLASSES


def test_prefetcher_orders_batches():
    src = ZipfLM(vocab_size=50, seq_len=16, batch_per_host=2, seed=7)
    pre = Prefetcher(src, start_step=5)
    try:
        b5 = pre.next()
        b6 = pre.next()
    finally:
        pre.close()
    np.testing.assert_array_equal(b5["tokens"], src.batch(5)["tokens"])
    np.testing.assert_array_equal(b6["tokens"], src.batch(6)["tokens"])
