"""Telemetry layer: disabled-path no-op guarantees, pinned export
schemas, and the analytic HBM/FLOP accounting cross-checked against the
EXPERIMENTS.md P25/P27 hand arithmetic.

The analytic-traffic tests are the paper-notebook numbers as executable
code: the P25 decode-tick figure (one fused attend launch reads
``nbands * nr`` cache rows of K and V per grid row) and the P27
fixed-HBM budget (245,760 dense cache bytes for the smoke llama config)
must both be reproduced by the generic traffic model in
``repro.obs.traffic`` from nothing but the traced LaunchContract.
"""
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.analysis import contracts
from repro.configs import get_smoke_config
from repro.obs import export, metrics, traffic

pytestmark = []


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and empty."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# -- disabled path: true no-op -----------------------------------------------

def test_disabled_accessors_return_shared_stubs():
    assert not obs.enabled()
    # object IDENTITY, not just no-op behaviour: the disabled hot path
    # must never allocate or touch the registry dict
    assert obs.counter("serve.ticks") is obs.NULL_COUNTER
    assert obs.counter("other", family="x") is obs.NULL_COUNTER
    assert obs.gauge("pool.occupancy") is obs.NULL_GAUGE
    assert obs.histogram("serve.ttft_s") is obs.NULL_HISTOGRAM
    assert obs.span("serve.tick") is obs.NULL_SPAN
    obs.counter("serve.ticks").inc()
    obs.gauge("pool.occupancy").set(0.5)
    obs.histogram("serve.ttft_s").observe(1.0)
    with obs.span("serve.tick"):
        pass
    obs.instant("kernel.launch")
    snap = metrics.registry().snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
    assert len(obs.tracing.buffer()) == 0


def test_disabled_overhead_is_tiny():
    """1e5 fully-instrumented iterations of the disabled path in well
    under a second -- i.e. the per-site cost is a branch + a no-op
    call, microseconds at most (the acceptance bound is < 1% on a real
    decode tick, which is milliseconds)."""
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        obs.counter("serve.ticks").inc()
        obs.gauge("serve.queue_depth").set(3)
        obs.histogram("serve.itl_s").observe(1e-3)
    dt = time.perf_counter() - t0
    assert dt < 2.0, f"{n} disabled-path iterations took {dt:.2f}s"


def test_disabled_launches_record_no_metrics():
    """contracts.launch() fires no telemetry while disabled (the hook
    is only registered by obs.enable())."""
    c = _capture_decode_contract(Lmax=64, nr=8, d=16, G=2, R=2)
    assert c is not None
    assert metrics.registry().snapshot()["counters"] == {}
    assert len(obs.tracing.buffer()) == 0


# -- enabled path ------------------------------------------------------------

def test_counters_gauges_labels_and_kind_conflict():
    obs.enable()
    obs.counter("kernel.launches", family="decode_attend").inc()
    obs.counter("kernel.launches", family="decode_attend").inc(2)
    obs.counter("kernel.launches", family="band_fwd").inc()
    obs.gauge("pool.occupancy").set(0.25)
    snap = metrics.registry().snapshot()
    assert snap["counters"][
        "kernel.launches{family=decode_attend}"] == 3
    assert snap["counters"]["kernel.launches{family=band_fwd}"] == 1
    assert snap["gauges"]["pool.occupancy"] == 0.25
    with pytest.raises(TypeError):
        obs.gauge("kernel.launches", family="band_fwd")


def test_histogram_exact_quantiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.exponential(0.01, size=200)
    h = obs.Histogram(keep_samples=len(xs))
    for x in xs:
        h.observe(float(x))
    assert h.exact
    assert h.quantile(0.5) == pytest.approx(np.median(xs), rel=1e-12)
    assert h.quantile(0.99) == pytest.approx(
        np.percentile(xs, 99), rel=1e-12)
    assert h.quantile(0.0) == pytest.approx(xs.min())
    assert h.quantile(1.0) == pytest.approx(xs.max())


def test_histogram_bucket_fallback_after_reservoir_overflow():
    h = obs.Histogram(keep_samples=8)
    rng = np.random.default_rng(1)
    xs = rng.uniform(1e-4, 1e-1, size=100)
    for x in xs:
        h.observe(float(x))
    assert not h.exact
    q = h.quantile(0.5)
    assert h.min <= q <= h.max
    # cumulative counts are monotone and end at the total
    cum = h.cumulative()
    assert [c for _, c in cum] == sorted(c for _, c in cum)
    assert cum[-1][0] == math.inf and cum[-1][1] == h.count


# -- pinned export schemas ---------------------------------------------------

def _populate():
    obs.enable()
    obs.counter("serve.ticks").inc(4)
    obs.counter("kernel.launches", family="decode_attend").inc()
    obs.gauge("pool.occupancy").set(0.5)
    for v in (1e-3, 2e-3, 5e-3):
        obs.histogram("serve.ttft_s").observe(v)
    with obs.span("serve.tick", tid=obs.TRACK_SERVE, args={"n": 2}):
        with obs.span("serve.decode", tid=obs.TRACK_SERVE):
            pass
    obs.instant("kernel.launch", tid=obs.TRACK_KERNELS,
                args={"family": "decode_attend", "grid": [4],
                      "hbm_read_bytes": 1024, "hbm_write_bytes": 64,
                      "flops": 2048})


def test_snapshot_schema_pinned():
    _populate()
    snap = export.snapshot()
    assert export.validate_snapshot(snap) == []
    assert snap["schema"] == "repro.obs.snapshot/1"
    h = snap["metrics"]["histograms"]["serve.ttft_s"]
    assert h["count"] == 3 and h["sum"] == pytest.approx(8e-3)
    assert h["min"] == pytest.approx(1e-3)
    assert h["p50"] == pytest.approx(2e-3)
    # tuning state rides in every snapshot (satellite: tuning obs)
    assert snap["tuning"]["backend"]
    # the snapshot round-trips through JSON unchanged
    assert export.validate_snapshot(
        json.loads(json.dumps(snap))) == []


def test_snapshot_validator_rejects_drift():
    _populate()
    snap = export.snapshot()
    bad = dict(snap, schema="repro.obs.snapshot/2")
    assert export.validate_snapshot(bad)
    bad = json.loads(json.dumps(snap))
    del bad["metrics"]["histograms"]["serve.ttft_s"]["buckets"]
    assert export.validate_snapshot(bad)
    bad = json.loads(json.dumps(snap))
    bad["tuning"]["tuning_digest"] = "nope"
    assert export.validate_snapshot(bad)


def test_prometheus_text_schema_pinned():
    _populate()
    text = export.prometheus_text()
    assert export.validate_prometheus_text(
        text, require_metrics=("repro_serve_ticks_total",
                               "repro_pool_occupancy",
                               "repro_serve_ttft_s_bucket",
                               "repro_serve_ttft_s_sum",
                               "repro_serve_ttft_s_count")) == []
    lines = text.splitlines()
    assert "# TYPE repro_serve_ticks counter" in lines
    assert "repro_serve_ticks_total 4" in lines
    assert ('repro_kernel_launches_total{family="decode_attend"} 1'
            in lines)
    # histogram buckets are cumulative and close with le="+Inf"
    buckets = [ln for ln in lines
               if ln.startswith("repro_serve_ttft_s_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)
    assert buckets[-1].startswith('repro_serve_ttft_s_bucket{le="+Inf"}')
    assert counts[-1] == 3
    # drift guard: a malformed line fails the validator
    assert export.validate_prometheus_text("bad line here\n")


def test_chrome_trace_schema_pinned(tmp_path):
    _populate()
    path = tmp_path / "trace.json"
    export.write_trace(str(path))
    doc = json.loads(path.read_text())
    assert export.validate_chrome_trace(
        doc, require_kernel_traffic=True) == []
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"serve.tick", "serve.decode", "kernel.launch",
            "thread_name", "process_name"} <= names
    # every track used is named via "M" metadata (Perfetto lanes)
    tracks = {e["args"]["name"] for e in evs if e["ph"] == "M"
              and e["name"] == "thread_name"}
    assert {"serve", "train", "bench", "kernels"} <= tracks
    # the environment header pins what produced the trace
    assert doc["metadata"]["backend"] == jax.default_backend()
    # drift guard: stripping the traffic args fails the strict check
    for e in evs:
        if e["name"] == "kernel.launch":
            del e["args"]["flops"]
    assert export.validate_chrome_trace(doc, require_kernel_traffic=True)


def test_jsonl_emitter(tmp_path):
    _populate()
    path = tmp_path / "metrics.jsonl"
    em = export.JsonlEmitter(str(path), period_s=3600.0)
    assert em.maybe_emit()          # first call always emits
    assert not em.maybe_emit()      # inside the period: skipped
    em.emit()                       # forced shutdown line
    lines = path.read_text().splitlines()
    assert len(lines) == 2
    for ln in lines:
        doc = json.loads(ln)
        assert export.validate_snapshot(doc) == []
        assert "unix_time" in doc


# -- analytic HBM/FLOP accounting vs P25/P27 ---------------------------------

def _capture_decode_contract(Lmax, nr, d, G, R):
    """Trace decode_attend_fused via eval_shape (no compile, no
    device) and return its LaunchContract."""
    from repro.core import h1d_decode as hd
    from repro.kernels.h1d_decode_kernel import decode_attend_fused
    cache = hd.init_cache(R, Lmax, d, d, nr=nr, dtype=jnp.float32)
    q = jnp.zeros((R, G, d), jnp.float32)
    t = jnp.full((R,), Lmax - 1, jnp.int32)
    with contracts.capture() as buf:
        jax.eval_shape(
            lambda c, q, t: decode_attend_fused(c, q, t, nr=nr),
            cache, q, t)
    (c,) = [c for c in buf if c.family == "decode_attend"]
    return c


def _capture_update_contract(Lmax, nr, d, R):
    from repro.core import h1d_decode as hd
    from repro.kernels.h1d_decode_kernel import update_cache_fused
    cache = hd.init_cache(R, Lmax, d, d, nr=nr, dtype=jnp.float32)
    kn = jnp.zeros((R, d), jnp.float32)
    vn = jnp.zeros((R, d), jnp.float32)
    t = jnp.full((R,), Lmax - 1, jnp.int32)
    with contracts.capture() as buf:
        jax.eval_shape(lambda c, k, v, t: update_cache_fused(c, k, v, t),
                       cache, kn, vn, t)
    (c,) = [c for c in buf if c.family == "decode_update"]
    return c


def test_analytic_hbm_matches_p25_decode_attend():
    """EXPERIMENTS.md P25, fused decode attend at Lmax=1024, nr=16,
    d=64: the kernel reads nbands 16-row K+V bands per grid row --
    ``nbands * nr * 2 * d * 4`` bytes -- and writes one (G, d) output
    block.  The generic per-contract traffic model must reproduce the
    hand count within 5% (its only extra term is the (G, d) q block)."""
    Lmax, nr, d, G, R = 1024, 16, 64, 4, 8
    c = _capture_decode_contract(Lmax, nr, d, G, R)
    # band count straight off the contract: own + prev + one per level
    nbands = 2 + sum(1 for o in c.inputs if o.name.startswith("k_lvl"))
    hand_read_per_row = nbands * nr * 2 * d * 4      # K+V bands, f32
    tr = traffic.contract_hbm_bytes(c)
    read_per_row = tr["read_bytes"] / R
    assert abs(read_per_row - hand_read_per_row) / hand_read_per_row \
        <= 0.05, (read_per_row, hand_read_per_row)
    # output writes are exact: one (1, G, d) f32 block per row
    assert tr["write_bytes"] == R * G * d * 4
    # FLOPs: 2*Q*K*(d+dv) matmul + softmax terms, Q=G, K=nbands*nr
    fl = traffic.contract_flops(c)
    K = nbands * nr
    hand_flops = R * (2 * G * K * (d + d) + 8 * G * K)
    assert abs(fl - hand_flops) / hand_flops <= 0.05, (fl, hand_flops)


def test_analytic_hbm_matches_p25_cache_update():
    """P25's update launch: per level, read AND write the 2-row K+V
    sibling pair -- ``M * 2 * 2 * d * 4`` bytes each way per row (reads
    add the two (1, d) new-token operands)."""
    Lmax, nr, d, R = 1024, 16, 64, 8
    c = _capture_update_contract(Lmax, nr, d, R)
    M = sum(1 for o in c.inputs if o.name.startswith("k_l"))
    tr = traffic.contract_hbm_bytes(c)
    hand_write_per_row = M * 2 * 2 * d * 4
    hand_read_per_row = hand_write_per_row + 2 * d * 4   # + k_new/v_new
    assert tr["write_bytes"] / R == hand_write_per_row
    assert tr["read_bytes"] / R == hand_read_per_row


def test_analytic_traffic_vs_p25_scaling_in_lmax():
    """The analytic read count must scale like the P25 accounting: one
    extra 2*nr-row band (K+V) per doubling of Lmax."""
    reads = {}
    for Lmax in (256, 512, 1024):
        c = _capture_decode_contract(Lmax, nr=16, d=64, G=4, R=4)
        reads[Lmax] = traffic.contract_hbm_bytes(c)["read_bytes"] / 4
    band = 16 * 2 * 64 * 4                       # nr * (K+V) * d * f32
    assert reads[512] - reads[256] == band
    assert reads[1024] - reads[512] == band


def test_p27_fixed_hbm_budget_hand_accounting():
    """EXPERIMENTS.md P27: the fixed-HBM concurrency headline budget is
    the DENSE engine's cache footprint -- slots x (layers x kv_heads) x
    (hierarchy rows) x head_dim x (K+V) x 4 bytes = 245,760 for the
    smoke llama3.2-1b at max_len 128 with 2 slots.  pool_bytes and the
    committed BENCH_serve.json must both equal the hand formula."""
    import os
    from repro.core import hierarchy
    from repro.models import get_model
    from repro.serve import paged_cache as pc
    cfg = get_smoke_config("llama3.2-1b")
    max_len, slots = 128, 2
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    caches = fns.init_caches(params, cfg, slots, max_len)
    # hierarchy row count: level-l has max_len >> l rows, down to the
    # coarsest level the decode cache keeps (2*nr rows)
    levels = hierarchy.num_levels(max_len, cfg.nr)
    rows = sum(max_len >> l for l in range(levels))
    head_dim = cfg.d_model // cfg.num_heads
    hand = slots * cfg.num_layers * cfg.num_kv_heads \
        * rows * head_dim * 2 * 4
    assert pc.pool_bytes(caches) == hand == 245_760
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "BENCH_serve.json")) as f:
        rows_json = json.load(f)["rows"]
    derived = next(r["derived"] for r in rows_json
                   if r["name"] == "serve_concurrency_fixed_hbm")
    assert int(derived.split("hbm_bytes=")[1].split()[0]) == hand


def test_launch_hook_feeds_registry_and_trace():
    """With telemetry on, a traced launch lands as kernel.* counters
    AND a kernel.launch instant whose analytic args agree with the
    direct traffic-model call."""
    obs.enable()
    c = _capture_decode_contract(Lmax=256, nr=8, d=16, G=2, R=4)
    snap = metrics.registry().snapshot()["counters"]
    assert snap["kernel.launches{family=decode_attend}"] >= 1
    tr = traffic.contract_hbm_bytes(c)
    assert snap["kernel.hbm_read_bytes{family=decode_attend}"] \
        == tr["read_bytes"]
    assert snap["kernel.hbm_write_bytes{family=decode_attend}"] \
        == tr["write_bytes"]
    doc = obs.tracing.buffer().chrome_trace(export.trace_metadata())
    launches = [e for e in doc["traceEvents"]
                if e["name"] == "kernel.launch"]
    assert launches and launches[0]["args"]["family"] == "decode_attend"
    assert launches[0]["args"]["hbm_read_bytes"] == tr["read_bytes"]
    assert export.validate_chrome_trace(
        doc, require_kernel_traffic=True) == []


# -- serve-path integration --------------------------------------------------

def _tiny_engine(paged):
    from repro.models import get_model
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, paged=paged,
                      token_budget=64)
    prompts = [np.arange(4, 16) % cfg.vocab_size,
               np.arange(4, 16) % cfg.vocab_size,       # shared prefix
               (np.arange(3, 27) * 5) % cfg.vocab_size]
    reqs = [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    return eng, reqs


@pytest.mark.slow
def test_serve_engine_emits_ticks_latencies_and_pool_counters():
    obs.enable()
    eng, reqs = _tiny_engine(paged=True)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    snap = export.snapshot()
    cs, hs = snap["metrics"]["counters"], snap["metrics"]["histograms"]
    assert cs["serve.requests"] == 3
    assert cs["serve.finished"] == 3
    assert cs["serve.ticks"] >= 1
    assert cs["serve.admissions"] >= 3
    # one TTFT per request; ITL for every subsequent token
    assert hs["serve.ttft_s"]["count"] == 3
    assert hs["serve.itl_s"]["count"] == sum(
        len(r.out_tokens) - 1 for r in reqs)
    assert hs["serve.request_latency_s"]["count"] == 3
    # pool counters mirrored from PoolStats: the duplicate prompt hits
    # the prefix registry
    assert cs.get("pool.prefix_hits", 0) >= 1
    assert "pool.occupancy" in snap["metrics"]["gauges"]
    assert "serve.token_budget_util" in snap["metrics"]["gauges"]
    # serve.tick spans cover every engine tick
    ticks = [e for e in obs.tracing.buffer().chrome_trace()
             ["traceEvents"] if e.get("name") == "serve.tick"]
    assert len(ticks) == cs["serve.ticks"]
    assert export.validate_snapshot(snap) == []


@pytest.mark.slow
def test_serve_engine_disabled_leaves_no_telemetry():
    eng, reqs = _tiny_engine(paged=False)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert metrics.registry().snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}}
    assert len(obs.tracing.buffer()) == 0


def test_pool_stats_snapshot_and_reset():
    from repro.serve.paged_cache import PoolStats
    st = PoolStats()
    st.prefix_hits += 3
    st.prefix_misses += 1
    st.cow_copies += 2
    snap = st.snapshot()
    assert snap["prefix_hits"] == 3 and snap["cow_copies"] == 2
    assert st.prefix_hit_rate() == pytest.approx(0.75)
    st.reset()
    assert st.prefix_hits == 0 and st.cow_copies == 0
    assert st.prefix_hit_rate() == 0.0          # no division by zero
    assert set(PoolStats().snapshot()) == set(snap)


def test_tuning_state_rides_in_snapshot():
    """Satellite: the KernelPolicy decision log is exportable through
    the obs snapshot, and the digest matches the policy's own."""
    from repro.kernels.tuning import get_policy
    p = get_policy()
    p.resolve_impl("auto")                      # force >= 1 decision
    ts = export.tuning_snapshot()
    assert ts["backend"] == p.backend
    assert ts["tuning_digest"] == p.tuning_digest()
    assert ts["decision_log_len"] == len(p.decisions)
    assert ts["decision_log_len"] >= 1
    total = sum(n for srcs in ts["decisions"].values()
                for n in srcs.values())
    assert total == ts["decision_log_len"]
