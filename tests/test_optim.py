"""Optimizers, schedules, gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, adafactor, apply_updates, cosine_schedule,
                         linear_schedule, clip_by_global_norm, global_norm,
                         init_error_feedback, int8_compress, topk_compress)


def quad_problem(seed=0, dim=8):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (dim, dim))
    A = A @ A.T / dim + jnp.eye(dim)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))

    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss, {"x": jnp.zeros((dim,))}


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lambda s: 0.05, weight_decay=0.0),
    lambda: adafactor(lambda s: 0.5),
])
def test_optimizer_converges_on_quadratic(make_opt):
    loss, params = quad_problem()
    opt = make_opt()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < l0 - 0.5


def test_adamw_weight_decay_shrinks_weights():
    opt = adamw(lambda s: 0.01, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    zeros = {"w": jnp.zeros((4,))}
    for _ in range(50):
        upd, state = opt.update(zeros, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    small = {"a": jnp.full((3,), 1e-3), "b": jnp.full((4,), 1e-3)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(same["a"], small["a"])


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    lin = linear_schedule(1.0, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-6
    assert float(cos(100)) < float(cos(50)) < float(cos(10))
    assert abs(float(lin(100))) < 1e-6


def test_int8_compression_error_feedback_unbiased_over_time():
    """Error feedback: sum of compressed grads converges to sum of true
    grads (residual stays bounded)."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (256,))}
    ef = init_error_feedback(g_true)
    total_c = jnp.zeros((256,))
    for i in range(50):
        gc, ef = int8_compress(g_true, ef)
        total_c = total_c + gc["w"]
    total_t = 50 * g_true["w"]
    # relative error of the accumulated signal is tiny
    rel = float(jnp.linalg.norm(total_c - total_t)
                / jnp.linalg.norm(total_t))
    assert rel < 0.02
    assert float(jnp.abs(ef.residual["w"]).max()) < 0.1


def test_topk_compression_sparsity_and_feedback():
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (1000,))}
    ef = init_error_feedback(g)
    gc, ef = topk_compress(g, ef, frac=0.05)
    nz = int((gc["w"] != 0).sum())
    assert nz <= 55
    # residual holds exactly what was dropped
    np.testing.assert_allclose(np.asarray(gc["w"] + ef.residual["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_compressed_sgd_still_converges():
    loss, params = quad_problem(seed=3)
    ef = init_error_feedback(params)
    for _ in range(400):
        g = jax.grad(loss)(params)
        gc, ef = int8_compress(g, ef)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, gc)
    g_final = jax.grad(loss)(params)
    assert float(global_norm(g_final)) < 0.05
