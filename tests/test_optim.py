"""Optimizers, schedules, gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, adafactor, apply_updates, cosine_schedule,
                         linear_schedule, clip_by_global_norm, global_norm,
                         init_error_feedback, int8_compress, topk_compress)


def quad_problem(seed=0, dim=8):
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (dim, dim))
    A = A @ A.T / dim + jnp.eye(dim)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,))

    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss, {"x": jnp.zeros((dim,))}


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(lambda s: 0.05, weight_decay=0.0),
    lambda: adafactor(lambda s: 0.5),
])
def test_optimizer_converges_on_quadratic(make_opt):
    loss, params = quad_problem()
    opt = make_opt()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < l0 - 0.5


def test_adamw_weight_decay_shrinks_weights():
    opt = adamw(lambda s: 0.01, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    zeros = {"w": jnp.zeros((4,))}
    for _ in range(50):
        upd, state = opt.update(zeros, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    small = {"a": jnp.full((3,), 1e-3), "b": jnp.full((4,), 1e-3)}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(same["a"], small["a"])


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    lin = linear_schedule(1.0, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-6
    assert float(cos(100)) < float(cos(50)) < float(cos(10))
    assert abs(float(lin(100))) < 1e-6


def test_int8_compression_error_feedback_unbiased_over_time():
    """Error feedback: sum of compressed grads converges to sum of true
    grads (residual stays bounded)."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (256,))}
    ef = init_error_feedback(g_true)
    total_c = jnp.zeros((256,))
    for i in range(50):
        gc, ef = int8_compress(g_true, ef)
        total_c = total_c + gc["w"]
    total_t = 50 * g_true["w"]
    # relative error of the accumulated signal is tiny
    rel = float(jnp.linalg.norm(total_c - total_t)
                / jnp.linalg.norm(total_t))
    assert rel < 0.02
    assert float(jnp.abs(ef.residual["w"]).max()) < 0.1


def test_int8_rounding_shared_with_kv_cache():
    """The gradient-compression int8 path and the paged KV-cache quantizer
    are the SAME utility (core.quantization) -- pin both call sites to
    identical rounding, including the round-half-to-even ties and the
    multiply-by-reciprocal scale rule the kernels rely on for parity."""
    from repro.core import quantization as qz
    from repro.optim import compression as comp
    assert comp._quantize_int8 is qz.quantize_int8
    assert comp._dequantize_int8 is qz.dequantize_int8
    # round-half-to-even at scale 1.0: 0.5 -> 0, 1.5 -> 2, 2.5 -> 2
    x = jnp.asarray([127.0, 0.5, 1.5, 2.5, -0.5, -1.5])
    q, s = qz.quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(float(s), 1.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(q), [127, 0, 2, 2, 0, -2])
    # scale = absmax * (1/127) as a MULTIPLY (never a divide, which
    # XLA may rewrite differently inside fused kernels)
    amax = jnp.float32(3.7)
    np.testing.assert_array_equal(
        np.asarray(qz.int8_scale(jnp.asarray([-amax, 0.1]))),
        np.asarray(amax * jnp.float32(qz.RECIP_QMAX)))
    # clipping at +-127 (no -128 asymmetry)
    q2, _ = qz.quantize_int8(jnp.asarray([1000.0, -1e-30]))
    assert int(q2[0]) == 127
    # per-row (axis=-1) and per-tensor agree on a single row
    row = jax.random.normal(jax.random.PRNGKey(2), (1, 16))
    qa, sa = qz.quantize_int8(row, axis=-1)
    qb, sb = qz.quantize_int8(row)
    np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
    np.testing.assert_allclose(float(sa[0, 0]), float(sb), rtol=1e-7)


def test_topk_compression_sparsity_and_feedback():
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (1000,))}
    ef = init_error_feedback(g)
    gc, ef = topk_compress(g, ef, frac=0.05)
    nz = int((gc["w"] != 0).sum())
    assert nz <= 55
    # residual holds exactly what was dropped
    np.testing.assert_allclose(np.asarray(gc["w"] + ef.residual["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_compressed_sgd_still_converges():
    loss, params = quad_problem(seed=3)
    ef = init_error_feedback(params)
    for _ in range(400):
        g = jax.grad(loss)(params)
        gc, ef = int8_compress(g, ef)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, gc)
    g_final = jax.grad(loss)(params)
    assert float(global_norm(g_final)) < 0.05
