"""Checkpointing: roundtrip, atomicity, async, GC, resharding restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt


def make_tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(key, (4, 8)),
                      "b": jnp.arange(3.0)},
            "step_list": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}


def test_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_ignored(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 5, tree)
    # simulate a crash mid-write: tmp dir without COMMIT
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    # and a renamed dir missing COMMIT
    bad2 = tmp_path / "step_00000010"
    bad2.mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_async_checkpointer_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = make_tree()
    for s in (1, 2, 3, 4):
        saver.save(s, tree)
    saver.wait()
    steps = sorted(os.listdir(str(tmp_path)))
    assert "step_00000003" in steps and "step_00000004" in steps
    assert "step_00000001" not in steps


def test_restore_with_sharding(tmp_path):
    tree = make_tree(seed=1)
    ckpt.save(str(tmp_path), 1, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = ckpt.restore(str(tmp_path), 1,
                       jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overwrite_same_step(tmp_path):
    t1 = make_tree(seed=2)
    t2 = jax.tree.map(lambda x: x + 1, t1)
    ckpt.save(str(tmp_path), 3, t1)
    ckpt.save(str(tmp_path), 3, t2)
    out = ckpt.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t1))
    np.testing.assert_array_equal(np.asarray(out["layer"]["b"]),
                                  np.asarray(t2["layer"]["b"]))
