"""Pallas kernel sweeps vs the pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import band_attention, band_attention_ref, MODES


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def make(B, G, L, d, dv, dtype, seed=0):
    k1, k2, k3 = keys(3, seed)
    q = jax.random.normal(k1, (B, G, L, d), dtype)
    k = jax.random.normal(k2, (B, L, d), dtype)
    v = jax.random.normal(k3, (B, L, dv), dtype)
    w = jnp.ones((B, L), jnp.float32)
    return q, k, v, w


SHAPES = [
    (1, 1, 128, 16, 16, 16),
    (2, 2, 256, 32, 32, 16),
    (1, 4, 256, 64, 64, 8),
    (2, 1, 384, 16, 8, 32),     # L not a power of two (tq must divide)
    (1, 1, 256, 128, 128, 16),
]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("B,G,L,d,dv,nr", SHAPES)
def test_kernel_matches_ref_f32(B, G, L, d, dv, nr, mode):
    if L % 128:
        pytest.skip("tile size must divide L")
    q, k, v, w = make(B, G, L, d, dv, jnp.float32)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=nr, mode=mode)
    yk, dk, mk = band_attention(q, k, v, w, nr=nr, mode=mode,
                                impl="pallas_interpret")
    np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mk, mr, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("mode", MODES)
def test_kernel_matches_ref_bf16(mode):
    q, k, v, w = make(1, 2, 256, 32, 32, jnp.bfloat16, seed=1)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
    yk, dk, mk = band_attention(q, k, v, w, nr=16, mode=mode,
                                impl="pallas_interpret")
    np.testing.assert_allclose(yk, yr, atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(dk, dr, atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("mode", MODES)
def test_jnp_blocked_matches_ref(mode):
    q, k, v, w = make(2, 2, 192, 16, 16, jnp.float32, seed=2)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
    yj, dj, mj = band_attention(q, k, v, w, nr=16, mode=mode, impl="jnp")
    np.testing.assert_allclose(yj, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dj, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mj, mr, atol=2e-5, rtol=1e-4)


def test_kernel_ragged_weights():
    q, k, v, w = make(1, 1, 256, 16, 16, jnp.float32, seed=3)
    w = (jnp.arange(256) < 201).astype(jnp.float32)[None]
    for mode in MODES:
        yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
        yk, dk, mk = band_attention(q, k, v, w, nr=16, mode=mode,
                                    impl="pallas_interpret")
        np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("mode", MODES)
def test_kernel_custom_vjp_grads(mode):
    q, k, v, w = make(1, 1, 256, 16, 16, jnp.float32, seed=4)

    def loss(fn):
        def f(q, k, v, w):
            y, dn, m = fn(q, k, v, w)
            z = y / jnp.maximum(dn, 1e-9)[..., None]
            return jnp.sum(z ** 2) + jnp.sum(jnp.tanh(m)) + 1e-3 * dn.sum()
        return f

    fk = loss(lambda *a: band_attention(*a, nr=16, mode=mode,
                                        impl="pallas_interpret"))
    fr = loss(lambda *a: band_attention_ref(*a, nr=16, mode=mode))
    gk = jax.grad(fk, argnums=(0, 1, 2, 3))(q, k, v, w)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3))(q, k, v, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_kernel_tq_tiling_variants():
    q, k, v, w = make(1, 1, 512, 32, 32, jnp.float32, seed=5)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode="l0_causal")
    for tq in (128, 256, 512):
        yk, dk, mk = band_attention(q, k, v, w, nr=16, mode="l0_causal",
                                    impl="pallas_interpret", tq=tq)
        np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
