"""Pallas kernel sweeps vs the pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import band_attention, band_attention_ref, MODES


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def make(B, G, L, d, dv, dtype, seed=0):
    k1, k2, k3 = keys(3, seed)
    q = jax.random.normal(k1, (B, G, L, d), dtype)
    k = jax.random.normal(k2, (B, L, d), dtype)
    v = jax.random.normal(k3, (B, L, dv), dtype)
    w = jnp.ones((B, L), jnp.float32)
    return q, k, v, w


# default run keeps the small square shape and the non-pow2-L ragged
# shape; the wide-head sweeps are redundant coverage (slow set)
SHAPES = [
    (1, 1, 128, 16, 16, 16),
    pytest.param(1, 4, 256, 64, 64, 8, marks=pytest.mark.slow),
    (2, 1, 384, 16, 8, 32),     # L not a power of two (tq must divide)
    pytest.param(1, 1, 256, 128, 128, 16, marks=pytest.mark.slow),
]
# ((2, 2, 256, 32, 32, 16) rides along in test_kernel_matches_ref_bf16)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("B,G,L,d,dv,nr", SHAPES)
def test_kernel_matches_ref_f32(B, G, L, d, dv, nr, mode):
    if L % 128:
        pytest.skip("tile size must divide L")
    q, k, v, w = make(B, G, L, d, dv, jnp.float32)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=nr, mode=mode)
    yk, dk, mk = band_attention(q, k, v, w, nr=nr, mode=mode,
                                impl="pallas_interpret")
    np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mk, mr, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("mode", MODES)
def test_kernel_matches_ref_bf16(mode):
    q, k, v, w = make(1, 2, 256, 32, 32, jnp.bfloat16, seed=1)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
    yk, dk, mk = band_attention(q, k, v, w, nr=16, mode=mode,
                                impl="pallas_interpret")
    np.testing.assert_allclose(yk, yr, atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(dk, dr, atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("mode", MODES)
def test_jnp_blocked_matches_ref(mode):
    q, k, v, w = make(2, 2, 192, 16, 16, jnp.float32, seed=2)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
    yj, dj, mj = band_attention(q, k, v, w, nr=16, mode=mode, impl="jnp")
    np.testing.assert_allclose(yj, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dj, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mj, mr, atol=2e-5, rtol=1e-4)


def test_kernel_ragged_weights():
    q, k, v, w = make(1, 1, 256, 16, 16, jnp.float32, seed=3)
    w = (jnp.arange(256) < 201).astype(jnp.float32)[None]
    for mode in MODES:
        yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode=mode)
        yk, dk, mk = band_attention(q, k, v, w, nr=16, mode=mode,
                                    impl="pallas_interpret")
        np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)


# gradient parity per mode is swept exhaustively in test_kernel_bwd;
# this spot-check keeps one causal + one bidir mode in the default run
@pytest.mark.parametrize("mode", [
    "l0_causal", "coarse_bidir",
    pytest.param("l0_bidir", marks=pytest.mark.slow),
    pytest.param("coarse_causal", marks=pytest.mark.slow)])
def test_kernel_custom_vjp_grads(mode):
    q, k, v, w = make(1, 1, 128, 16, 16, jnp.float32, seed=4)

    def loss(fn):
        def f(q, k, v, w):
            y, dn, m = fn(q, k, v, w)
            z = y / jnp.maximum(dn, 1e-9)[..., None]
            return jnp.sum(z ** 2) + jnp.sum(jnp.tanh(m)) + 1e-3 * dn.sum()
        return f

    fk = loss(lambda *a: band_attention(*a, nr=16, mode=mode,
                                        impl="pallas_interpret"))
    fr = loss(lambda *a: band_attention_ref(*a, nr=16, mode=mode))
    gk = jax.grad(fk, argnums=(0, 1, 2, 3))(q, k, v, w)
    gr = jax.grad(fr, argnums=(0, 1, 2, 3))(q, k, v, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_kernel_tq_tiling_variants():
    q, k, v, w = make(1, 1, 512, 32, 32, jnp.float32, seed=5)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode="l0_causal")
    for tq in (128, 256, 512):
        yk, dk, mk = band_attention(q, k, v, w, nr=16, mode="l0_causal",
                                    impl="pallas_interpret", tq=tq)
        np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)


def test_kernel_shrinks_tq_instead_of_xla_fallback():
    """L < tq must shrink the tile and STAY on the kernel path, not
    silently fall back to the blocked-jnp implementation (regression:
    kernel benchmarks/parity tests could unknowingly measure XLA)."""
    import repro.kernels.ops as ops

    q, k, v, w = make(1, 1, 64, 16, 16, jnp.float32, seed=9)
    calls = []
    orig = ops._blocked_jnp
    ops._blocked_jnp = lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1]
    try:
        yk, dk, mk = band_attention(q, k, v, w, nr=16, mode="l0_causal",
                                    impl="pallas_interpret", tq=128)
    finally:
        ops._blocked_jnp = orig
    assert not calls, "pallas impl fell back to blocked-jnp"
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=16, mode="l0_causal")
    np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
    with pytest.raises(ValueError):
        ops.resolve_tq(100, 16, 128, "l0_causal")   # L not a multiple of nr


# ---------------------------------------------------------------------------
# mode='sub' (fine-q causal coarse level): fine queries x coarse keys
# ---------------------------------------------------------------------------

# (L, nr, ratio, tq): covers the wide layout (nq < tq), the nq == tq
# boundary, and the deep layout (nq > tq, query block spans tiles)
SUB_SHAPES = [
    (512, 16, 2, 128),
    (512, 16, 8, 128),
    (512, 16, 16, 128),
    (1024, 16, 32, 128),
    (256, 8, 4, 64),
]


def make_sub(B, G, L, ratio, d, dv, seed=0):
    k1, k2, k3 = keys(3, seed)
    Lk = L // ratio
    q = jax.random.normal(k1, (B, G, L, d), jnp.float32)
    k = jax.random.normal(k2, (B, Lk, d), jnp.float32)
    v = jax.random.normal(k3, (B, Lk, dv), jnp.float32)
    w = jnp.ones((B, Lk), jnp.float32)
    return q, k, v, w


@pytest.mark.parametrize("L,nr,ratio,tq", SUB_SHAPES)
def test_sub_kernel_matches_ref(L, nr, ratio, tq):
    q, k, v, w = make_sub(2, 3, L, ratio, 16, 24, seed=ratio)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=nr, mode="sub",
                                    ratio=ratio)
    yk, dk, mk = band_attention(q, k, v, w, nr=nr, mode="sub", ratio=ratio,
                                impl="pallas_interpret", tq=tq)
    np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dk, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mk, mr, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("L,nr,ratio,tq", SUB_SHAPES[:3])
def test_sub_jnp_blocked_matches_ref(L, nr, ratio, tq):
    q, k, v, w = make_sub(1, 2, L, ratio, 16, 16, seed=10 + ratio)
    yr, dr, mr = band_attention_ref(q, k, v, w, nr=nr, mode="sub",
                                    ratio=ratio)
    yj, dj, mj = band_attention(q, k, v, w, nr=nr, mode="sub", ratio=ratio,
                                impl="jnp")
    np.testing.assert_allclose(yj, yr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(dj, dr, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(mj, mr, atol=2e-5, rtol=1e-4)


def test_sub_kernel_ragged_weights():
    """Padded coarse kv_weight: trailing weight-0 coarse keys must be
    masked identically to the dense oracle."""
    for L, nr, ratio, tq in ((512, 16, 2, 128), (512, 16, 16, 128)):
        q, k, v, w = make_sub(1, 1, L, ratio, 16, 16, seed=20 + ratio)
        Lk = L // ratio
        w = w * (jnp.arange(Lk) < Lk - 3).astype(jnp.float32)[None]
        yr, dr, mr = band_attention_ref(q, k, v, w, nr=nr, mode="sub",
                                        ratio=ratio)
        yk, dk, mk = band_attention(q, k, v, w, nr=nr, mode="sub",
                                    ratio=ratio, impl="pallas_interpret",
                                    tq=tq)
        np.testing.assert_allclose(yk, yr, atol=2e-5, rtol=1e-4)
        np.testing.assert_allclose(dk, dr, atol=2e-5, rtol=1e-4)
