"""Hand-written Pallas backward vs the jnp-reference VJP (interpret mode).

The custom VJP of ``band_attention(impl='pallas*')`` runs the fused
backward kernels in ``repro.kernels.h1d_block_bwd``; the oracle is
``jax.vjp`` of ``band_attention_ref`` (dense, natively differentiated).
Random cotangents on all three outputs ``(y, dn, m)`` exercise the
delta/recompute path AND the argmax routing of the row-max gradient.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import h1d_attention
from repro.kernels import band_attention, band_attention_ref, MODES


def make(B, G, L, d, dv, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, G, L, d), jnp.float32)
    k = jax.random.normal(k2, (B, L, d), jnp.float32)
    v = jax.random.normal(k3, (B, L, dv), jnp.float32)
    w = jnp.ones((B, L), jnp.float32)
    return q, k, v, w


def vjp_pair(mode, q, k, v, w, *, nr=16, tq=128, seed=7):
    """Return (pallas_grads, ref_grads) under identical random cotangents."""
    out_r, vjp_r = jax.vjp(
        lambda *a: band_attention_ref(*a, nr=nr, mode=mode), q, k, v, w)
    _, vjp_p = jax.vjp(
        lambda *a: band_attention(*a, nr=nr, mode=mode, tq=tq,
                                  impl="pallas_interpret"), q, k, v, w)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    cts = tuple(jax.random.normal(kk, o.shape, o.dtype)
                for kk, o in zip(ks, out_r))
    return vjp_p(cts), vjp_r(cts)


# the padded-w mask path is mode-agnostic: one causal + one bidir
# padded case run by default, the rest under -m slow
_ALL_MODE_CASES = [(m, False) for m in MODES] + [
    ("l0_causal", True), ("coarse_bidir", True)] + [
    pytest.param(m, True, marks=pytest.mark.slow)
    for m in MODES if m not in ("l0_causal", "coarse_bidir")]


@pytest.mark.parametrize("mode,padded", _ALL_MODE_CASES)
def test_bwd_parity_all_modes(mode, padded):
    q, k, v, w = make(1, 2, 128, 16, 16)
    if padded:
        w = w * (jnp.arange(128) < 101).astype(jnp.float32)[None]
    gp, gr = vjp_pair(mode, q, k, v, w)
    for name, a, b in zip("qkvw", gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"d{name} mismatch ({mode})")


@pytest.mark.parametrize("mode", [
    "l0_causal", "coarse_bidir",
    pytest.param("l0_bidir", marks=pytest.mark.slow),
    pytest.param("coarse_causal", marks=pytest.mark.slow)])
def test_bwd_parity_multi_tile_gqa(mode):
    # 4 query tiles at tq=128 exercises both halo directions of the
    # key-grid kernel; G=3 exercises the in-VMEM group accumulation;
    # dv != d exercises the separate value head width.
    q, k, v, w = make(1, 3, 256, 16, 32, seed=11)
    gp, gr = vjp_pair(mode, q, k, v, w, nr=16)
    for name, a, b in zip("qkvw", gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"d{name} mismatch ({mode})")


@pytest.mark.parametrize("tq", [128, pytest.param(256, marks=pytest.mark.slow)])
def test_bwd_parity_tq_variants(tq):
    # one mode suffices: this test varies only the tile size (the full
    # mode sweep runs in test_bwd_parity_all_modes)
    q, k, v, w = make(1, 1, 256, 16, 16, seed=3)
    for mode in ("l0_causal",):
        gp, gr = vjp_pair(mode, q, k, v, w, tq=tq)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("nr", [8, pytest.param(32, marks=pytest.mark.slow)])
def test_bwd_parity_nr_variants(nr):
    # one causal + one bidir mode suffice here: the full mode sweep runs
    # in test_bwd_parity_all_modes; this test only varies nr
    q, k, v, w = make(1, 1, 128, 16, 16, seed=5)
    for mode in ("l0_causal", "coarse_bidir"):
        gp, gr = vjp_pair(mode, q, k, v, w, nr=nr)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mode='sub' (fine-q causal coarse level) backward
# ---------------------------------------------------------------------------

from test_kernels import make_sub   # shared (B,G,L,ratio,d,dv) builder


def sub_vjp_pair(q, k, v, w, *, nr, ratio, tq=128, seed=7):
    out_r, vjp_r = jax.vjp(
        lambda *a: band_attention_ref(*a, nr=nr, mode="sub", ratio=ratio),
        q, k, v, w)
    _, vjp_p = jax.vjp(
        lambda *a: band_attention(*a, nr=nr, mode="sub", ratio=ratio,
                                  tq=tq, impl="pallas_interpret"),
        q, k, v, w)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    cts = tuple(jax.random.normal(kk, o.shape, o.dtype)
                for kk, o in zip(ks, out_r))
    return vjp_p(cts), vjp_r(cts)


# wide layout (nq < tq), nq == tq boundary, deep layout (nq > tq);
# G=2 exercises the in-VMEM GQA accumulation, multi-tile both grids,
# dv != d the separate value head width
# default: shallow wide + deepest deep layouts, padded only on the
# shallow one; remaining grid combinations run under -m slow
_SUB_CASES = [
    (256, 16, 2, 128, False),
    (256, 16, 2, 128, True),
    (512, 16, 16, 128, False),
    pytest.param(512, 16, 8, 128, False, marks=pytest.mark.slow),
    pytest.param(512, 16, 8, 128, True, marks=pytest.mark.slow),
    pytest.param(512, 16, 16, 128, True, marks=pytest.mark.slow),
    pytest.param(1024, 16, 32, 128, False, marks=pytest.mark.slow),
    pytest.param(1024, 16, 32, 128, True, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("L,nr,ratio,tq,padded", _SUB_CASES)
def test_sub_bwd_parity(L, nr, ratio, tq, padded):
    q, k, v, w = make_sub(1, 2, L, ratio, 16, 32, seed=ratio)
    if padded:
        Lk = L // ratio
        w = w * (jnp.arange(Lk) < Lk - 3).astype(jnp.float32)[None]
    gp, gr = sub_vjp_pair(q, k, v, w, nr=nr, ratio=ratio, tq=tq)
    for name, a, b in zip("qkvw", gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"d{name} mismatch (ratio={ratio})")


def _count_jnp_level_calls(monkeypatch):
    """Patch call counters onto the two pure-jnp level implementations."""
    import importlib
    h1d_mod = importlib.import_module("repro.core.h1d_attention")
    ops_mod = importlib.import_module("repro.kernels.ops")
    calls = {"_level_fine_q": 0, "_blocked_jnp": 0}

    orig_f = h1d_mod._level_fine_q
    orig_b = ops_mod._blocked_jnp

    def count_f(*a, **kw):
        calls["_level_fine_q"] += 1
        return orig_f(*a, **kw)

    def count_b(*a, **kw):
        calls["_blocked_jnp"] += 1
        return orig_b(*a, **kw)

    monkeypatch.setattr(h1d_mod, "_level_fine_q", count_f)
    monkeypatch.setattr(ops_mod, "_blocked_jnp", count_b)
    return calls


def test_h1d_fine_q_kernel_complete(monkeypatch):
    """Acceptance: fine-q causal fwd+grad at L=256, nr=16, tq=64 on the
    kernel path matches the jnp oracle to 1e-4 AND executes zero
    ``_level_fine_q`` / ``_blocked_jnp`` calls -- every hierarchy level
    runs fused, and tq=64 puts the three 'sub' levels on the wide
    (nq<tq), boundary (nq==tq) and deep (nq>tq) tilings."""
    B, G, L, D, nr = 1, 2, 256, 16, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(k1, (B, G, L, D), jnp.float32)
    k = jax.random.normal(k2, (B, L, D), jnp.float32)
    v = jax.random.normal(k3, (B, L, D), jnp.float32)

    def loss(impl):
        def f(q, k, v):
            z = h1d_attention(q, k, v, nr=nr, causal=True,
                              causal_mode="fine-q", impl=impl, tq=64)
            return jnp.sum(z ** 2)
        return f

    calls = _count_jnp_level_calls(monkeypatch)
    zk, gk = jax.value_and_grad(loss("pallas_interpret"),
                                argnums=(0, 1, 2))(q, k, v)
    assert calls == {"_level_fine_q": 0, "_blocked_jnp": 0}, calls

    zj, gj = jax.value_and_grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    assert calls["_level_fine_q"] > 0      # the oracle stayed on jnp
    np.testing.assert_allclose(zk, zj, atol=1e-4, rtol=1e-4)
    for name, a, b in zip("qkv", gk, gj):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"d{name} mismatch")


@pytest.mark.slow
@pytest.mark.parametrize("causal,cmode", [(False, "coarse-q"),
                                          (True, "coarse-q"),
                                          (True, "fine-q")])
def test_h1d_attention_grad_kernel_vs_jnp(causal, cmode):
    """Full-operator gradient through the streaming cross-level combine:
    the kernel path (level-0 + coarse levels on the custom VJP) against
    the blocked-jnp path (plain XLA autodiff).  Slow sweep: the default
    run covers the same path via test_h1d_fine_q_kernel_complete
    and the per-mode band parity tests."""
    B, G, L, D, nr = 1, 2, 256, 32, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(k1, (B, G, L, D), jnp.float32)
    k = jax.random.normal(k2, (B, L, D), jnp.float32)
    v = jax.random.normal(k3, (B, L, D), jnp.float32)

    def loss(impl):
        def f(q, k, v):
            z = h1d_attention(q, k, v, nr=nr, causal=causal,
                              causal_mode=cmode, impl=impl, tq=128)
            return jnp.sum(z ** 2)
        return f

    gk = jax.grad(loss("pallas_interpret"), argnums=(0, 1, 2))(q, k, v)
    gj = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gk, gj):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"d{name} mismatch")


@pytest.mark.parametrize("L", [pytest.param(320, marks=pytest.mark.slow), 129])
def test_local_attention_kernel_path_padding(L):
    """Kernel-path sliding-window attention must pad to the tile unit
    (regression: window-multiple padding tripped the L % tq assert)."""
    from repro.models.attention import _local_attention

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (1, L, 4, 16), jnp.float32)
    k = jax.random.normal(k2, (1, L, 2, 16), jnp.float32)
    v = jax.random.normal(k3, (1, L, 2, 16), jnp.float32)
    zi = _local_attention(q, k, v, 64, True, None, "pallas_interpret",
                          tq=128)
    zj = _local_attention(q, k, v, 64, True, None, "jnp", tq=128)
    np.testing.assert_allclose(zi, zj, atol=2e-5, rtol=1e-4)


def test_train_step_runs_on_kernel_path(monkeypatch):
    """A full fine-q causal training step (loss + grads + optimizer) on
    the Pallas custom-VJP path, via the TrainConfig attention overrides.
    Every hierarchy level must stay fused: zero pure-jnp level calls."""
    from repro.data import ZipfLM
    from repro.models.common import ModelConfig
    from repro.train import TrainConfig, init_state, make_train_step

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64, attention="h1d", nr=16,
                      tie_embeddings=True)
    tc = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=4,
                     attn_impl="pallas_interpret", attn_tq=128,
                     attn_causal_mode="fine-q")
    calls = _count_jnp_level_calls(monkeypatch)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = ZipfLM(vocab_size=64, seq_len=64, batch_per_host=2, seed=0)
    state, m = step(state, jax.tree.map(jnp.asarray, data.batch(0)))
    assert np.isfinite(float(m["loss"]))
    assert int(state.step) == 1
    assert calls == {"_level_fine_q": 0, "_blocked_jnp": 0}, calls
