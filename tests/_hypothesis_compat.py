"""Fallback for test modules that mix hypothesis property tests with
plain pytest tests: when ``hypothesis`` is not installed, ``@given``
tests skip cleanly while the rest of the module still runs.

Usage (at module top, after ``import pytest``)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
import pytest


class _Strategies:
    """Stub of ``hypothesis.strategies``: every strategy constructor
    returns an opaque dummy; ``@st.composite`` keeps the name callable so
    module-level ``shapes()``-style calls still evaluate."""

    def composite(self, fn):
        return lambda *a, **k: None

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()


def given(*args, **kwargs):
    return pytest.mark.skip(reason="hypothesis not installed")


def settings(*args, **kwargs):
    return lambda fn: fn
