"""Sequence-parallel kernel path (`parallel/sp_attention`): shard_map +
halo exchange around the unmodified fused Pallas kernels.

Parity targets the single-device ``impl='pallas_interpret'`` path (the
exact kernel program), per the SP acceptance bar: band levels and the
full hierarchy to <= 1e-5, decode-cache updates bit-exact, greedy
engine tokens identical.

Multi-device cases need fabricated host devices => subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (same pattern as
test_pipeline_parallel).  Each subprocess bundles several checks to
amortize the interpreter start-up.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


_PRELUDE = textwrap.dedent("""
    import os, warnings
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               "--xla_backend_optimization_level=0")
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel import sp_attention as sp
""")


BAND_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.kernels import band_attention

    B, G, L, D, nr = 2, 2, 128, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, G, L, D))
    k = jax.random.normal(ks[1], (B, L, D))
    v = jax.random.normal(ks[2], (B, L, D))
    w = jnp.ones((B, L)).at[:, -5:].set(0.0)        # padded tail

    MODES = [("l0_bidir", 1), ("l0_causal", 1), ("coarse_bidir", 1),
             ("coarse_causal", 1), ("sub", 2)]
    refs = {}
    for mode, ratio in MODES:
        Lk = L // ratio
        refs[(mode, ratio)] = band_attention(
            q, k[:, :Lk], v[:, :Lk], w[:, :Lk], nr=nr, mode=mode,
            ratio=ratio, impl="pallas_interpret")

    # d=4 makes L/d = 32 < tq hint 128: the tq shrink must keep the
    # kernel path under sharding (resolve_tq inside the local launch);
    # d=2 re-checks the bidirectional halo pair at another shard count
    cases = [(4, MODES), (2, [("l0_bidir", 1)])]
    for dsz, modes in cases:
        mesh = make_mesh((dsz,), ("data",))
        for mode, ratio in modes:
            Lk = L // ratio
            got = jax.jit(lambda q, k, v, w, m=mode, r=ratio, ms=mesh:
                          sp.sp_band_attention(
                              q, k, v, w, nr=nr, mode=m, ratio=r, tq=128,
                              impl="pallas_interpret", mesh=ms))(
                q, k[:, :Lk], v[:, :Lk], w[:, :Lk])
            err = max(float(jnp.abs(a - b).max())
                      for a, b in zip(got, refs[(mode, ratio)]))
            assert err < 1e-5, (dsz, mode, ratio, err)
    print("BAND_OK")

    # --- GQA dim0 not divisible by the model axis: LOUD fallback ------
    mesh_dm = make_mesh((2, 2), ("data", "model"))
    B3 = 3     # batch*kv_heads = 3, model axis = 2 -> cannot shard heads
    q3 = jax.random.normal(ks[3], (B3, G, L, D))
    k3, v3, w3 = k[:1].repeat(B3, 0), v[:1].repeat(B3, 0), w[:1].repeat(B3, 0)
    ref = band_attention(q3, k3, v3, w3, nr=nr, mode="l0_causal",
                         impl="pallas_interpret")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = sp.sp_band_attention(q3, k3, v3, w3, nr=nr, mode="l0_causal",
                                   impl="pallas_interpret", mesh=mesh_dm)
    assert any("model" in str(x.message) for x in rec), \\
        "expected a loud fallback warning"
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(got, ref))
    assert err < 1e-5, err
    print("GQA_FALLBACK_OK")
""")


H1D_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.core import h1d_attention

    B, G, L, D, nr = 1, 2, 128, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, G, L, D))
    k = jax.random.normal(ks[1], (B, L, D))
    v = jax.random.normal(ks[2], (B, L, D))
    w = jnp.ones((B, L)).at[:, -5:].set(0.0)

    # L/d = 32 with nr=16 -> levels 0-1 run local kernels, level 2 goes
    # through the gathered deep path
    mesh4 = make_mesh((4,), ("data",))
    for causal, cmode in ((True, "fine-q"), (False, "fine-q"),
                          (True, "coarse-q")):
        ref = h1d_attention(q, k, v, nr=nr, causal=causal,
                            causal_mode=cmode, kv_weight=w,
                            impl="pallas_interpret")
        got = jax.jit(lambda q, k, v, w, c=causal, m=cmode:
                      sp.sp_h1d_attention(
                          q, k, v, nr=nr, causal=c, causal_mode=m,
                          kv_weight=w, impl="pallas_interpret",
                          mesh=mesh4))(q, k, v, w)
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-5, (causal, cmode, err)
    print("H1D_OK")

    # --- gradients flow through the halo exchange (training path) -----
    # tiny shape: L/d = 16 with nr=8 still covers local kernels (levels
    # 0-1), the gathered deep level AND the custom-VJP backward kernels
    Lg, nrg = 64, 8
    qg, kg, vg = q[:, :, :Lg, :8], k[:, :Lg, :8], v[:, :Lg, :8]
    wg = jnp.ones((B, Lg))
    def loss(fn):
        return lambda *a: jnp.sum(fn(*a) ** 2)
    g_sp = jax.jit(jax.grad(loss(lambda q, k, v: sp.sp_h1d_attention(
        q, k, v, nr=nrg, causal=True, kv_weight=wg,
        impl="pallas_interpret", mesh=mesh4)), argnums=(0, 1, 2)))(qg, kg, vg)
    g_ref = jax.jit(jax.grad(loss(lambda q, k, v: h1d_attention(
        q, k, v, nr=nrg, causal=True, kv_weight=wg,
        impl="pallas_interpret")), argnums=(0, 1, 2)))(qg, kg, vg)
    for a, b in zip(g_sp, g_ref):
        err = float(jnp.abs(a - b).max() / (1.0 + jnp.abs(b).max()))
        assert err < 1e-5, err
    print("GRAD_OK")

    # --- sp_scope dispatch: h1d_attention routes itself under SP ------
    # trace-only check: the jaxpr must contain the SP collectives
    with sp.sp_scope(mesh4):
        jaxpr = str(jax.make_jaxpr(lambda q, k, v: h1d_attention(
            q, k, v, nr=nr, causal=True, kv_weight=w,
            impl="pallas_interpret"))(q, k, v))
    assert ("shard_map" in jaxpr) or ("ppermute" in jaxpr), jaxpr[:2000]
    without = str(jax.make_jaxpr(lambda q, k, v: h1d_attention(
        q, k, v, nr=nr, causal=True, kv_weight=w,
        impl="pallas_interpret"))(q, k, v))
    assert "ppermute" not in without
    print("DISPATCH_OK")
""")


DECODE_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.core import h1d_decode as hd

    B, G, Lmax, D, nr = 6, 2, 256, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    cache = hd.prefill_cache(jax.random.normal(ks[0], (B, Lmax, D)),
                             jax.random.normal(ks[1], (B, Lmax, D)),
                             Lmax, nr)
    q = jax.random.normal(ks[2], (B, G, D))
    # includes t == Lmax: out of range -- defensive parity with the
    # single-chip kernel's clamping (no shard may zero the deep levels)
    t = jnp.asarray([0, 15, 16, 130, 255, 256], jnp.int32)

    IMPL = "pallas_interpret"
    for dsz in (2, 4):
        mesh = make_mesh((dsz,), ("data",))
        z_ref = hd.decode_attend(cache, q, t, nr=nr, impl=IMPL)
        z_sp = jax.jit(lambda c, qq, tt, ms=mesh: sp.sp_decode_attend(
            c, qq, tt, nr=nr, impl=IMPL, mesh=ms))(cache, q, t)
        assert float(jnp.abs(z_sp - z_ref).max()) < 1e-5

        kn = jax.random.normal(ks[3], (B, D))
        vn = jax.random.normal(ks[4], (B, D))
        c_ref = hd.update_cache(cache, kn, vn, t, impl=IMPL)
        c_sp = jax.jit(lambda c, a, b, tt, ms=mesh: sp.sp_update_cache(
            c, a, b, tt, impl=IMPL, mesh=ms))(cache, kn, vn, t)
        for a, b in zip(jax.tree.leaves(c_sp), jax.tree.leaves(c_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("DECODE_OK")

    # scoped dispatch of the uniform (scalar-t) path -- the shape that
    # was explicitly single-chip before this layer
    mesh = make_mesh((4,), ("data",))
    with sp.sp_scope(mesh):
        zu = hd.decode_attend_uniform(cache, q, jnp.int32(130), nr=nr,
                                      impl=IMPL)
    zu_ref = hd.decode_attend_uniform(cache, q, jnp.int32(130), nr=nr,
                                      impl=IMPL)
    assert float(jnp.abs(zu - zu_ref).max()) < 1e-5
    print("UNIFORM_OK")
""")


ENGINE_SCRIPT = _PRELUDE + textwrap.dedent("""
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serve import ServeEngine, Request

    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)

    def run(mesh, slots):
        eng = ServeEngine(cfg, params, slots=slots, max_len=64,
                          decode_impl="pallas_interpret", mesh=mesh)
        rng = np.random.default_rng(0)
        reqs = []
        for i in range(slots):
            p = rng.integers(0, cfg.vocab_size,
                             size=int(rng.integers(6, 20))).astype(np.int32)
            r = Request(uid=i, prompt=p, max_new_tokens=6)
            reqs.append(r)
            eng.submit(r)
        eng.run()
        return [r.out_tokens for r in reqs]

    # greedy tokens must be IDENTICAL to the single-device kernel path;
    # slots=3 exercises a non-power-of-two slot count, slots=1 the
    # uniform long-context path
    ref3 = run(None, 3)
    assert run(make_mesh((2,), ("data",)), 3) == ref3
    ref1 = run(None, 1)
    assert run(make_mesh((4,), ("data",)), 1) == ref1
    print("ENGINE_OK")

    # too many shards for the cache -> loud error, not a wrong answer
    try:
        ServeEngine(cfg, params, slots=1, max_len=16,
                    decode_impl="pallas_interpret",
                    mesh=make_mesh((4,), ("data",)))
    except ValueError as e:
        assert "shard" in str(e)
        print("GUARD_OK")
    else:
        raise AssertionError("expected ValueError for unshardable max_len")
""")


def test_sp_band_parity_and_gqa_fallback():
    out = _run(BAND_SCRIPT)
    assert "BAND_OK" in out and "GQA_FALLBACK_OK" in out, out


def test_sp_hierarchy_parity_and_grads():
    out = _run(H1D_SCRIPT)
    for tag in ("H1D_OK", "GRAD_OK", "DISPATCH_OK"):
        assert tag in out, out


def test_sp_decode_parity():
    out = _run(DECODE_SCRIPT)
    assert "DECODE_OK" in out and "UNIFORM_OK" in out, out


def test_sp_engine_greedy_tokens_identical():
    out = _run(ENGINE_SCRIPT)
    assert "ENGINE_OK" in out and "GUARD_OK" in out, out


def test_sp_scope_noop_without_mesh():
    """sp_scope(None) and a 1-way axis are inert: plain single-device
    dispatch, no shard_map in the jaxpr."""
    from repro.parallel import sp_scope, sp_ctx
    with sp_scope(None):
        assert sp_ctx() is None
    mesh = jax.make_mesh((1,), ("data",))
    with sp_scope(mesh):
        assert sp_ctx() is None


def test_sp_one_way_passthrough_and_validation():
    """A 1-way mesh is a passthrough to the single-launch kernel, and
    unshardable shapes raise informative errors instead of computing a
    wrong answer."""
    from repro.parallel import sp_attention as sp
    mesh = jax.make_mesh((1,), ("data",))
    B, G, L, D, nr = 1, 1, 64, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, G, L, D))
    k = jax.random.normal(ks[1], (B, L, D))
    v = jax.random.normal(ks[2], (B, L, D))
    w = jnp.ones((B, L))
    from repro.kernels import band_attention
    ref = band_attention(q, k, v, w, nr=nr, mode="l0_causal",
                         impl="pallas_interpret")
    got = sp.sp_band_attention(q, k, v, w, nr=nr, mode="l0_causal",
                               impl="pallas_interpret", mesh=mesh)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    # shardability validation is pure shape math -- exercise it directly
    with pytest.raises(ValueError, match="fewer shards"):
        sp._validate_sp_shape(32, 8, 16, "test")   # L/d = 4 < nr
    assert sp.sp_sharded_levels(256, 16, 4) == 3   # fine + 2 coarse
    assert sp.sp_sharded_levels(64, 16, 4) == 1    # fine only
    assert sp.sp_sharded_levels(32, 16, 4) == 0    # too short to shard
