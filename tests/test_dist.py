"""Distributed-ownership checker (`analysis/dist.py`): the committed
sequence-parallel dispatch rules must verify clean on every mesh size,
and each violation kind (ownership-gap, ownership-overlap,
halo-mismatch, comm-mismatch) must be provably catchable -- a seeded
mutation of the corresponding rule is injected through the checker's
hook arguments and the expected kind must come back."""
import jax.numpy as jnp
import pytest

from repro.analysis import dist
from repro.parallel import sp_attention as sp


def _kinds(violations):
    return sorted({v.kind for v in violations})


# ---------------------------------------------------------------------------
# committed rules verify clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 2, 4, 8])
def test_decode_ownership_clean(d):
    checks, vs = dist.check_decode(d, 4, 64)
    assert checks > 0
    assert vs == [], _kinds(vs)


@pytest.mark.parametrize("d", [2, 4])
def test_halo_and_comm_clean(d):
    checks_h, vs_h = dist.check_halo(d, 4, 128)
    checks_c, vs_c = dist.check_comm(d, 4, 128)
    assert checks_h > 0 and checks_c > 0
    assert vs_h == [] and vs_c == []


def test_run_dist_sweep_shape():
    stats, vs = dist.run_dist(mesh_sizes=(2,), decode_geoms=((4, 64),),
                              band_geoms=((4, 64),))
    assert vs == []
    assert stats["configs"] == 3          # 1 decode + 1 halo/comm pair
    assert stats["checks"] > 0


# ---------------------------------------------------------------------------
# seeded mutations: every DIST kind is actually caught
# ---------------------------------------------------------------------------

def test_mutation_unclamped_owner_is_ownership_gap():
    """The historical last-shard rule: without the clip to d-1 the
    final position t == Lmax has no owner."""
    _, vs = dist.check_decode(
        4, 4, 64, update_owner=lambda t, Lloc, d: t // Lloc)
    assert "ownership-gap" in _kinds(vs)


def test_mutation_geq_owned_bits_is_ownership_overlap():
    """An `owner >= s` rule makes every earlier shard also claim the
    row: the exactly-once check must flag the double ownership."""
    _, vs = dist.check_decode(
        4, 4, 64,
        update_owned=lambda t, s, Lloc, d:
            (t // Lloc >= s).astype(jnp.int32))
    assert "ownership-overlap" in _kinds(vs)


def test_mutation_upper_clipped_local_t_is_halo_mismatch():
    """Clamping the owner's local position to Lloc-1 (the pre-PR-5 bug
    shape) breaks the sibling parity bits and the pair-map agreement."""
    _, vs = dist.check_decode(
        4, 4, 64,
        update_local_t=lambda t, s, Lloc: jnp.clip(t - s * Lloc, 0,
                                                   Lloc - 1))
    assert "halo-mismatch" in _kinds(vs)


def test_mutation_doubled_band_index_is_halo_mismatch():
    """A band-geometry that returns twice the local block index no
    longer reconstructs the dense contract's global block."""
    def bad_geo(t, s, nr, Lmax, d, nsh, nlevels):
        bidx, own = sp._band_geometry(t, s, nr, Lmax, d, nsh, nlevels)
        return bidx + bidx, own
    _, vs = dist.check_decode(4, 4, 64, band_geometry=bad_geo)
    assert "halo-mismatch" in _kinds(vs)


def test_mutation_empty_halo_is_halo_mismatch():
    """Dropping the one-block-per-direction halo exchange leaves the
    band_mask neighbourhood uncovered at every shard boundary."""
    _, vs = dist.check_halo(
        4, 4, 64, halo_blocks=lambda s, nbl, d, causal: set())
    assert _kinds(vs) == ["halo-mismatch"]
    assert len(vs) > 1                     # both modes, several levels


def test_mutation_wrong_shallow_count_is_comm_mismatch():
    """An off n_shallow breaks the L >> l >= d*nr threshold rule, the
    decode-path agreement and the pinned comm-volume formula."""
    _, vs = dist.check_comm(
        4, 4, 64, n_shallow_fn=lambda M, Lloc, nr: 1)
    assert "comm-mismatch" in _kinds(vs)


def test_all_dist_kinds_are_catchable():
    """Union over the seeded mutations covers every DIST kind -- the
    checker has no dead violation class."""
    caught = set()
    for kw in (dict(update_owner=lambda t, Lloc, d: t // Lloc),
               dict(update_owned=lambda t, s, Lloc, d:
                    (t // Lloc >= s).astype(jnp.int32)),
               dict(update_local_t=lambda t, s, Lloc:
                    jnp.clip(t - s * Lloc, 0, Lloc - 1))):
        caught |= {v.kind for v in dist.check_decode(4, 4, 64, **kw)[1]}
    caught |= {v.kind for v in dist.check_comm(
        4, 4, 64, n_shallow_fn=lambda M, Lloc, nr: 1)[1]}
    caught |= {v.kind for v in dist.check_halo(
        4, 4, 64, halo_blocks=lambda s, nbl, d, causal: set())[1]}
    assert caught >= set(dist.DIST_KINDS)
