"""Property tests: the hierarchical partition covers every token pair
exactly once at the right level (DESIGN.md section 1.1)."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hierarchy as hc
from repro.core.ref_attention import _level_mask_coarse, _level_mask_fine_q
from repro.kernels import band_mask


@st.composite
def shapes(draw):
    nr = draw(st.sampled_from([2, 4, 8, 16]))
    nb = draw(st.sampled_from([1, 2, 4, 8, 16]))
    return nr * nb, nr


@given(shapes(), st.booleans())
@settings(max_examples=30, deadline=None)
def test_level_assignment_complete_and_disjoint(shape, causal):
    L, nr = shape
    lam = hc.level_assignment_map(L, nr, causal=causal)
    i = np.arange(L)[:, None]
    j = np.arange(L)[None, :]
    if causal:
        assert (lam[j > i] == -1).all()
        assert (lam[j <= i] >= 0).all()
    else:
        assert (lam >= 0).all()
    # level = smallest l with block distance <= 1
    M = max(hc.num_levels(L, nr), 1)
    expect = np.full((L, L), -1)
    for l in range(M - 1, -1, -1):
        span = nr * (1 << l)
        near = np.abs(i // span - j // span) <= 1
        expect[near] = l
    if causal:
        expect[j > i] = -1
    assert (lam == expect).all()


@given(shapes())
@settings(max_examples=20, deadline=None)
def test_coarse_masks_partition_exactly(shape):
    """Union of per-level expanded masks == all pairs, disjointly."""
    L, nr = shape
    M = hc.num_levels(L, nr)
    if M == 0:
        pytest.skip("single block")
    total = np.zeros((L, L), np.int64)
    for l in range(M):
        Lc = L >> l
        m = _level_mask_coarse(Lc, nr, l, causal=False)
        total += np.kron(m, np.ones((1 << l, 1 << l), np.int64))
    assert total.min() == 1 and total.max() == 1


@given(shapes())
@settings(max_examples=20, deadline=None)
def test_fine_q_masks_partition_causal(shape):
    L, nr = shape
    M = hc.num_levels(L, nr)
    if M == 0:
        pytest.skip("single block")
    i = np.arange(L)[:, None]
    j = np.arange(L)[None, :]
    total = np.asarray(_level_mask_coarse(L, nr, 0, causal=True),
                       np.int64)
    for l in range(1, M):
        m = np.asarray(_level_mask_fine_q(L, L >> l, nr, l), np.int64)
        total += np.repeat(m, 1 << l, axis=1)
    lower = (j <= i)
    assert (total[lower] == 1).all()
    assert (total[~lower] == 0).all()


@given(shapes())
@settings(max_examples=15, deadline=None)
def test_band_mask_matches_level0_reference(shape):
    L, nr = shape
    qi = np.arange(L)[:, None]
    ki = np.arange(L)[None, :]
    for mode, causal in (("l0_bidir", False), ("l0_causal", True)):
        got = np.asarray(band_mask(qi, ki, nr, mode, L))
        ref = np.asarray(_level_mask_coarse(L, nr, 0, causal=causal))
        assert (got == ref).all(), mode


@given(shapes())
@settings(max_examples=15, deadline=None)
def test_band_mask_matches_coarse_reference(shape):
    Lc, nr = shape
    if Lc // nr < 2:
        pytest.skip("needs >= 2 blocks")
    qi = np.arange(Lc)[:, None]
    ki = np.arange(Lc)[None, :]
    for mode, causal in (("coarse_bidir", False), ("coarse_causal", True)):
        got = np.asarray(band_mask(qi, ki, nr, mode, Lc))
        ref = np.asarray(_level_mask_coarse(Lc, nr, 1, causal=causal))
        assert (got == ref).all(), mode
