"""Paged-pool model checker (`analysis/pool_model.py`): the REAL
PagePool must verify clean over an exhaustive bounded exploration, and
each violation kind (refcount-leak, use-after-free, shared-alias,
zombie-registry) must be provably catchable -- a seeded allocator
mutation (a PagePool subclass breaking one rule) must be caught with a
minimized counterexample that replays through the real pool."""
import inspect
import json

import numpy as np
import pytest

from repro.analysis import pool_model as pm
from repro.analysis.checker import Violation
from repro.serve.paged_cache import PagePool, PoolExhausted


def _geom():
    return dict(pm.DEFAULT_GEOMETRY)


# ---------------------------------------------------------------------------
# seeded allocator mutations (each breaks exactly one rule)
# ---------------------------------------------------------------------------

class NoUnregister(PagePool):
    """Eviction / COW forget to drop the registry entry."""

    def _unregister(self, l, page):
        pass


class LosePage(PagePool):
    """Unregistered refcount-0 pages silently leak (never freed)."""

    def _decref(self, l, page):
        self.refcount[l][page] -= 1
        if self.refcount[l][page] == 0 and (l, page) in self.key_of:
            self.evictable[(l, page)] = None


class EagerFree(PagePool):
    """Pages returned to the free list while still mapped elsewhere."""

    def _decref(self, l, page):
        super()._decref(l, page)
        if self.refcount[l][page] > 0:
            self.free[l].append(page)


class NoCow(PagePool):
    """Decode writes land on still-shared pages (no copy-on-write)."""

    def prepare_tick(self, slot, t, copies):
        from repro.serve.paged_cache import ZERO
        for l in range(self.M):
            blk = t // (self.nr << l)
            p = int(self.table[l][slot, blk])
            if p < 0:
                np_ = self._alloc(l)
                self._map(slot, l, blk, np_)
                copies.setdefault(l, []).append((ZERO, np_))
            elif (l, p) in self.key_of and self.refcount[l][p] == 1:
                self._unregister(l, p)


MUTANTS = [
    (NoUnregister, "zombie-registry"),
    (LosePage, "refcount-leak"),
    (EagerFree, "use-after-free"),
    (NoCow, "shared-alias"),
]


# ---------------------------------------------------------------------------
# the real pool is clean
# ---------------------------------------------------------------------------

def test_real_pool_explores_clean():
    res = pm.explore(max_states=2500)
    assert res.violations == []
    assert res.counterexample is None
    assert res.states >= 2500              # state space larger than cap
    # every op class and every interesting allocator path was exercised
    for op in ("admit", "tick", "finish", "snapshot", "restore"):
        assert res.coverage.get(op, 0) > 0, op
    for path in ("cow_copies", "evictions", "shared_maps", "fresh_pages"):
        assert res.coverage.get(path, 0) > 0, path


def test_ci_exploration_meets_state_floor():
    """The CI entry point (`run_pool` via `check --pool`) must explore
    at least 10^4 distinct states by default."""
    sig = inspect.signature(pm.run_pool)
    assert sig.parameters["max_states"].default >= 10 ** 4


# ---------------------------------------------------------------------------
# every pool kind is catchable, with replayable minimized schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls,kind", MUTANTS,
                         ids=[c.__name__ for c, _ in MUTANTS])
def test_mutation_caught_and_counterexample_replays(cls, kind):
    res = pm.explore(pool_factory=lambda: cls(**_geom()),
                     max_states=4000)
    kinds = {v.kind for v in res.violations}
    assert kind in kinds, kinds
    assert kinds <= set(pm.POOL_KINDS)
    ce = res.counterexample
    assert ce, "no counterexample schedule returned"
    assert len(ce) <= 4                    # minimization actually ran
    # the minimized schedule reproduces on the REAL (mutated) PagePool
    vs, _ = pm.replay_schedule(ce, pool_factory=lambda: cls(**_geom()))
    assert vs and {v.kind for v in vs} <= kinds
    # ... and the unmutated pool sails through the same schedule
    vs_clean, _ = pm.replay_schedule(ce)
    assert vs_clean == []
    # ... and survives a JSON round trip (the regression format)
    wire = json.loads(json.dumps(pm.schedule_to_json(ce)))
    assert pm.schedule_from_json(wire) == ce


def test_all_pool_kinds_are_catchable():
    caught = set()
    for cls, _ in MUTANTS:
        res = pm.explore(pool_factory=lambda cls=cls: cls(**_geom()),
                         max_states=4000)
        caught |= {v.kind for v in res.violations}
    assert caught == set(pm.POOL_KINDS)


# ---------------------------------------------------------------------------
# invariant functions flag hand-corrupted pools
# ---------------------------------------------------------------------------

def _admitted_pool():
    pool = PagePool(**_geom())
    pool.admit(0, pm.default_prompts()[0])
    assert pm.check_pool_invariants(pool) == []
    return pool


def test_invariants_flag_freed_while_mapped():
    pool = _admitted_pool()
    pool.free[0].append(int(pool.table[0][0, 0]))
    assert "use-after-free" in {v.kind
                                for v in pm.check_pool_invariants(pool)}


def test_invariants_flag_refcount_drift():
    pool = _admitted_pool()
    pool.refcount[0][int(pool.table[0][0, 0])] += 1
    assert "refcount-leak" in {v.kind
                               for v in pm.check_pool_invariants(pool)}


def test_invariants_flag_unregistered_alias():
    pool = _admitted_pool()
    p = int(pool.table[0][0, 0])
    pool.table[0][1, 0] = p                # alias without registry bump
    pool.refcount[0][p] += 1
    pool._unregister(0, p)
    assert "shared-alias" in {v.kind
                              for v in pm.check_pool_invariants(pool)}


def test_invariants_flag_stale_registry():
    pool = _admitted_pool()
    pool.registry[("bogus",)] = (0, 99)
    assert "zombie-registry" in {v.kind
                                 for v in pm.check_pool_invariants(pool)}


def test_tick_postconditions_flag_shared_write_set():
    pool = PagePool(**_geom())
    toks = pm.default_prompts()[2]         # 6 tokens: partial fine page
    pool.admit(0, toks)
    pool.admit(1, toks)                    # frontier page now shared
    t = len(toks)                          # t=6 lands IN the shared page
    vs = pm.check_tick_postconditions(pool, 0, t)
    assert "shared-alias" in {v.kind for v in vs}
    pool.prepare_tick(0, t, {})            # the real COW fixes it
    assert pm.check_tick_postconditions(pool, 0, t) == []
    assert pm.check_pool_invariants(pool) == []


def test_failed_admit_rolls_back_identically():
    pool = PagePool(slots=2, max_len=64, nr=8, pool_pages=4)
    fp0 = pm.pool_fingerprint(pool)
    with pytest.raises(PoolExhausted):
        pool.admit(0, np.arange(40, dtype=np.int32))   # needs 5 > 4
    assert pm._check_rollback(fp0, pm.pool_fingerprint(pool),
                              "admit slot0") == []


# ---------------------------------------------------------------------------
# admit_snapshot (restore path's allocator entry point)
# ---------------------------------------------------------------------------

def test_admit_snapshot_maps_private_pages():
    pool = PagePool(**_geom())
    toks = pm.default_prompts()[1]
    pool.admit(0, toks)
    blocks = {l: [int(b) for b in np.nonzero(pool.table[l][0] >= 0)[0]]
              for l in range(pool.M)}
    pool.release_slot(0)
    placed = pool.admit_snapshot(1, blocks)
    for l, pairs in placed.items():
        assert [b for b, _ in pairs] == blocks[l]
        for b, p in pairs:
            assert int(pool.table[l][1, b]) == p
            assert int(pool.refcount[l][p]) == 1     # private
            assert (l, p) not in pool.key_of          # never registered
    assert pm.check_pool_invariants(pool) == []


def test_admit_snapshot_exhaustion_unwinds_via_release():
    pool = PagePool(slots=1, max_len=16, nr=4, pool_pages=2)
    with pytest.raises(PoolExhausted):
        # 3 fine blocks against a 2-page fine pool
        pool.admit_snapshot(0, {0: [0, 1, 2]})
    # documented contract: partial mapping left in place ...
    assert (pool.table[0][0] >= 0).any()
    pool.release_slot(0)                   # ... caller unwinds
    assert pm.check_pool_invariants(pool) == []
    assert pool.occupancy() == 0.0


def test_violation_objects_are_checker_violations():
    """pool_model reuses the LaunchContract Violation type so the CLI
    and JSON report render both layers uniformly."""
    pool = _admitted_pool()
    pool.refcount[0][int(pool.table[0][0, 0])] += 1
    vs = pm.check_pool_invariants(pool)
    assert vs and all(isinstance(v, Violation) for v in vs)
    assert all(v.family == "pool" for v in vs)
