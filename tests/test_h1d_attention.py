"""Core hierarchical attention vs dense oracles, exactness, causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # property tests skip cleanly when absent
    from _hypothesis_compat import given, settings, st

from repro.core import (h1d_attention, h1d_attention_mha, dense_attention,
                        h1d_dense_oracle)

MODES = [(False, "coarse-q"), (True, "coarse-q"), (True, "fine-q")]


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@pytest.mark.parametrize("causal,mode", MODES)
@pytest.mark.parametrize("L,nr", [
    (64, 8),
    # deeper hierarchies are the heaviest jnp sweeps -- slow set; the
    # default run covers deep levels via the kernel-complete grad test
    # and the SP hierarchy parity tests
    pytest.param(128, 16, marks=pytest.mark.slow),
    pytest.param(128, 4, marks=pytest.mark.slow),
    (32, 32),
])
def test_matches_dense_oracle(L, nr, causal, mode):
    k1, k2, k3 = keys(3)
    q, k, v = rand(k1, 2, 2, L, 16), rand(k2, 2, L, 16), rand(k3, 2, L, 8)
    z1 = h1d_attention(q, k, v, nr=nr, causal=causal, causal_mode=mode)
    z2 = h1d_dense_oracle(q, k, v, nr=nr, causal=causal, causal_mode=mode)
    np.testing.assert_allclose(z1, z2, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("L,nr", [(16, 8), (32, 16), (8, 8)])
def test_exact_when_no_approximation(L, nr, causal):
    """With <= 2 level-0 blocks the tridiagonal covers all pairs: H1D
    must equal standard softmax attention exactly."""
    k1, k2, k3 = keys(3, seed=1)
    q, k, v = rand(k1, 1, 1, L, 8), rand(k2, 1, L, 8), rand(k3, 1, L, 8)
    z1 = h1d_attention(q, k, v, nr=nr, causal=causal)
    z2 = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(z1, z2, atol=2e-5, rtol=1e-4)


def test_fine_q_causality_no_future_leak():
    k1, k2, k3, k4 = keys(4, seed=2)
    L, nr, cut = 128, 8, 77
    q, k, v = rand(k1, 1, 1, L, 8), rand(k2, 1, L, 8), rand(k3, 1, L, 8)
    z1 = h1d_attention(q, k, v, nr=nr, causal=True, causal_mode="fine-q")
    q2 = q.at[:, :, cut:].add(rand(k4, 1, 1, L - cut, 8))
    k2_ = k.at[:, cut:].add(1.7)
    v2 = v.at[:, cut:].add(-2.3)
    z2 = h1d_attention(q2, k2_, v2, nr=nr, causal=True, causal_mode="fine-q")
    np.testing.assert_array_equal(np.asarray(z1[:, :, :cut]),
                                  np.asarray(z2[:, :, :cut]))


def test_coarse_q_is_paper_faithful_but_leaks():
    """Documents the coarse-query variant's future-information leak
    through attention *weights* (DESIGN.md 1.2): perturbing future tokens
    changes past outputs.  This is why fine-q is the serving default."""
    k1, k2, k3, k4 = keys(4, seed=3)
    L, nr, cut = 128, 8, 65
    q, k, v = rand(k1, 1, 1, L, 8), rand(k2, 1, L, 8), rand(k3, 1, L, 8)
    z1 = h1d_attention(q, k, v, nr=nr, causal=True, causal_mode="coarse-q")
    q2 = q.at[:, :, cut:].add(rand(k4, 1, 1, L - cut, 8))
    z2 = h1d_attention(q2, k, v, nr=nr, causal=True, causal_mode="coarse-q")
    assert float(jnp.abs(z1[:, :, :cut] - z2[:, :, :cut]).max()) > 1e-6


def test_rows_sum_to_one():
    """Applying attention to constant ones values must return ones
    (D-normalization correctness, Algorithm 1)."""
    k1, k2 = keys(2, seed=4)
    L, nr = 64, 8
    q, k = rand(k1, 2, 1, L, 8), rand(k2, 2, L, 8)
    v = jnp.ones((2, L, 4))
    for causal, mode in MODES:
        z = h1d_attention(q, k, v, nr=nr, causal=causal, causal_mode=mode)
        np.testing.assert_allclose(z, 1.0, atol=1e-5)


def test_numerically_stable_large_logits():
    k1, k2, k3 = keys(3, seed=5)
    L, nr = 128, 8
    q = rand(k1, 1, 1, L, 8) * 200.0
    k = rand(k2, 1, L, 8) * 200.0
    v = rand(k3, 1, L, 4)
    for causal, mode in MODES:
        z = h1d_attention(q, k, v, nr=nr, causal=causal, causal_mode=mode)
        assert np.isfinite(np.asarray(z)).all()


def test_kv_weight_pad_invariance():
    k1, k2, k3 = keys(3, seed=6)
    L, valid, nr = 128, 90, 8
    q, k, v = rand(k1, 1, 1, L, 8), rand(k2, 1, L, 8), rand(k3, 1, L, 8)
    w = (jnp.arange(L) < valid).astype(jnp.float32)[None]
    z1 = h1d_attention(q, k, v, nr=nr, kv_weight=w)
    z2 = h1d_attention(q, k.at[:, valid:].set(99.0),
                       v.at[:, valid:].set(-99.0), nr=nr, kv_weight=w)
    np.testing.assert_array_equal(np.asarray(z1[:, :, :valid]),
                                  np.asarray(z2[:, :, :valid]))


def test_mha_gqa_wrapper_matches_manual():
    k1, k2, k3 = keys(3, seed=7)
    B, L, Hq, Hkv, D, nr = 2, 64, 4, 2, 8, 8
    q = rand(k1, B, L, Hq, D)
    k = rand(k2, B, L, Hkv, D)
    v = rand(k3, B, L, Hkv, D)
    z = h1d_attention_mha(q, k, v, nr=nr, causal=True)
    for h in (0, Hq - 1):      # first/last head: one per kv group
        kv = h // (Hq // Hkv)
        zh = h1d_attention(q[:, :, h][:, None], k[:, :, kv], v[:, :, kv],
                           nr=nr, causal=True)[:, 0]
        np.testing.assert_allclose(z[:, :, h], zh, atol=2e-5, rtol=1e-4)


@given(st.sampled_from([4, 8, 16]), st.sampled_from([4, 8]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_oracle_agreement(nb, nr, seed):
    L = nb * nr
    k1, k2, k3 = keys(3, seed=seed % 1000)
    q, k, v = rand(k1, 1, 1, L, 4), rand(k2, 1, L, 4), rand(k3, 1, L, 4)
    for causal, mode in MODES:
        z1 = h1d_attention(q, k, v, nr=nr, causal=causal, causal_mode=mode)
        z2 = h1d_dense_oracle(q, k, v, nr=nr, causal=causal,
                              causal_mode=mode)
        np.testing.assert_allclose(z1, z2, atol=3e-5, rtol=1e-3)
