"""Training loop: loss goes down, grad-accum equivalence, checkpoint
restart continuity, watchdog."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import ZipfLM
from repro.train import (TrainConfig, init_state, make_train_step, Watchdog, checkpoint as ckpt)


def small_cfg():
    cfg = get_smoke_config("llama3.2-1b")
    return cfg


@pytest.mark.slow
def test_loss_decreases():
    cfg = small_cfg()
    tc = TrainConfig(peak_lr=3e-3, warmup=5, total_steps=60, ckpt_every=0)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = ZipfLM(vocab_size=cfg.vocab_size, seq_len=64, batch_per_host=8,
                  seed=0)
    losses = []
    for i in range(60):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses[::10]


def test_grad_accum_matches_large_batch():
    cfg = small_cfg()
    data = ZipfLM(vocab_size=cfg.vocab_size, seq_len=32, batch_per_host=8,
                  seed=1)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    tc1 = TrainConfig(peak_lr=1e-3, warmup=1, total_steps=10, grad_accum=1)
    tc2 = TrainConfig(peak_lr=1e-3, warmup=1, total_steps=10, grad_accum=4)
    s1, _ = init_state(jax.random.PRNGKey(0), cfg, tc1)
    s2, _ = init_state(jax.random.PRNGKey(0), cfg, tc2)
    s1b, _ = jax.jit(make_train_step(cfg, tc1))(s1, batch)
    s2b, _ = jax.jit(make_train_step(cfg, tc2))(s2, batch)
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s2b.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)


@pytest.mark.slow
def test_checkpoint_restart_continuity(tmp_path):
    cfg = small_cfg()
    tc = TrainConfig(peak_lr=1e-3, warmup=2, total_steps=20,
                     ckpt_dir=str(tmp_path), ckpt_every=5)
    data = ZipfLM(vocab_size=cfg.vocab_size, seq_len=32, batch_per_host=4,
                  seed=2)
    step = jax.jit(make_train_step(cfg, tc))

    # run 1: steps 0..9, checkpointing every 5
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    for i in range(10):
        state, _ = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        if (i + 1) % 5 == 0:
            saver.save(i + 1, state)
    saver.wait()
    ref_state = state

    # run 2: crash-restart from step 10, replays nothing, continues
    assert ckpt.latest_step(str(tmp_path)) == 10
    fresh, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    restored = ckpt.restore(str(tmp_path), 10, fresh)
    assert int(restored.step) == 10
    for a, b in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(ref_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continue training works
    restored, m = step(restored,
                       jax.tree.map(jnp.asarray, data.batch(10)))
    assert np.isfinite(float(m["loss"]))


def test_watchdog_flags_stragglers():
    wd = Watchdog(factor=3.0)
    for _ in range(10):
        wd.observe(0.1)
    assert wd.observe(1.0) is True
    assert wd.alarms == 1
    assert wd.observe(0.1) is False


def test_compressed_training_step_runs():
    cfg = small_cfg()
    tc = TrainConfig(peak_lr=1e-3, warmup=1, total_steps=5,
                     compress_grads="int8")
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    assert state.ef_state is not None
    data = ZipfLM(vocab_size=cfg.vocab_size, seq_len=32, batch_per_host=4,
                  seed=3)
    step = jax.jit(make_train_step(cfg, tc))
    for i in range(3):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        assert np.isfinite(float(m["loss"]))
    # residuals are being used
    res = jax.tree.leaves(state.ef_state.residual)
    assert any(float(jnp.abs(r).max()) > 0 for r in res)
