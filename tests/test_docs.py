"""Docs-layer invariant: every repo-root markdown file cited anywhere in
src/ (docstrings, comments) must exist -- README/DESIGN/EXPERIMENTS are
load-bearing references, not aspirations.  Logic lives in
scripts/check_docs.py so CI shells and the test share one scanner."""
import os
import sys


_SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
sys.path.insert(0, os.path.abspath(_SCRIPTS))

import check_docs  # noqa: E402


def test_no_dangling_markdown_references():
    missing = check_docs.missing_references()
    assert not missing, (
        "dangling repo-root markdown references:\n" + "\n".join(
            f"  {path}:{lineno}: {name}" for path, lineno, name in missing))


def test_no_stale_code_paths_in_docs():
    stale = check_docs.missing_code_paths()
    assert not stale, (
        "docs cite code files that do not exist:\n" + "\n".join(
            f"  {doc}:{lineno}: {ref}" for doc, lineno, ref in stale))


def test_code_path_regex_strips_qualifiers(tmp_path):
    doc = tmp_path / "X.md"
    doc.write_text("see src/repro/analysis/checker.py:check_contract and "
                   "tests/test_analysis.py; src/repro/nope_gone.py too\n")
    stale = check_docs.missing_code_paths(root=check_docs.ROOT,
                                          docs=(os.path.relpath(doc,
                                                check_docs.ROOT),))
    assert [r for _, _, r in stale] == ["src/repro/nope_gone.py"]


def test_core_docs_exist():
    for name in ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md"):
        assert os.path.exists(os.path.join(check_docs.ROOT, name)), name
