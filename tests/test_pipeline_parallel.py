"""Pipeline parallelism: GPipe schedule equals sequential application.

Needs >1 device => runs in a subprocess with fabricated host devices.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel import pipeline_apply

    S, M, Bm, D = 4, 8, 2, 16
    mesh = make_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, D, D)) * 0.3
    bs = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (M, Bm, D))

    def stage_fn(params, h):
        W, b = params
        return jnp.tanh(h @ W + b)

    with jax.set_mesh(mesh):
        out = pipeline_apply(stage_fn, (Ws, bs), x, mesh=mesh, axis="stage")
    out = np.asarray(out)

    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s] + bs[s])
    err = float(jnp.abs(out - np.asarray(ref)).max())
    print("ERR", err)
    assert err < 1e-5, err
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
