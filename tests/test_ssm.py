"""Mamba2 SSD: chunked algorithm vs naive recurrence oracle + decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # property tests skip cleanly when absent
    from _hypothesis_compat import given, settings, st

from repro.models.common import ModelConfig
from repro.models.ssm import (ssd_chunked, ssd_reference, ssd_step,
                              mamba2_init, mamba2_apply, mamba2_decode)


def make(B, S, H, P, G, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = 0.3 * jax.random.normal(ks[3], (B, S, G, N))
    Cm = 0.3 * jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunked_matches_reference(chunk):
    x, dt, A, Bm, Cm = make(2, 64, 4, 8, 2, 16)
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(h1, h2, atol=2e-4, rtol=1e-3)


@given(st.sampled_from([1, 2]), st.sampled_from([16, 32]),
       st.sampled_from([2, 4]), st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_ssd_property_chunk_invariance(B, S, H, seed):
    x, dt, A, Bm, Cm = make(B, S, H, 4, 1, 8, seed=seed)
    y8, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    yS, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=S)
    np.testing.assert_allclose(y8, yS, atol=3e-4, rtol=1e-3)


def test_ssd_step_chain_matches_reference():
    x, dt, A, Bm, Cm = make(1, 32, 2, 4, 1, 8, seed=1)
    yref, _ = ssd_reference(x, dt, A, Bm, Cm)
    h = jnp.zeros((1, 2, 8, 4))
    ys = []
    for t in range(32):
        y, h = ssd_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), yref, atol=1e-4, rtol=1e-3)


def test_mamba2_layer_decode_consistency():
    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8,
                      ssm_expand=2, ssm_chunk=8)
    key = jax.random.PRNGKey(2)
    p, _ = mamba2_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 24, 32))
    y_full, (h, conv) = mamba2_apply(p, cfg, x, return_state=True)
    # step-by-step decode must reproduce the full pass
    import repro.models.ssm as ssm_mod
    d_inner, H, G, N, conv_dim = ssm_mod.mamba2_dims(cfg)
    state = (jnp.zeros((2, H, N, cfg.ssm_head_dim)),
             jnp.zeros((2, cfg.ssm_conv_width - 1, conv_dim)))
    outs = []
    for t in range(24):
        y, state = mamba2_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y[:, 0])
    ydec = jnp.stack(outs, 1)
    np.testing.assert_allclose(ydec, y_full, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(state[0], h, atol=2e-4, rtol=1e-3)


def test_ssd_state_carry_composes():
    x, dt, A, Bm, Cm = make(1, 64, 2, 4, 1, 8, seed=3)
    yf, hf = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y1, h1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                         chunk=16)
    y2, h2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                         chunk=16, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), yf,
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(h2, hf, atol=2e-4, rtol=1e-3)
