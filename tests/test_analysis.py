"""Launch-contract subsystem: capture/recording, the static checker on
clean contracts, the seeded-mutation suite (an injected off-by-one index
map, double-written output block, out-of-range prefetch index, and alias
dtype mismatch must each be flagged), the checker-vs-runtime agreement
shim, and static VMEM rejection in the autotune candidate path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis import check, checker, vmem
from repro.analysis.contracts import (LaunchContract, Operand, capture,
                                      recent)
from repro.kernels import h1d_block, h1d_block_bwd
from repro.kernels.tuning import KernelPolicy, set_policy

F32 = "float32"


@pytest.fixture
def fresh_policy(tmp_path):
    p = KernelPolicy(cache_dir=str(tmp_path))
    prev = set_policy(p)
    yield p
    set_policy(prev)


def _band_shapes(L=256, d=16, ratio=1, B=1, G=2):
    Lk = L // ratio
    q = jax.ShapeDtypeStruct((B, G, L, d), F32)
    k = jax.ShapeDtypeStruct((B, Lk, d), F32)
    v = jax.ShapeDtypeStruct((B, Lk, d), F32)
    w = jax.ShapeDtypeStruct((B, Lk), F32)
    return q, k, v, w


@pytest.fixture(scope="module")
def band_c():
    """One clean band_fwd contract: L=256, nr=16, tq=64 -> grid (1,2,4)."""
    q, k, v, w = _band_shapes()
    with capture() as got:
        jax.eval_shape(lambda *a: h1d_block.band_attention_fwd(
            *a, nr=16, mode="l0_causal", tq=64), q, k, v, w)
    (c,) = got
    return c


@pytest.fixture(scope="module")
def decode_cs():
    """Every decode family's contracts at the checker CLI's geometry."""
    return check.decode_contracts(nr=4, d=8)


def _first(labeled, family):
    for _, c in labeled:
        if c.family == family:
            return c
    raise AssertionError(f"no {family} contract captured")


# ---------------------------------------------------------------------------
# capture + recording
# ---------------------------------------------------------------------------

def test_capture_records_launch(band_c):
    assert band_c.family == "band_fwd"
    assert band_c.grid == (1, 2, 4)
    assert [o.name for o in band_c.outputs] == ["y", "dn", "m"]
    assert band_c.inputs[0].name == "q"
    assert band_c.inputs[0].block == (1, 1, 64, 16)
    assert band_c.meta["mode"] == "l0_causal"
    assert band_c in recent("band_fwd")


# ---------------------------------------------------------------------------
# clean contracts pass
# ---------------------------------------------------------------------------

def test_band_contract_clean(band_c):
    assert checker.check_contract(band_c) == []


def test_all_decode_families_clean(decode_cs):
    fams = {c.family for _, c in decode_cs}
    assert {"decode_attend", "decode_update", "decode_attend_partial",
            "decode_update_partial", "decode_attend_paged",
            "decode_update_paged", "decode_attend_paged_quant",
            "decode_update_paged_quant"} <= fams
    for label, c in decode_cs:
        vs = checker.check_contract(c)
        assert vs == [], f"{label}: {[str(v) for v in vs]}"


def test_check_cli_main_passes():
    # tiny geometry so the in-test CLI run stays fast; the full default
    # sweep runs in scripts/ci.sh
    assert check.main(["--nr", "4", "--d", "8", "--samples", "1"]) == 0


# ---------------------------------------------------------------------------
# seeded-mutation suite: the checker must flag each injected defect
# ---------------------------------------------------------------------------

def _replace_input(c, i, **fields):
    ins = list(c.inputs)
    ins[i] = dataclasses.replace(ins[i], **fields)
    return dataclasses.replace(c, inputs=tuple(ins))


def _replace_output(c, o, **fields):
    outs = list(c.outputs)
    outs[o] = dataclasses.replace(outs[o], **fields)
    return dataclasses.replace(c, outputs=tuple(outs))


def test_mutation_off_by_one_index_map(band_c):
    """+1 on the q tile component walks past the last tile -> oob."""
    orig = band_c.inputs[0].index_map
    mut = _replace_input(
        band_c, 0,
        index_map=lambda b, g, i: (lambda t: t[:2] + (t[2] + 1, t[3]))(
            orig(b, g, i)))
    vs = checker.check_contract(mut)
    assert any(v.kind == "oob" and v.operand == "q" for v in vs), \
        [str(v) for v in vs]


def test_mutation_double_written_output(band_c):
    """Folding the y map onto half the tiles revisits blocks at
    non-contiguous grid steps AND leaves blocks unwritten."""
    mut = _replace_output(band_c, 0,
                          index_map=lambda b, g, i: (b, g, i % 2, 0))
    kinds = {v.kind for v in checker.check_contract(mut)}
    assert "double-write" in kinds, kinds
    assert "coverage-gap" in kinds, kinds


def test_mutation_out_of_range_prefetch(decode_cs):
    """Raising the page-table domain one past the pool's page count must
    surface as scalar-oob (a prefetch index outside the pool)."""
    c = _first(decode_cs, "decode_attend_paged")
    s = c.scalars[1]
    assert s.name == "bidx"
    mut = dataclasses.replace(
        c, scalars=(c.scalars[0],
                    dataclasses.replace(s, hi=np.asarray(s.hi) + 1)))
    vs = checker.check_contract(mut)
    assert any(v.kind == "scalar-oob" for v in vs), [str(v) for v in vs]


def test_mutation_alias_dtype_mismatch(decode_cs):
    """An aliased input whose dtype disagrees with its output must be
    flagged -- the in-place update would reinterpret the buffer."""
    c = _first(decode_cs, "decode_update_paged")
    assert c.aliases, "update_cache_paged must alias its pool operands"
    i, _ = c.aliases[0]
    mut = _replace_input(c, i, dtype="int8")
    vs = checker.check_contract(mut)
    assert any(v.kind == "alias-mismatch" for v in vs), [str(v) for v in vs]
    assert checker.summarize(vs)["by_kind"]["alias-mismatch"] >= 1


# ---------------------------------------------------------------------------
# checker-vs-runtime agreement: the contract IS what pallas_call gets
# ---------------------------------------------------------------------------

def test_contracts_agree_with_pallas_call(monkeypatch):
    """Shim ``pl`` inside the contracts module to record every live
    ``pallas_call``'s kwargs, trace one concrete shape per family, and
    assert the captured contract matches the call: grid, scalar-prefetch
    count, the very same BlockSpec index maps, block/array shapes, and
    the scalar-shifted alias dict."""
    from repro.analysis import contracts as C

    real_pl = C.pl
    recorded = []

    class _Shim:
        def __getattr__(self, name):
            return getattr(real_pl, name)

        def pallas_call(self, kernel, **kw):
            recorded.append(kw)
            return real_pl.pallas_call(kernel, **kw)

    monkeypatch.setattr(C, "pl", _Shim())

    q, k, v, w = _band_shapes(L=128)
    y = jax.ShapeDtypeStruct(q.shape, F32)
    r = jax.ShapeDtypeStruct(q.shape[:3], F32)
    qs, ks, vs, ws = _band_shapes(L=128, ratio=2)
    ys = jax.ShapeDtypeStruct(qs.shape, F32)
    rs = jax.ShapeDtypeStruct(qs.shape[:3], F32)
    with capture() as got:
        jax.eval_shape(lambda *a: h1d_block.band_attention_fwd(
            *a, nr=16, mode="l0_bidir", tq=64), q, k, v, w)
        jax.eval_shape(lambda *a: h1d_block_bwd.band_attention_bwd(
            *a, nr=16, mode="l0_bidir", tq=64),
            q, k, v, w, y, r, r, y, r, r)
        jax.eval_shape(lambda *a: h1d_block.band_attention_fwd(
            *a, nr=16, mode="sub", ratio=2, tq=64), qs, ks, vs, ws)
        jax.eval_shape(lambda *a: h1d_block_bwd.band_attention_bwd(
            *a, nr=16, mode="sub", ratio=2, tq=64),
            qs, ks, vs, ws, ys, rs, rs, ys, rs, rs)
        check.decode_contracts(nr=4, d=8)

    fams = {c.family for c in got}
    assert {"band_fwd", "band_bwd", "sub_fwd", "sub_bwd",
            "decode_attend", "decode_update", "decode_attend_partial",
            "decode_update_partial", "decode_attend_paged",
            "decode_update_paged", "decode_attend_paged_quant",
            "decode_update_paged_quant"} <= fams
    assert len(recorded) == len(got)

    for kw, c in zip(recorded, got):
        if "grid_spec" in kw:
            gs = kw["grid_spec"]
            assert tuple(gs.grid) == c.grid, c.family
            assert gs.num_scalar_prefetch == len(c.scalars), c.family
            in_specs, out_specs = list(gs.in_specs), gs.out_specs
        else:
            assert tuple(kw["grid"]) == c.grid, c.family
            assert not c.scalars, c.family
            in_specs, out_specs = list(kw["in_specs"]), kw["out_specs"]
        if not isinstance(out_specs, (list, tuple)):
            out_specs = [out_specs]
        out_shape = kw["out_shape"]
        if not isinstance(out_shape, (list, tuple)):
            out_shape = [out_shape]
        assert len(in_specs) == len(c.inputs), c.family
        for spec, op in zip(in_specs, c.inputs):
            assert tuple(spec.block_shape) == op.block, c.family
            assert spec.index_map is op.index_map, c.family
        assert len(out_specs) == len(c.outputs) == len(out_shape), c.family
        for spec, sh, op in zip(out_specs, out_shape, c.outputs):
            assert tuple(spec.block_shape) == op.block, c.family
            assert spec.index_map is op.index_map, c.family
            assert tuple(sh.shape) == op.shape, c.family
            assert str(sh.dtype) == op.dtype, c.family
        want = {len(c.scalars) + i: o for i, o in c.aliases}
        assert dict(kw.get("input_output_aliases") or {}) == want, c.family


# ---------------------------------------------------------------------------
# VMEM model + static rejection in the autotune candidate path
# ---------------------------------------------------------------------------

def test_contract_vmem_bytes_synthetic():
    op = Operand("x", (4, 8), F32, (1, 8), lambda i: (i, 0))
    c = LaunchContract("t", (4,), (), (op,), (op,), (), {})
    # 2 operands x (1*8 elements x 4 bytes) x double-buffering
    assert vmem.contract_vmem_bytes(c) == 2 * 8 * 4 * vmem.DOUBLE_BUFFER


def test_band_launch_bytes_monotonic_in_tq():
    sizes = [vmem.band_launch_bytes("band_fwd", L=256, nr=16,
                                    mode="l0_causal", tq=t, d=16)
             for t in (16, 64, 256)]
    assert sizes[0] < sizes[1] < sizes[2]


def test_vmem_budget_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "12345")
    assert vmem.default_budget() == 12345
    monkeypatch.delenv("REPRO_VMEM_BUDGET")
    assert vmem.default_budget() == int(vmem.VMEM_BYTES
                                        * vmem.DEFAULT_FRACTION)


def test_vmem_rejection_is_static_and_logged(fresh_policy):
    """Over-budget candidates are dropped BEFORE measurement, logged as
    ``rejected:vmem`` with bytes + reason, and enumeration alone leaves
    the tuning tables (digest) untouched."""
    p = fresh_policy
    budget = vmem.band_launch_bytes("band_fwd", L=256, nr=16,
                                    mode="l0_causal", tq=128, d=16) - 1
    d0 = p.tuning_digest()
    cands = p.candidates("band_fwd", L=256, nr=16, mode="l0_causal",
                         d=16, vmem_budget=budget)
    assert [c["tq"] for c in cands] == [16, 32, 64]
    assert all(c["vmem_bytes"] <= budget for c in cands)
    rej = [e for e in p.decisions if e["source"] == "rejected:vmem"]
    assert [e["config"]["tq"] for e in rej] == [128, 256]
    for e in rej:
        assert e["config"]["vmem_bytes"] > budget
        assert "budget" in e["config"] and "reason" in e["config"]
    assert p.tuning_digest() == d0  # pure enumeration writes no tables

    measured = []

    def fake_measure(fn, iters=2, warmup=1):
        measured.append(fn)
        return float(len(measured))

    p._measure = fake_measure
    entry = p.autotune_band(L=256, nr=16, mode="l0_causal", d=16,
                            vmem_budget=budget)
    assert len(measured) == 3        # ONLY the surviving candidates ran
    assert entry["tq"] == 16         # fake timer: first candidate wins
    assert entry["vmem_bytes"] <= budget


def test_vmem_all_rejected_names_the_reason(fresh_policy):
    fresh_policy._measure = lambda fn, iters=2, warmup=1: 1.0
    with pytest.raises(AssertionError, match="rejected:vmem"):
        fresh_policy.autotune_band(L=64, nr=16, mode="l0_causal", d=16,
                                   vmem_budget=1)


# ---------------------------------------------------------------------------
# check CLI: --json report schema, --family filter, section selection
# ---------------------------------------------------------------------------

def _report(tmp_path, argv):
    import json
    path = tmp_path / "report.json"
    rc = check.main(argv + ["--json", str(path)])
    with open(path) as f:
        return rc, json.load(f)


def test_check_json_report_schema(tmp_path, capsys):
    """Pin the machine-readable report's schema: tooling diffs these
    across PRs, so a key rename must fail loudly here."""
    rc, rep = _report(tmp_path, ["--pool", "--pool-states", "400"])
    capsys.readouterr()
    assert rc == 0
    assert set(rep) == {"sections", "contracts", "families", "violations",
                        "dist", "pool", "ok", "runtime_s"}
    assert rep["sections"] == ["pool"]
    assert rep["contracts"] == 0 and rep["families"] == {}
    assert rep["violations"] == [] and rep["ok"] is True
    assert rep["dist"] is None
    assert isinstance(rep["runtime_s"], float)
    pool = rep["pool"]
    assert pool["states"] >= 400
    assert pool["transitions"] > pool["states"] // 2
    assert isinstance(pool["coverage"], dict) and pool["coverage"]
    assert "counterexample" not in pool        # only present on failure


def test_check_json_kernels_section(tmp_path, capsys):
    """Kernel runs populate contracts/families; violations (none on the
    committed kernels) carry label + the Violation dataclass fields."""
    rc, rep = _report(tmp_path, ["--kernels", "--nr", "4", "--d", "8",
                                 "--samples", "1",
                                 "--family", "decode_update"])
    capsys.readouterr()
    assert rc == 0
    assert rep["contracts"] > 0
    assert rep["families"] and all(f.startswith("decode_update")
                                   for f in rep["families"])
    assert rep["pool"] is None and rep["dist"] is None


def test_check_family_filters_contracts(capsys):
    """--family SUBSTR restricts the kernel sweep to matching labels or
    contract families (and the run still passes)."""
    assert check.main(["--nr", "4", "--d", "8", "--samples", "1",
                       "--family", "band_fwd"]) == 0
    out = capsys.readouterr().out
    assert "band_fwd" in out
    assert "decode" not in out


def test_check_cli_pool_section_stdout(capsys):
    assert check.main(["--pool", "--pool-states", "300"]) == 0
    out = capsys.readouterr().out
    assert "pool:" in out and "states" in out
    assert "checked" not in out            # kernel summary suppressed


# ---------------------------------------------------------------------------
# env-override hardening (REPRO_VMEM_BUDGET / REPRO_TUNE_CACHE)
# ---------------------------------------------------------------------------

def test_vmem_budget_malformed_env_warns_and_defaults(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET", "lots")
    with pytest.warns(RuntimeWarning, match="REPRO_VMEM_BUDGET"):
        got = vmem.default_budget()
    assert got == int(vmem.VMEM_BYTES * vmem.DEFAULT_FRACTION)


def test_tune_cache_malformed_env_warns_and_defaults(monkeypatch,
                                                     tmp_path):
    """A blank or NUL-bearing REPRO_TUNE_CACHE cannot be a cache dir:
    the policy must warn and fall back to the default path instead of
    crashing on first table save."""
    import os
    for bad in ("   ", "a\0b"):
        # NUL bytes cannot pass through putenv, so patch the mapping
        monkeypatch.setattr(os, "environ", {"REPRO_TUNE_CACHE": bad})
        with pytest.warns(RuntimeWarning, match="REPRO_TUNE_CACHE"):
            p = KernelPolicy()
        assert p.cache_dir == os.path.expanduser("~/.cache/repro_tune")
    # a usable path passes through silently
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p = KernelPolicy()
    assert p.cache_dir == str(tmp_path)


def test_save_table_bad_dir_degrades_gracefully(tmp_path):
    """An unusable cache_dir passed EXPLICITLY (bypassing the env
    sanitizer) must not crash tuning -- table persistence is best
    effort."""
    p = KernelPolicy(cache_dir="cache\0dir")
    p._tables["band_fwd"] = {"x": {"tq": 16}}
    with pytest.warns(RuntimeWarning, match="cannot persist"):
        assert p._save_table("band_fwd") is None   # kept in memory
