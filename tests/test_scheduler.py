"""Characterization tests for `serve/scheduler.py`: pin the victim
choice (LIFO over admit serials, exclusions honored) and the plan()
token-budget accounting (active slots pre-charge the budget, chunked
prefill charges the CHUNK, lookahead bounds the skip-ahead window)
that the preemption and parity tests depend on indirectly."""
import numpy as np
import pytest

from repro.serve import ContinuousBatchingScheduler, QueueEntry


def _entry(n, uid):
    return QueueEntry(req=uid, prompt=np.arange(n, dtype=np.int32))


def _bucket(s):
    return 1 << max(s - 1, 0).bit_length()


def _plan(sched, queue, free_slots=4, n_active=0, can=lambda e: True):
    groups, rest = sched.plan(queue, free_slots, n_active, _bucket, can)
    return ([[e.req for e in g.entries] for g in groups],
            [e.req for e in rest])


# ---------------------------------------------------------------------------
# choose_victim
# ---------------------------------------------------------------------------

def test_choose_victim_is_lifo_by_admit_serial():
    """The MOST RECENTLY admitted slot is preempted (slot ids do not
    matter, admission serials do): oldest work keeps its pages."""
    assert ContinuousBatchingScheduler.choose_victim(
        {0: 11, 1: 5, 2: 9}) == 0
    assert ContinuousBatchingScheduler.choose_victim(
        {3: 1, 1: 2}) == 1                    # serial wins, not slot id


def test_choose_victim_honors_exclusions():
    serial = {0: 3, 1: 2, 2: 1}
    assert ContinuousBatchingScheduler.choose_victim(
        serial, exclude=(0,)) == 1
    assert ContinuousBatchingScheduler.choose_victim(
        serial, exclude=(0, 1)) == 2
    assert ContinuousBatchingScheduler.choose_victim(
        serial, exclude=(0, 1, 2)) is None
    assert ContinuousBatchingScheduler.choose_victim({}) is None


# ---------------------------------------------------------------------------
# plan(): token-budget accounting
# ---------------------------------------------------------------------------

def test_budget_is_precharged_by_active_slots():
    """Every active slot costs one token of this tick's work BEFORE any
    admission: budget 10 with 6 decoding slots leaves 4, so a 5-token
    prompt no longer fits (it did with n_active=0)."""
    sched = ContinuousBatchingScheduler(token_budget=10)
    assert _plan(sched, [_entry(5, 0)], n_active=0)[0] == [[0]]
    assert _plan(sched, [_entry(5, 0)], n_active=6)[0] == []
    # exactly-fitting chunk is admitted (budget is >=, not >)
    assert _plan(sched, [_entry(4, 0)], n_active=6)[0] == [[0]]


def test_budget_never_goes_negative():
    """n_active beyond the budget clamps to zero rather than borrowing
    from future ticks -- only the anti-starvation pick can exceed it."""
    sched = ContinuousBatchingScheduler(token_budget=4)
    groups, rest = _plan(sched, [_entry(2, 0)], n_active=9)
    assert groups == [] and rest == [0]
    # idle engine (n_active=0): first pick admitted even over budget
    groups, _ = _plan(sched, [_entry(30, 0)], n_active=0)
    assert groups == [[0]]
    # ... but NOT when other work is already running this tick
    groups, rest = _plan(sched, [_entry(30, 0)], n_active=1)
    assert groups == [] and rest == [0]


def test_budget_spends_cumulatively_across_groups():
    """Each admission debits its chunk: 3+3 exhausts budget 7 after the
    second entry (leaving 1), so the third entry (cost 3) stays queued
    even though a slot is free."""
    sched = ContinuousBatchingScheduler(token_budget=7)
    groups, rest = _plan(
        sched, [_entry(3, 0), _entry(3, 1), _entry(3, 2)], free_slots=3)
    assert groups == [[0, 1]] and rest == [2]


def test_chunked_prefill_charges_the_chunk_not_the_prompt():
    """With prefill_chunk=4 a 30-token prompt costs 4 budget tokens and
    admits on its first 4 tokens only; the tail streams through decode
    ticks (engine-side), so budget 8 fits TWO long prompts."""
    sched = ContinuousBatchingScheduler(token_budget=8, prefill_chunk=4)
    queue = [_entry(30, 0), _entry(30, 1), _entry(30, 2)]
    groups, rest = sched.plan(queue, 4, 0, _bucket, lambda e: True)
    assert [[e.req for e in g.entries] for g in groups] == [[0, 1]]
    assert [e.req for e in rest] == [2]
    for g in groups:
        for c in g.chunks:
            assert len(c) == 4
        assert g.bucket == _bucket(4)
    # short prompts are charged their true length, not the chunk cap
    assert sched.chunk_len(3) == 3 and sched.chunk_len(30) == 4


def test_lookahead_bounds_the_skip_window():
    """An infeasible head may be jumped by at most `lookahead` later
    entries; entry lookahead+1 is out of the window even if feasible."""
    can = lambda e: len(e.prompt) < 10
    queue = lambda: [_entry(30, 0), _entry(40, 1), _entry(5, 2)]
    # lookahead=1: the feasible entry sits at index 2 -- unreachable
    sched = ContinuousBatchingScheduler(lookahead=1)
    groups, rest = _plan(sched, queue(), free_slots=1, can=can)
    assert groups == [] and rest == [0, 1, 2]
    # lookahead=2 reaches it; FIFO order of the skipped heads survives
    sched = ContinuousBatchingScheduler(lookahead=2)
    groups, rest = _plan(sched, queue(), free_slots=1, can=can)
    assert groups == [[2]] and rest == [0, 1]


def test_legacy_mode_groups_consecutive_same_bucket_only():
    """token_budget=None + lookahead=0 + no chunking is the dense parity
    oracle's schedule: pop the head, pull CONSECUTIVE same-bucket
    entries, never skip."""
    sched = ContinuousBatchingScheduler()
    queue = [_entry(5, 0), _entry(6, 1), _entry(20, 2), _entry(7, 3)]
    groups, rest = _plan(sched, queue, free_slots=4)
    # 5 and 6 share bucket 8; 20 breaks the run, 7 starts a new group
    assert groups == [[0, 1], [2], [3]] and rest == []
    # with lookahead, the same queue coalesces the split bucket
    sched = ContinuousBatchingScheduler(lookahead=2)
    groups, rest = _plan(sched, queue, free_slots=4)
    assert groups == [[0, 1, 3], [2]] and rest == []


def test_free_slots_cap_admissions():
    sched = ContinuousBatchingScheduler()
    queue = [_entry(5, i) for i in range(4)]
    groups, rest = _plan(sched, queue, free_slots=2)
    assert groups == [[0, 1]] and rest == [2, 3]
    groups, rest = _plan(sched, queue, free_slots=0)
    assert groups == [] and rest == [0, 1, 2, 3]


def test_can_admit_gates_every_pick():
    """The pool-availability probe rejects entries anywhere in a group,
    not just the head pick."""
    sched = ContinuousBatchingScheduler(lookahead=3)
    queue = [_entry(5, 0), _entry(6, 1), _entry(5, 2)]
    groups, rest = _plan(sched, queue, free_slots=3,
                         can=lambda e: e.req != 1)
    assert groups == [[0, 2]] and rest == [1]


def test_constructor_validation():
    with pytest.raises(ValueError, match="token_budget"):
        ContinuousBatchingScheduler(token_budget=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ContinuousBatchingScheduler(prefill_chunk=0)
