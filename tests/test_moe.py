"""MoE dispatch correctness: sort-based fixed-capacity routing vs a dense
oracle, load-balance loss, capacity behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.ffn import moe_init, moe_apply


def make_cfg(E=8, k=2, cf=8.0, **kw):
    return ModelConfig(d_model=16, moe_experts=E, moe_top_k=k,
                       moe_d_ff=32, moe_capacity_factor=cf, **kw)


def moe_dense_oracle(p, cfg, x):
    """Compute every expert for every token, combine with top-k weights."""
    gates = jax.nn.softmax(x.astype(jnp.float32) @ p["router"], -1)
    top_w, top_i = jax.lax.top_k(gates, cfg.moe_top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    act = jax.nn.silu
    h = act(jnp.einsum("bsd,edf->bsef", x, p["w1"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, p["w3"])
    y_all = jnp.einsum("bsef,efd->bsed", h, p["w2"])      # (B,S,E,d)
    onehot = jax.nn.one_hot(top_i, cfg.moe_experts)        # (B,S,k,E)
    w_e = jnp.einsum("bske,bsk->bse", onehot, top_w)
    return jnp.einsum("bsed,bse->bsd", y_all, w_e)


def test_moe_matches_dense_oracle_when_capacity_ample():
    cfg = make_cfg(cf=8.0)
    key = jax.random.PRNGKey(0)
    p, specs = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, 16))
    out, aux = moe_apply(p, cfg, x)
    ref = moe_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_gracefully():
    cfg = make_cfg(cf=0.25)           # tight capacity: tokens dropped
    key = jax.random.PRNGKey(1)
    p, _ = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 64, 16))
    out, _ = moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens give zero expert output, not garbage
    norm = jnp.linalg.norm(out, axis=-1)
    assert float(norm.min()) >= 0.0


def test_moe_aux_loss_balanced_vs_skewed():
    cfg = make_cfg(E=4, k=1, moe_aux_loss=1.0)
    key = jax.random.PRNGKey(2)
    p, _ = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 128, 16))
    # skew the router hard toward expert 0
    p_skew = dict(p)
    p_skew["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, aux_bal = moe_apply(p, cfg, x)
    _, aux_skew = moe_apply(p_skew, cfg, x)
    assert float(aux_skew) > float(aux_bal)


def test_moe_shared_and_residual_branches():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 16, 16))
    cfg_s = make_cfg(moe_shared_d_ff=32)
    p, _ = moe_init(key, cfg_s, jnp.float32)
    out_s, _ = moe_apply(p, cfg_s, x)
    assert "shared" in p
    cfg_r = make_cfg(moe_dense_residual=True, d_ff=32)
    p2, _ = moe_init(key, cfg_r, jnp.float32)
    out_r, _ = moe_apply(p2, cfg_r, x)
    assert "residual" in p2
    # residual branch contributes: zeroing it changes the output
    p3 = dict(p2)
    p3["residual"] = jax.tree.map(jnp.zeros_like, p2["residual"])
    out_r0, _ = moe_apply(p3, cfg_r, x)
    assert float(jnp.abs(out_r - out_r0).max()) > 1e-6


def test_moe_grads_flow_to_router_and_experts():
    cfg = make_cfg()
    key = jax.random.PRNGKey(4)
    p, _ = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 32, 16))

    def loss(p):
        out, aux = moe_apply(p, cfg, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w1"]).sum()) > 0
    assert float(jnp.abs(g["w2"]).sum()) > 0
