"""Incremental decode vs training-time attention: bit-level consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (h1d_attention, init_cache, prefill_cache, update_cache, decode_attend)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@pytest.mark.parametrize("L,nr", [(64, 8),
                                  pytest.param(128, 16, marks=pytest.mark.slow),
                                  pytest.param(256, 8, marks=pytest.mark.slow)])
def test_decode_matches_train_fine_q(L, nr):
    k1, k2, k3 = keys(3)
    B, G, D, Dv = 2, 2, 8, 8
    q = jax.random.normal(k1, (B, G, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, Dv))
    ztrain = h1d_attention(q, k, v, nr=nr, causal=True,
                           causal_mode="fine-q")
    cache = init_cache(B, L, D, Dv, nr)
    upd = jax.jit(update_cache)
    att = jax.jit(lambda c, qq, tt: decode_attend(c, qq, tt, nr=nr))
    outs = []
    for t in range(L):
        tt = jnp.full((B,), t, jnp.int32)
        cache = upd(cache, k[:, t], v[:, t], tt)
        outs.append(att(cache, q[:, :, t], tt))
    zdec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(zdec, ztrain, atol=2e-5, rtol=1e-4)


def test_prefill_then_decode_continuation():
    k1, k2, k3 = keys(3, seed=1)
    B, G, L, Lp, D, nr = 1, 1, 128, 100, 8, 8
    q = jax.random.normal(k1, (B, G, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, D))
    ztrain = h1d_attention(q, k, v, nr=nr, causal=True,
                           causal_mode="fine-q")
    cache = prefill_cache(k[:, :Lp], v[:, :Lp], L, nr)
    upd = jax.jit(update_cache)
    att = jax.jit(lambda c, qq, tt: decode_attend(c, qq, tt, nr=nr))
    outs = []
    for t in range(Lp, L):
        tt = jnp.full((B,), t, jnp.int32)
        cache = upd(cache, k[:, t], v[:, t], tt)
        outs.append(att(cache, q[:, :, t], tt))
    zdec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(zdec, ztrain[:, :, Lp:], atol=2e-5, rtol=1e-4)


def test_decode_per_row_positions():
    """Batch rows at different positions decode independently."""
    k1, k2, k3 = keys(3, seed=2)
    B, G, L, D, nr = 2, 1, 64, 4, 8
    q = jax.random.normal(k1, (B, G, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, D))
    ztrain = h1d_attention(q, k, v, nr=nr, causal=True,
                           causal_mode="fine-q")
    # row 0 at position 40, row 1 at position 63
    cache = prefill_cache(k, v, L, nr)   # caches hold the full K/V
    tt = jnp.array([40, 63], jnp.int32)
    qq = jnp.stack([q[0, :, 40], q[1, :, 63]], axis=0)
    z = decode_attend(cache, qq, tt, nr=nr)
    np.testing.assert_allclose(z[0], ztrain[0, :, 40], atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(z[1], ztrain[1, :, 63], atol=2e-5, rtol=1e-4)


@pytest.mark.slow
def test_attention_layer_decode_consistency():
    """Layer-level: attn_apply (teacher forcing) vs prefill+decode for the
    h1d, full and local cache paths.  Slow: the same layer glue runs in
    the default arch prefill/decode smokes and the serving tests."""
    from repro.models.common import ModelConfig
    from repro.models.attention import (attn_init, attn_apply, attn_decode,
                                        prefill_into_cache)
    B, S, Lmax = 2, 48, 64
    for attention, window in (("h1d", 0), ("full", 0), ("full", 16)):
        cfg = ModelConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                          d_model=32, attention=attention, nr=8,
                          sliding_window=window)
        layer_global = window == 0
        key = jax.random.PRNGKey(3)
        params, _ = attn_init(key, cfg, jnp.float32)
        x = jax.random.normal(key, (B, S, 32))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        full = attn_apply(params, cfg, x, pos, causal=True,
                          layer_global=layer_global)
        out_p, cache = prefill_into_cache(params, cfg, x[:, :S - 8],
                                          pos[:, :S - 8], Lmax,
                                          layer_global=layer_global)
        np.testing.assert_allclose(out_p, full[:, :S - 8], atol=2e-4,
                                   rtol=1e-3)
        for t in range(S - 8, S):
            tt = jnp.full((B,), t, jnp.int32)
            out_d, cache = attn_decode(params, cfg, x[:, t:t + 1], tt,
                                       cache, layer_global=layer_global)
            if attention == "h1d" or (attention == "full" and layer_global):
                # h1d fine-q and full attention are decode-consistent;
                # local layers use per-token windows at decode vs
                # block-local at train (documented approximation).
                np.testing.assert_allclose(out_d[:, 0], full[:, t],
                                           atol=2e-4, rtol=1e-3)
