"""KernelPolicy launch-policy layer: impl enum validation, resolve_tq
error paths, table fallback on corrupt/stale/foreign files, cache hits
skipping re-measurement, the measured autotune round-trip, and
``impl='auto'`` numerical parity across the band / decode / serve
surfaces."""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import tuning
from repro.kernels.tuning import (KernelPolicy, IMPLS, canonical_impl, set_policy, resolve_tq, table_key)


@pytest.fixture
def fresh_policy(tmp_path):
    """A policy with an isolated on-disk cache, installed as the process
    policy for the duration of the test."""
    p = KernelPolicy(cache_dir=str(tmp_path))
    prev = set_policy(p)
    yield p
    set_policy(prev)


def _band_inputs(L=64, nr=16, d=16, ratio=1, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    Lk = L // ratio
    q = jax.random.normal(ks[0], (1, 2, L, d))
    k = jax.random.normal(ks[1], (1, Lk, d))
    v = jax.random.normal(ks[2], (1, Lk, d))
    w = jnp.ones((1, Lk))
    return q, k, v, w


# ---------------------------------------------------------------------------
# satellite 1: canonical impl enum
# ---------------------------------------------------------------------------

def test_unknown_impl_raises_with_allowed_set():
    with pytest.raises(ValueError, match="allowed impls"):
        canonical_impl("pallas_interp")  # typo'd string must not fall through
    q, k, v, w = _band_inputs()
    with pytest.raises(ValueError, match="allowed impls"):
        ops.band_attention(q, k, v, w, nr=16, mode="l0_bidir", impl="triton")


def test_every_canonical_impl_accepted():
    for impl in IMPLS:
        assert canonical_impl(impl) == impl


# ---------------------------------------------------------------------------
# satellite 2: resolve_tq error paths name mode/ratio
# ---------------------------------------------------------------------------

def test_resolve_tq_L_not_multiple_of_nr():
    with pytest.raises(ValueError,
                       match=r"mode=coarse_causal, ratio=1.*L=100.*nr=16"):
        resolve_tq(100, 16, 128, "coarse_causal")


def test_resolve_tq_hint_below_nr():
    with pytest.raises(ValueError, match=r"mode=sub, ratio=4.*tq hint 8"):
        resolve_tq(64, 16, 8, "sub", ratio=4)


def test_resolve_tq_legalizes_hint():
    # hint larger than L shrinks; non-dividing hint drops to a divisor
    assert resolve_tq(64, 16, 512, "l0_bidir") == 64
    assert resolve_tq(96, 16, 64, "l0_causal") == 48
    assert resolve_tq(128, 16, 128, "sub", ratio=2) == 128


# ---------------------------------------------------------------------------
# table loading: corrupt / version-mismatch / foreign-backend files
# ---------------------------------------------------------------------------

def _write_table(policy, family, text=None, payload=None):
    path = policy._table_path(family)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text if text is not None else json.dumps(payload))
    return path


def test_corrupt_table_warns_and_uses_default(fresh_policy):
    _write_table(fresh_policy, "band_fwd", text="{not json!")
    with pytest.warns(RuntimeWarning, match="corrupt tuning table"):
        tq = fresh_policy.band_tq(L=64, nr=16, mode="l0_bidir")
    assert tq == 128  # committed default, not a crash
    assert fresh_policy.decisions[-1]["source"] == "default"


def test_version_mismatch_warns_and_uses_default(fresh_policy):
    key = table_key(64, 16, "l0_bidir")
    _write_table(fresh_policy, "band_fwd", payload={
        "version": 999, "backend": fresh_policy.backend,
        "kernel": "band_fwd", "entries": {key: {"tq": 16}}})
    with pytest.warns(RuntimeWarning, match="version"):
        tq = fresh_policy.band_tq(L=64, nr=16, mode="l0_bidir")
    assert tq == 128  # stale table's tq=16 must NOT apply


def test_foreign_backend_table_warns_and_uses_default(fresh_policy):
    key = table_key(64, 16, "l0_bidir")
    _write_table(fresh_policy, "band_fwd", payload={
        "version": tuning.TABLE_VERSION, "backend": "not-a-backend",
        "kernel": "band_fwd", "entries": {key: {"tq": 16}}})
    with pytest.warns(RuntimeWarning, match="backend"):
        assert fresh_policy.band_tq(L=64, nr=16, mode="l0_bidir") == 128


def test_valid_table_entry_wins_over_default(fresh_policy):
    key = table_key(64, 16, "l0_bidir")
    _write_table(fresh_policy, "band_fwd", payload={
        "version": tuning.TABLE_VERSION, "backend": fresh_policy.backend,
        "kernel": "band_fwd", "entries": {key: {"tq": 32}}})
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a valid table must not warn
        assert fresh_policy.band_tq(L=64, nr=16, mode="l0_bidir") == 32
    assert fresh_policy.decisions[-1]["source"] == "table"


def test_override_bypasses_table(fresh_policy):
    key = table_key(64, 16, "l0_bidir")
    _write_table(fresh_policy, "band_fwd", payload={
        "version": tuning.TABLE_VERSION, "backend": fresh_policy.backend,
        "kernel": "band_fwd", "entries": {key: {"tq": 32}}})
    assert fresh_policy.band_tq(L=64, nr=16, mode="l0_bidir",
                                override=64) == 64
    assert fresh_policy.decisions[-1]["source"] == "override"


# ---------------------------------------------------------------------------
# satellite 3a: cache hit avoids re-measurement
# ---------------------------------------------------------------------------

def test_autotune_cache_hit_skips_measurement(fresh_policy, monkeypatch):
    calls = {"n": 0}
    real = KernelPolicy._measure

    def counting(self, fn, **kw):
        calls["n"] += 1
        return real(self, fn, iters=1, warmup=1)

    monkeypatch.setattr(KernelPolicy, "_measure", counting)
    e1 = fresh_policy.autotune_band(L=64, nr=16, mode="l0_causal", d=8)
    assert calls["n"] > 0 and e1["source"] == "measured"
    n_first = calls["n"]

    # same policy, same shape bucket: in-memory table hit, zero measures
    e2 = fresh_policy.autotune_band(L=64, nr=16, mode="l0_causal", d=8)
    assert calls["n"] == n_first and e2["tq"] == e1["tq"]

    # fresh policy over the same cache dir: on-disk hit, zero measures
    p2 = KernelPolicy(cache_dir=fresh_policy.cache_dir)
    e3 = p2.autotune_band(L=64, nr=16, mode="l0_causal", d=8)
    assert calls["n"] == n_first and e3["tq"] == e1["tq"]
    assert p2.decisions[-1]["source"] == "table"


def test_autotune_round_trip_applies_measured_config(fresh_policy):
    """Autotune writes a table; a fresh policy reloads it and a real
    band_attention launch applies the measured tq (decision log)."""
    entry = fresh_policy.autotune_band(L=64, nr=16, mode="l0_bidir", d=8)
    path = fresh_policy._table_path("band_fwd")
    assert os.path.exists(path)
    with open(path) as f:
        table = json.load(f)
    assert table["version"] == tuning.TABLE_VERSION
    assert table["backend"] == fresh_policy.backend
    key = table_key(64, 16, "l0_bidir")
    assert table["entries"][key]["tq"] == entry["tq"]
    assert table["entries"][key]["source"] == "measured"

    p2 = KernelPolicy(cache_dir=fresh_policy.cache_dir)
    prev = set_policy(p2)
    try:
        q, k, v, w = _band_inputs(d=8)
        ops.band_attention(q, k, v, w, nr=16, mode="l0_bidir",
                           impl="pallas_interpret")
        dec = [d for d in p2.decisions if d["family"] == "band_fwd"]
        assert dec and dec[-1]["source"] == "table"
        assert dec[-1]["config"]["tq"] == entry["tq"]
    finally:
        set_policy(prev)


def test_tuning_digest_tracks_tables(fresh_policy):
    d0 = fresh_policy.tuning_digest()
    assert len(d0) == 12 and int(d0, 16) >= 0
    fresh_policy.autotune_band(L=64, nr=16, mode="sub", ratio=2, d=8)
    p2 = KernelPolicy(cache_dir=fresh_policy.cache_dir)
    assert p2.tuning_digest() != d0  # new table changes the digest


def test_candidates_enumeration(fresh_policy):
    cands = fresh_policy.candidates("band_fwd", L=256, nr=16,
                                    mode="l0_bidir")
    assert [c["tq"] for c in cands] == [16, 32, 64, 128, 256]
    sub = fresh_policy.candidates("sub_fwd", L=256, nr=16, mode="sub",
                                  ratio=8)
    assert {c["tq"]: c["layout"] for c in sub} == {
        16: "deep", 32: "deep", 64: "deep", 128: "wide", 256: "wide"}
    dec = fresh_policy.candidates("decode_attend", L=0, nr=16, rows=7)
    assert dec == [{"grid": (7,)}]
    with pytest.raises(ValueError, match="allowed families"):
        fresh_policy.candidates("nope", L=64, nr=16)


# ---------------------------------------------------------------------------
# decision log: bounded size; cache persistence degrades gracefully
# ---------------------------------------------------------------------------

def test_decision_log_bounded(fresh_policy):
    """The decision log is a bounded deque: old entries fall off instead
    of growing without limit in a long-lived serving process."""
    p = fresh_policy
    assert p.decisions.maxlen == 512
    for i in range(700):
        p._log("band_fwd", f"k{i}", "default", {"tq": 128})
    assert len(p.decisions) == 512
    assert p.decisions[0]["key"] == "k188"   # oldest 188 evicted
    assert p.decisions[-1]["key"] == "k699"


def test_unwritable_cache_degrades_to_memory(tmp_path):
    """An unwritable $REPRO_TUNE_CACHE must not abort the autotune
    sweep: RuntimeWarning + in-memory tables, measured entry reused."""
    blocker = tmp_path / "blocker"
    blocker.write_text("a file where the cache dir should be")
    p = KernelPolicy(cache_dir=str(blocker / "cache"))
    p._measure = lambda fn, iters=2, warmup=1: 1.0
    with pytest.warns(RuntimeWarning, match="in memory"):
        entry = p.autotune_band(L=64, nr=16, mode="l0_causal", d=8)
    assert entry["source"] == "measured"
    assert not os.path.exists(p._table_path("band_fwd"))
    # the measured entry survives in the in-memory table for this process
    assert p.band_tq(L=64, nr=16, mode="l0_causal") == entry["tq"]
    assert p._entries("band_fwd")[table_key(64, 16, "l0_causal")]["tq"] \
        == entry["tq"]


# ---------------------------------------------------------------------------
# satellite 3b: impl='auto' parity across the band modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,ratio", [("l0_bidir", 1), ("l0_causal", 1),
                                        ("coarse_bidir", 1),
                                        ("coarse_causal", 1),
                                        ("sub", 2), ("sub", 8)])
def test_auto_matches_interpret_band(fresh_policy, mode, ratio):
    q, k, v, w = _band_inputs(L=128, nr=16, d=16, ratio=ratio)
    ref = ops.band_attention(q, k, v, w, nr=16, mode=mode, ratio=ratio,
                             impl="pallas_interpret")
    out = ops.band_attention(q, k, v, w, nr=16, mode=mode, ratio=ratio,
                             impl="auto")
    for a, b in zip(out, ref):
        assert float(jnp.abs(a - b).max()) <= 1e-5
    # 'auto' resolution itself must be in the decision log
    srcs = [d for d in fresh_policy.decisions if d["source"] == "auto"]
    assert srcs and srcs[-1]["key"].startswith("impl@")


def test_auto_grad_matches_interpret(fresh_policy):
    from repro.core import h1d_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 16))
    k = jax.random.normal(ks[1], (1, 64, 16))
    v = jax.random.normal(ks[2], (1, 64, 16))

    def loss(impl):
        def f(q, k, v):
            return jnp.sum(h1d_attention(q, k, v, nr=16, causal=True,
                                         impl=impl) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))

    g_auto = loss("auto")(q, k, v)
    g_ref = loss("pallas_interpret")(q, k, v)
    for a, b in zip(g_auto, g_ref):
        assert float(jnp.abs(a - b).max()) <= 1e-5


def test_auto_decode_parity(fresh_policy):
    from repro.core import h1d_decode as hd
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    R, Lmax, D, G, nr = 3, 64, 16, 2, 16
    cache = hd.prefill_cache(jax.random.normal(ks[0], (R, Lmax, D)),
                             jax.random.normal(ks[1], (R, Lmax, D)),
                             Lmax, nr)
    q = jax.random.normal(ks[2], (R, G, D))
    kn = jax.random.normal(ks[3], (R, D))
    vn = jax.random.normal(ks[4], (R, D))
    t = jnp.asarray([nr, 33, 48], dtype=jnp.int32)

    c_auto = hd.update_cache(cache, kn, vn, t, impl="auto")
    c_jnp = hd.update_cache(cache, kn, vn, t, impl="jnp")
    for a, b in zip(jax.tree.leaves(c_auto), jax.tree.leaves(c_jnp)):
        assert float(jnp.abs(a - b).max()) == 0.0  # bit-exact cache update

    z_auto = hd.decode_attend(c_auto, q, t, nr=nr, impl="auto")
    z_ref = hd.decode_attend(c_jnp, q, t, nr=nr, impl="pallas_interpret")
    assert float(jnp.abs(z_auto - z_ref).max()) <= 1e-5
    fams = {d["family"] for d in fresh_policy.decisions}
    assert {"decode_update", "decode_attend"} <= fams


def test_auto_paged_serve_matches_jnp(fresh_policy):
    """decode_impl='auto' through the whole paged engine: same greedy
    tokens as the jnp oracle."""
    from test_paged import _model, _workload, _run
    cfg, _ = _model()
    wl = _workload(11, 4, cfg)
    ref = _run(wl, slots=2, decode_impl="jnp")[1]
    got = _run(wl, slots=2, decode_impl="auto", paged=True)[1]
    assert got == ref


def test_serve_engine_rejects_unknown_impl():
    from test_paged import _model
    from repro.serve import ServeEngine
    cfg, params = _model()
    with pytest.raises(ValueError, match="allowed impls"):
        ServeEngine(cfg, params, max_len=64, decode_impl="tritn")


def test_model_config_auto_attn_impl(fresh_policy):
    """attn_impl='auto' end to end through attn_apply (tq from policy)."""
    import dataclasses
    from test_paged import _model
    from repro.models import get_model
    cfg, params = _model()
    fns = get_model(cfg)
    toks = jnp.asarray(np.arange(24, dtype=np.int32)[None, :] % cfg.vocab_size)
    batch = {"tokens": toks}
    ref = fns.prefill(params, dataclasses.replace(cfg, attn_impl="jnp"),
                      batch, 32)[0]
    out = fns.prefill(params, dataclasses.replace(cfg, attn_impl="auto"),
                      batch, 32)[0]
    assert float(jnp.abs(out - ref).max()) <= 1e-4
