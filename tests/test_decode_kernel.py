"""Fused Pallas decode kernels (`kernels/h1d_decode_kernel`) vs the jnp
oracle in `core/h1d_decode` -- interpret mode executes the exact kernel
bodies on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import h1d_attention, h1d_decode as hd

IMPL = "pallas_interpret"


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _cache(B, Lmax, D, Dv, nr, seed=0):
    k1, k2 = _keys(2, seed)
    k = jax.random.normal(k1, (B, Lmax, D))
    v = jax.random.normal(k2, (B, Lmax, Dv))
    return hd.prefill_cache(k, v, Lmax, nr)


def _interesting_ts(Lmax, nr, n_extra=4, seed=0):
    """Positions covering the mask edge cases: first block (t < nr),
    block boundaries, top-level span boundaries and half-span quadrant
    flips, the last position, plus random fill."""
    M = hd.hc.num_levels(Lmax, nr)
    span = nr << max(M - 1, 1)
    ts = [0, 1, nr - 1, nr, 2 * nr - 1,
          span - 1, span, span + span // 2 - 1, span + span // 2,
          Lmax - 1]
    rng = np.random.default_rng(seed)
    ts += list(rng.integers(0, Lmax, size=n_extra))
    return np.array(sorted({int(t) % Lmax for t in ts}), np.int32)


@pytest.mark.parametrize("Lmax,nr,G", [
    (256, 16, 1), (256, 8, 4), (512, 16, 2),
    pytest.param(1024, 16, 2, marks=pytest.mark.slow)])
def test_attend_parity_sweep(Lmax, nr, G):
    """Per-row random/boundary positions, incl. GQA groups G > 1."""
    ts = _interesting_ts(Lmax, nr)
    B, D, Dv = len(ts), 16, 16
    cache = _cache(B, Lmax, D, Dv, nr, seed=Lmax + nr)
    q = jax.random.normal(_keys(1, seed=1)[0], (B, G, D))
    t = jnp.asarray(ts)
    z_ref = hd.decode_attend(cache, q, t, nr=nr)
    z_ker = jax.jit(lambda c, qq, tt: hd.decode_attend(
        c, qq, tt, nr=nr, impl=IMPL))(cache, q, t)
    np.testing.assert_allclose(z_ker, z_ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("Lmax,nr", [
    (256, 16), (512, 16),
    pytest.param(1024, 16, marks=pytest.mark.slow)])
def test_update_parity_sequential(Lmax, nr):
    """Fused ancestor update == vmap'd oracle, bit-exact, including the
    chained dependency across several sequential writes."""
    B, D, Dv = 4, 16, 8
    c_ref = _cache(B, Lmax, D, Dv, nr, seed=2)
    c_ker = c_ref
    rng = np.random.default_rng(3)
    upd = jax.jit(lambda c, kn, vn, tt: hd.update_cache(
        c, kn, vn, tt, impl=IMPL))
    for step in range(3):
        kk = _keys(2, seed=10 + step)
        kn = jax.random.normal(kk[0], (B, D))
        vn = jax.random.normal(kk[1], (B, Dv))
        t = jnp.asarray(rng.integers(0, Lmax, size=B).astype(np.int32))
        c_ref = hd.update_cache(c_ref, kn, vn, t)
        c_ker = upd(c_ker, kn, vn, t)
        for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uniform_scalar_t_specialization():
    """decode_attend_uniform / update_cache_uniform on the kernel path
    (scalar t broadcast per row) match their jnp oracles."""
    B, G, Lmax, D, nr = 3, 2, 128, 16, 16
    cache = _cache(B, Lmax, D, D, nr, seed=4)
    q = jax.random.normal(_keys(1, seed=5)[0], (B, G, D))
    for t in (0, 70, 127):
        t = jnp.int32(t)
        z_ref = hd.decode_attend_uniform(cache, q, t, nr=nr)
        z_ker = hd.decode_attend_uniform(cache, q, t, nr=nr, impl=IMPL)
        np.testing.assert_allclose(z_ker, z_ref, atol=1e-5, rtol=1e-5)
    kk = _keys(2, seed=6)
    kn = jax.random.normal(kk[0], (B, D))
    vn = jax.random.normal(kk[1], (B, D))
    c_ref = hd.update_cache_uniform(cache, kn, vn, jnp.int32(70))
    c_ker = hd.update_cache_uniform(cache, kn, vn, jnp.int32(70), impl=IMPL)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_decode_matches_train_fine_q():
    """Full incremental loop on the kernel path (update + attend fused)
    reproduces training-time fine-q attention."""
    L, nr, B, G, D = 64, 8, 2, 2, 8
    k1, k2, k3 = _keys(3, seed=7)
    q = jax.random.normal(k1, (B, G, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, D))
    ztrain = h1d_attention(q, k, v, nr=nr, causal=True, causal_mode="fine-q")
    cache = hd.init_cache(B, L, D, D, nr)
    upd = jax.jit(lambda c, kn, vn, tt: hd.update_cache(
        c, kn, vn, tt, impl=IMPL))
    att = jax.jit(lambda c, qq, tt: hd.decode_attend(
        c, qq, tt, nr=nr, impl=IMPL))
    outs = []
    for t in range(L):
        tt = jnp.full((B,), t, jnp.int32)
        cache = upd(cache, k[:, t], v[:, t], tt)
        outs.append(att(cache, q[:, :, t], tt))
    zdec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(zdec, ztrain, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("B", [1, pytest.param(2, marks=pytest.mark.slow)])
def test_attn_decode_layer_kernel_path(B):
    """Layer-level attn_decode with cfg.decode_impl='pallas_interpret'
    matches the jnp decode path (B=1 uniform by default; the batched
    per-row-t layer path is the slow variant -- the kernel itself is
    per-row either way and swept in test_attend_parity_sweep)."""
    import dataclasses
    from repro.models.common import ModelConfig
    from repro.models.attention import attn_init, attn_decode, \
        prefill_into_cache
    cfg = ModelConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                      d_model=32, attention="h1d", nr=8)
    kcfg = dataclasses.replace(cfg, decode_impl=IMPL)
    key = jax.random.PRNGKey(8)
    params, _ = attn_init(key, cfg, jnp.float32)
    S, Lmax = 24, 32
    x = jax.random.normal(key, (B, S + 1, 32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, cache = prefill_into_cache(params, cfg, x[:, :S], pos, Lmax)
    tt = jnp.full((B,), S, jnp.int32)
    out_j, cache_j = attn_decode(params, cfg, x[:, S:S + 1], tt, cache)
    out_k, cache_k = attn_decode(params, kcfg, x[:, S:S + 1], tt, cache)
    np.testing.assert_allclose(out_k, out_j, atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_j), jax.tree.leaves(cache_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# paged (page-table-indexed) kernel variants
# ---------------------------------------------------------------------------

def _identity_paged(dense, R, Lmax, nr):
    """Carve a dense cache into an identity-mapped pool: page
    ``r * nblocks_l + j`` holds row-r's level-l block j, so paged
    results must equal the dense cache's exactly."""
    M = hd.hc.num_levels(Lmax, nr)
    nbl = [(Lmax >> l) // nr for l in range(M)]
    D, Dv = dense.k.shape[-1], dense.v.shape[-1]
    pool = hd.PagedH1DCache(
        k=dense.k.reshape(R * nbl[0], nr, D),
        v=dense.v.reshape(R * nbl[0], nr, Dv),
        ck=tuple(a.reshape(R * nbl[l + 1], nr, D)
                 for l, a in enumerate(dense.ck)),
        cv=tuple(a.reshape(R * nbl[l + 1], nr, Dv)
                 for l, a in enumerate(dense.cv)))
    return pool, nbl


def _identity_tables(ts, nbl, nr, M):
    R = len(ts)
    bidx = np.zeros((R, 2 + (M - 1)), np.int32)
    utab = np.zeros((R, M), np.int32)
    for r, t in enumerate(ts):
        b0 = t // nr
        bidx[r, 0] = r * nbl[0] + b0
        bidx[r, 1] = r * nbl[0] + max(b0 - 1, 0)
        for l in range(1, M):
            bidx[r, 1 + l] = r * nbl[l] + max(t // (nr << l) - 1, 0)
        for l in range(M):
            utab[r, l] = r * nbl[l] + (t >> l) // nr
    return jnp.asarray(bidx), jnp.asarray(utab)


@pytest.mark.parametrize("Lmax,nr,G", [(256, 16, 1), (128, 8, 4)])
def test_paged_attend_parity(Lmax, nr, G):
    """decode_attend_paged (jnp oracle AND fused kernel) == the dense
    decode_attend on an identity page layout, incl. boundary/quadrant
    positions and GQA groups."""
    ts = _interesting_ts(Lmax, nr)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=Lmax)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    M = hd.hc.num_levels(Lmax, nr)
    bidx, _ = _identity_tables(ts, nbl, nr, M)
    q = jax.random.normal(_keys(1, seed=2)[0], (R, G, D))
    t = jnp.asarray(ts)
    z_dense = hd.decode_attend(cache, q, t, nr=nr)
    z_jnp = hd.decode_attend_paged(pool, q, t, bidx, nr=nr)
    np.testing.assert_array_equal(np.asarray(z_jnp), np.asarray(z_dense))
    z_ker = jax.jit(lambda p, qq, tt, bb: hd.decode_attend_paged(
        p, qq, tt, bb, nr=nr, impl=IMPL))(pool, q, t, bidx)
    np.testing.assert_allclose(z_ker, z_dense, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("Lmax,nr", [(256, 16), (128, 8)])
def test_paged_update_parity_bit_exact(Lmax, nr):
    """update_cache_paged must be BIT-exact against the dense ancestor
    update (jnp oracle and fused kernel), including chained sequential
    writes through the carried pair mean/sum."""
    ts = _interesting_ts(Lmax, nr, n_extra=2)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=nr)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    M = hd.hc.num_levels(Lmax, nr)
    k1, k2 = _keys(2, seed=5)
    t = jnp.asarray(ts)
    for step in range(3):          # chained writes t, t+1, t+2
        tt = jnp.minimum(t + step, Lmax - 1)
        _, utab = _identity_tables(np.asarray(tt), nbl, nr, M)
        kn = jax.random.normal(jax.random.fold_in(k1, step), (R, D))
        vn = jax.random.normal(jax.random.fold_in(k2, step), (R, D))
        cache = hd.update_cache(cache, kn, vn, tt)
        pool_j = hd.update_cache_paged(pool, kn, vn, tt, utab)
        pool_k = jax.jit(lambda p, a, b, c, u: hd.update_cache_paged(
            p, a, b, c, u, impl=IMPL))(pool, kn, vn, tt, utab)
        for a, b in zip(jax.tree.leaves(pool_j), jax.tree.leaves(pool_k)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        pool = pool_j
        flat, _ = _identity_paged(cache, R, Lmax, nr)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(pool)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
