"""Fused Pallas decode kernels (`kernels/h1d_decode_kernel`) vs the jnp
oracle in `core/h1d_decode` -- interpret mode executes the exact kernel
bodies on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import h1d_attention, h1d_decode as hd
from repro.core import quantization as qz

IMPL = "pallas_interpret"


def _keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _cache(B, Lmax, D, Dv, nr, seed=0):
    k1, k2 = _keys(2, seed)
    k = jax.random.normal(k1, (B, Lmax, D))
    v = jax.random.normal(k2, (B, Lmax, Dv))
    return hd.prefill_cache(k, v, Lmax, nr)


def _interesting_ts(Lmax, nr, n_extra=4, seed=0):
    """Positions covering the mask edge cases: first block (t < nr),
    block boundaries, top-level span boundaries and half-span quadrant
    flips, the last position, plus random fill."""
    M = hd.hc.num_levels(Lmax, nr)
    span = nr << max(M - 1, 1)
    ts = [0, 1, nr - 1, nr, 2 * nr - 1,
          span - 1, span, span + span // 2 - 1, span + span // 2,
          Lmax - 1]
    rng = np.random.default_rng(seed)
    ts += list(rng.integers(0, Lmax, size=n_extra))
    return np.array(sorted({int(t) % Lmax for t in ts}), np.int32)


@pytest.mark.parametrize("Lmax,nr,G", [
    (256, 16, 1), (256, 8, 4), (512, 16, 2),
    pytest.param(1024, 16, 2, marks=pytest.mark.slow)])
def test_attend_parity_sweep(Lmax, nr, G):
    """Per-row random/boundary positions, incl. GQA groups G > 1."""
    ts = _interesting_ts(Lmax, nr)
    B, D, Dv = len(ts), 16, 16
    cache = _cache(B, Lmax, D, Dv, nr, seed=Lmax + nr)
    q = jax.random.normal(_keys(1, seed=1)[0], (B, G, D))
    t = jnp.asarray(ts)
    z_ref = hd.decode_attend(cache, q, t, nr=nr)
    z_ker = jax.jit(lambda c, qq, tt: hd.decode_attend(
        c, qq, tt, nr=nr, impl=IMPL))(cache, q, t)
    np.testing.assert_allclose(z_ker, z_ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("Lmax,nr", [
    (256, 16), (512, 16),
    pytest.param(1024, 16, marks=pytest.mark.slow)])
def test_update_parity_sequential(Lmax, nr):
    """Fused ancestor update == vmap'd oracle, bit-exact, including the
    chained dependency across several sequential writes."""
    B, D, Dv = 4, 16, 8
    c_ref = _cache(B, Lmax, D, Dv, nr, seed=2)
    c_ker = c_ref
    rng = np.random.default_rng(3)
    upd = jax.jit(lambda c, kn, vn, tt: hd.update_cache(
        c, kn, vn, tt, impl=IMPL))
    for step in range(3):
        kk = _keys(2, seed=10 + step)
        kn = jax.random.normal(kk[0], (B, D))
        vn = jax.random.normal(kk[1], (B, Dv))
        t = jnp.asarray(rng.integers(0, Lmax, size=B).astype(np.int32))
        c_ref = hd.update_cache(c_ref, kn, vn, t)
        c_ker = upd(c_ker, kn, vn, t)
        for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uniform_scalar_t_specialization():
    """decode_attend_uniform / update_cache_uniform on the kernel path
    (scalar t broadcast per row) match their jnp oracles."""
    B, G, Lmax, D, nr = 3, 2, 128, 16, 16
    cache = _cache(B, Lmax, D, D, nr, seed=4)
    q = jax.random.normal(_keys(1, seed=5)[0], (B, G, D))
    for t in (0, 70, 127):
        t = jnp.int32(t)
        z_ref = hd.decode_attend_uniform(cache, q, t, nr=nr)
        z_ker = hd.decode_attend_uniform(cache, q, t, nr=nr, impl=IMPL)
        np.testing.assert_allclose(z_ker, z_ref, atol=1e-5, rtol=1e-5)
    kk = _keys(2, seed=6)
    kn = jax.random.normal(kk[0], (B, D))
    vn = jax.random.normal(kk[1], (B, D))
    c_ref = hd.update_cache_uniform(cache, kn, vn, jnp.int32(70))
    c_ker = hd.update_cache_uniform(cache, kn, vn, jnp.int32(70), impl=IMPL)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_decode_matches_train_fine_q():
    """Full incremental loop on the kernel path (update + attend fused)
    reproduces training-time fine-q attention."""
    L, nr, B, G, D = 64, 8, 2, 2, 8
    k1, k2, k3 = _keys(3, seed=7)
    q = jax.random.normal(k1, (B, G, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, D))
    ztrain = h1d_attention(q, k, v, nr=nr, causal=True, causal_mode="fine-q")
    cache = hd.init_cache(B, L, D, D, nr)
    upd = jax.jit(lambda c, kn, vn, tt: hd.update_cache(
        c, kn, vn, tt, impl=IMPL))
    att = jax.jit(lambda c, qq, tt: hd.decode_attend(
        c, qq, tt, nr=nr, impl=IMPL))
    outs = []
    for t in range(L):
        tt = jnp.full((B,), t, jnp.int32)
        cache = upd(cache, k[:, t], v[:, t], tt)
        outs.append(att(cache, q[:, :, t], tt))
    zdec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(zdec, ztrain, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("B", [1, pytest.param(2, marks=pytest.mark.slow)])
def test_attn_decode_layer_kernel_path(B):
    """Layer-level attn_decode with cfg.decode_impl='pallas_interpret'
    matches the jnp decode path (B=1 uniform by default; the batched
    per-row-t layer path is the slow variant -- the kernel itself is
    per-row either way and swept in test_attend_parity_sweep)."""
    import dataclasses
    from repro.models.common import ModelConfig
    from repro.models.attention import attn_init, attn_decode, \
        prefill_into_cache
    cfg = ModelConfig(num_heads=4, num_kv_heads=2, head_dim=8,
                      d_model=32, attention="h1d", nr=8)
    kcfg = dataclasses.replace(cfg, decode_impl=IMPL)
    key = jax.random.PRNGKey(8)
    params, _ = attn_init(key, cfg, jnp.float32)
    S, Lmax = 24, 32
    x = jax.random.normal(key, (B, S + 1, 32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    _, cache = prefill_into_cache(params, cfg, x[:, :S], pos, Lmax)
    tt = jnp.full((B,), S, jnp.int32)
    out_j, cache_j = attn_decode(params, cfg, x[:, S:S + 1], tt, cache)
    out_k, cache_k = attn_decode(params, kcfg, x[:, S:S + 1], tt, cache)
    np.testing.assert_allclose(out_k, out_j, atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_j), jax.tree.leaves(cache_k)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# paged (page-table-indexed) kernel variants
# ---------------------------------------------------------------------------

def _identity_paged(dense, R, Lmax, nr):
    """Carve a dense cache into an identity-mapped pool: page
    ``r * nblocks_l + j`` holds row-r's level-l block j, so paged
    results must equal the dense cache's exactly."""
    M = hd.hc.num_levels(Lmax, nr)
    nbl = [(Lmax >> l) // nr for l in range(M)]
    D, Dv = dense.k.shape[-1], dense.v.shape[-1]
    pool = hd.PagedH1DCache(
        k=dense.k.reshape(R * nbl[0], nr, D),
        v=dense.v.reshape(R * nbl[0], nr, Dv),
        ck=tuple(a.reshape(R * nbl[l + 1], nr, D)
                 for l, a in enumerate(dense.ck)),
        cv=tuple(a.reshape(R * nbl[l + 1], nr, Dv)
                 for l, a in enumerate(dense.cv)))
    return pool, nbl


def _identity_tables(ts, nbl, nr, M):
    R = len(ts)
    bidx = np.zeros((R, 2 + (M - 1)), np.int32)
    utab = np.zeros((R, M), np.int32)
    for r, t in enumerate(ts):
        b0 = t // nr
        bidx[r, 0] = r * nbl[0] + b0
        bidx[r, 1] = r * nbl[0] + max(b0 - 1, 0)
        for l in range(1, M):
            bidx[r, 1 + l] = r * nbl[l] + max(t // (nr << l) - 1, 0)
        for l in range(M):
            utab[r, l] = r * nbl[l] + (t >> l) // nr
    return jnp.asarray(bidx), jnp.asarray(utab)


@pytest.mark.parametrize("Lmax,nr,G", [(256, 16, 1), (128, 8, 4)])
def test_paged_attend_parity(Lmax, nr, G):
    """decode_attend_paged (jnp oracle AND fused kernel) == the dense
    decode_attend on an identity page layout, incl. boundary/quadrant
    positions and GQA groups."""
    ts = _interesting_ts(Lmax, nr)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=Lmax)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    M = hd.hc.num_levels(Lmax, nr)
    bidx, _ = _identity_tables(ts, nbl, nr, M)
    q = jax.random.normal(_keys(1, seed=2)[0], (R, G, D))
    t = jnp.asarray(ts)
    z_dense = hd.decode_attend(cache, q, t, nr=nr)
    z_jnp = hd.decode_attend_paged(pool, q, t, bidx, nr=nr)
    np.testing.assert_array_equal(np.asarray(z_jnp), np.asarray(z_dense))
    z_ker = jax.jit(lambda p, qq, tt, bb: hd.decode_attend_paged(
        p, qq, tt, bb, nr=nr, impl=IMPL))(pool, q, t, bidx)
    np.testing.assert_allclose(z_ker, z_dense, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("Lmax,nr", [(256, 16), (128, 8)])
def test_paged_update_parity_bit_exact(Lmax, nr):
    """update_cache_paged must be BIT-exact against the dense ancestor
    update (jnp oracle and fused kernel), including chained sequential
    writes through the carried pair mean/sum."""
    ts = _interesting_ts(Lmax, nr, n_extra=2)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=nr)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    M = hd.hc.num_levels(Lmax, nr)
    k1, k2 = _keys(2, seed=5)
    t = jnp.asarray(ts)
    for step in range(3):          # chained writes t, t+1, t+2
        tt = jnp.minimum(t + step, Lmax - 1)
        _, utab = _identity_tables(np.asarray(tt), nbl, nr, M)
        kn = jax.random.normal(jax.random.fold_in(k1, step), (R, D))
        vn = jax.random.normal(jax.random.fold_in(k2, step), (R, D))
        cache = hd.update_cache(cache, kn, vn, tt)
        pool_j = hd.update_cache_paged(pool, kn, vn, tt, utab)
        pool_k = jax.jit(lambda p, a, b, c, u: hd.update_cache_paged(
            p, a, b, c, u, impl=IMPL))(pool, kn, vn, tt, utab)
        for a, b in zip(jax.tree.leaves(pool_j), jax.tree.leaves(pool_k)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        pool = pool_j
        flat, _ = _identity_paged(cache, R, Lmax, nr)
        for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(pool)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# quantized (int8 per-row) paged variants
# ---------------------------------------------------------------------------

# per-level quantization configs swept below: all levels, fine level
# only, fine + first coarse (index 0 = level 0)
_QCONFIGS = [
    pytest.param(None, id="all-int8"),
    pytest.param((True, False, False, False, False), id="l0-int8"),
    pytest.param((True, True, False, False, False), id="l01-int8"),
]


def _quantize_pool(pool, quant):
    """Quantize an fp32 identity pool into a QuantPagedH1DCache with the
    same per-row absmax rule the decode-kernel rewrites use (fp32 levels
    keep their data and carry never-read all-ones scales)."""
    M = 1 + len(pool.ck)
    quant = (True,) * M if quant is None else tuple(quant[:M])

    def q(arr, is_q):
        if is_q:
            qd, sc = qz.quantize_int8(arr, axis=-1)
            return qd, sc[..., 0]
        return arr, jnp.ones(arr.shape[:-1], jnp.float32)

    k, ksc = q(pool.k, quant[0])
    v, vsc = q(pool.v, quant[0])
    cks, cvs, ckscs, cvscs = [], [], [], []
    for l, (ck, cv) in enumerate(zip(pool.ck, pool.cv), start=1):
        a, b = q(ck, quant[l]); cks.append(a); ckscs.append(b)
        a, b = q(cv, quant[l]); cvs.append(a); cvscs.append(b)
    return hd.QuantPagedH1DCache(
        k=k, v=v, ck=tuple(cks), cv=tuple(cvs), ksc=ksc, vsc=vsc,
        cksc=tuple(ckscs), cvsc=tuple(cvscs)), quant


def test_quant_roundtrip_idempotent():
    """quantize -> dequantize -> requantize is idempotent where it
    matters: the int8 payload is bit-stable from the first round trip,
    and the recomputed scales stay within ~1 ulp of the previous round
    (bounded oscillation, no compounding drift) -- so the decode
    kernel's repeated sibling-pair rewrites cannot walk the cache."""
    for axis in (-1, None):
        x = jax.random.normal(_keys(1, seed=20)[0], (64, 16))
        q, s = qz.quantize_int8(x, axis=axis)
        s0 = s
        for _ in range(4):
            q2, s2 = qz.quantize_int8(qz.dequantize_int8(q, s), axis=axis)
            np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
            np.testing.assert_allclose(np.asarray(s2), np.asarray(s),
                                       rtol=2e-7)
            q, s = q2, s2
        np.testing.assert_allclose(np.asarray(s), np.asarray(s0), rtol=5e-7)


@pytest.mark.parametrize("quant", _QCONFIGS)
@pytest.mark.parametrize("Lmax,nr,G", [(256, 16, 1), (128, 8, 4)])
def test_quant_attend_error_bound_vs_fp32(Lmax, nr, G, quant):
    """Quantized attend (jnp oracle AND fused kernel) stays within a
    pinned error bound of the fp32 jnp oracle on the same identity page
    layout -- boundary/quadrant positions (incl. t < nr) and GQA groups
    from `_interesting_ts`."""
    ts = _interesting_ts(Lmax, nr)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=Lmax)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    qpool, _ = _quantize_pool(pool, quant)
    M = hd.hc.num_levels(Lmax, nr)
    bidx, _ = _identity_tables(ts, nbl, nr, M)
    q = jax.random.normal(_keys(1, seed=2)[0], (R, G, D))
    t = jnp.asarray(ts)
    z_fp32 = hd.decode_attend(cache, q, t, nr=nr)
    z_jnp = hd.decode_attend_paged(qpool, q, t, bidx, nr=nr)
    # int8 per-row absmax: per-element dequant error <= scale/2 ~ 0.4%
    # of the row absmax; the softmax-weighted combination stays well
    # under 5% absolute for unit-normal KV
    err = float(jnp.max(jnp.abs(z_jnp - z_fp32)))
    assert err < 0.05, err
    z_ker = jax.jit(lambda p, qq, tt, bb: hd.decode_attend_paged(
        p, qq, tt, bb, nr=nr, impl=IMPL))(qpool, q, t, bidx)
    # oracle and kernel see identical int8+scale inputs -> tight parity
    np.testing.assert_allclose(z_ker, z_jnp, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("quant", _QCONFIGS)
@pytest.mark.parametrize("Lmax,nr", [(256, 16), (128, 8)])
def test_quant_update_parity_bit_exact(Lmax, nr, quant):
    """Quantized paged ancestor update: the fused kernel must be
    BIT-exact against the jnp quant oracle -- int8 payloads AND the
    freshly recomputed per-row scales -- including chained sequential
    writes (the ancestor carry rides the pre-quantization f32 pair)."""
    ts = _interesting_ts(Lmax, nr, n_extra=2)
    R, D = len(ts), 16
    cache = _cache(R, Lmax, D, D, nr, seed=nr)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    qpool, qflags = _quantize_pool(pool, quant)
    M = hd.hc.num_levels(Lmax, nr)
    assert hd.quant_level_flags(qpool) == qflags
    k1, k2 = _keys(2, seed=5)
    t = jnp.asarray(ts)
    upd_k = jax.jit(lambda p, a, b, c, u: hd.update_cache_paged(
        p, a, b, c, u, impl=IMPL))
    for step in range(3):
        tt = jnp.minimum(t + step, Lmax - 1)
        _, utab = _identity_tables(np.asarray(tt), nbl, nr, M)
        kn = jax.random.normal(jax.random.fold_in(k1, step), (R, D))
        vn = jax.random.normal(jax.random.fold_in(k2, step), (R, D))
        pool_j = hd.update_cache_paged(qpool, kn, vn, tt, utab)
        pool_k = upd_k(qpool, kn, vn, tt, utab)
        for a, b in zip(jax.tree.leaves(pool_j), jax.tree.leaves(pool_k)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        qpool = pool_j
    # written fine rows round-trip to the exact written values when the
    # row is freshly quantized (its own absmax sets the scale)
    if qflags[0]:
        row0 = np.asarray(tt) % nr
        got = qz.dequantize_int8(
            qpool.k[utab[:, 0], row0],
            qpool.ksc[utab[:, 0], row0][:, None])
        np.testing.assert_allclose(np.asarray(got), np.asarray(kn),
                                   atol=2e-2)


def test_quant_update_fp32_levels_untouched_scales():
    """Mixed config: fp32 levels keep all-ones scale arrays (never
    read, never written) while int8 levels get fresh per-row scales."""
    Lmax, nr, D = 128, 8, 16
    ts = _interesting_ts(Lmax, nr, n_extra=0)
    R = len(ts)
    cache = _cache(R, Lmax, D, D, nr, seed=9)
    pool, nbl = _identity_paged(cache, R, Lmax, nr)
    qpool, _ = _quantize_pool(pool, (True, False, False, False))
    M = hd.hc.num_levels(Lmax, nr)
    t = jnp.asarray(ts)
    _, utab = _identity_tables(ts, nbl, nr, M)
    kk = _keys(2, seed=10)
    kn = jax.random.normal(kk[0], (R, D))
    vn = jax.random.normal(kk[1], (R, D))
    for impl in ("jnp", IMPL):
        out = hd.update_cache_paged(qpool, kn, vn, t, utab, impl=impl)
        for sc in (*out.cksc, *out.cvsc):
            np.testing.assert_array_equal(
                np.asarray(sc), np.ones_like(np.asarray(sc)))
        for arr in (*out.ck, *out.cv):
            assert arr.dtype == jnp.float32
