"""Benchmark JSON baselines: schema smoke test.

``benchmarks/bench_kernels.py --json`` and ``bench_decode.py --json``
write machine-readable perf baselines (BENCH_kernels.json /
BENCH_decode.json) that tooling diffs across PRs.  This test pins the
schema of the COMMITTED files so a refactor cannot silently change the
row format (or forget to commit a baseline) without failing CI.
"""
import json
import os

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

BASELINES = {
    "BENCH_kernels.json": "kernels",
    "BENCH_decode.json": "decode",
    "BENCH_serve.json": "serve",
    "BENCH_scaling.json": "scaling",
}


@pytest.mark.parametrize("fname,bench", sorted(BASELINES.items()))
def test_bench_json_schema(fname, bench):
    path = os.path.join(ROOT, fname)
    assert os.path.exists(path), (
        f"{fname} baseline missing -- regenerate with "
        f"PYTHONPATH=src python benchmarks/bench_{bench}.py --json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["bench"] == bench
    assert isinstance(payload["shape"], dict) and payload["shape"]
    assert isinstance(payload["backend"], str)
    # baselines must record the XLA env AND the launch-policy tuning
    # state they were measured under, so a regeneration with different
    # flags or tuning tables is visible in the diff
    assert "xla_flags" in payload
    assert isinstance(payload["tuning_digest"], str) and payload[
        "tuning_digest"]
    assert isinstance(payload["backend"], str) and payload["backend"]
    rows = payload["rows"]
    assert isinstance(rows, list) and rows, "empty benchmark rows"
    names = set()
    for row in rows:
        assert set(row) >= {"name", "us_per_call", "derived"}, row
        assert isinstance(row["name"], str) and row["name"]
        assert isinstance(row["us_per_call"], (int, float))
        assert row["us_per_call"] >= 0.0, row
        assert isinstance(row["derived"], str)
        names.add(row["name"])
    assert len(names) == len(rows), "duplicate benchmark row names"


def test_bench_serve_covers_both_engines():
    """The serve baseline must keep the dense-vs-paged comparison
    diffable: throughput + latency per engine, the fixed-HBM
    concurrency headline, pool counters, and the token-parity guard --
    and its shape block must pin the workload knobs (incl. the
    shared-prefix length) so a regenerated baseline with a different
    workload is visible in the diff."""
    with open(os.path.join(ROOT, "BENCH_serve.json")) as f:
        payload = json.load(f)
    names = {r["name"] for r in payload["rows"]}
    for want in ("serve_dense_tok_s", "serve_paged_tok_s",
                 "serve_dense_latency", "serve_paged_latency",
                 "serve_paged_pool", "serve_prefix_hit_rate",
                 "serve_concurrency_fixed_hbm",
                 "serve_paged_token_parity"):
        assert want in names, want
    hit = next(r for r in payload["rows"]
               if r["name"] == "serve_prefix_hit_rate")
    rate = float(hit["derived"].split("hit_rate=")[1].split()[0])
    assert 0.0 <= rate <= 1.0, rate
    # the shared-prefix workload must actually hit the registry
    assert rate > 0.0, hit["derived"]
    for knob in ("max_len", "nr", "requests", "prefix_len",
                 "dense_slots", "paged_slots"):
        assert knob in payload["shape"], knob
    parity = next(r for r in payload["rows"]
                  if r["name"] == "serve_paged_token_parity")
    assert "identical=True" in parity["derived"]
    ratio = next(r for r in payload["rows"]
                 if r["name"] == "serve_concurrency_fixed_hbm")
    assert float(ratio["derived"].split("ratio=")[1].split()[0]) >= 2.0


def test_bench_serve_int8_quality_curve():
    """The serve baseline must also keep the int8 concurrency-vs-quality
    curve diffable: int8 engine throughput/latency/pool rows, greedy
    token-match rate vs the dense fp32 oracle (>= 0.99), per-level max
    dequantization error, cache bytes per storage dtype at the shared
    HBM budget, and the int8-vs-fp32-paged concurrency headline
    (>= 1.5x at fixed HBM)."""
    with open(os.path.join(ROOT, "BENCH_serve.json")) as f:
        payload = json.load(f)
    rows = {r["name"]: r["derived"] for r in payload["rows"]}
    for want in ("serve_paged_int8_tok_s", "serve_paged_int8_latency",
                 "serve_paged_int8_pool", "serve_quality_int8_match",
                 "serve_quality_int8_dequant", "serve_quality_hbm_bytes",
                 "serve_concurrency_int8_fixed_hbm"):
        assert want in rows, want
    assert "int8_slots" in payload["shape"]
    rate = float(rows["serve_quality_int8_match"]
                 .split("match_rate=")[1].split()[0])
    assert rate >= 0.99, rate
    # one max-|err| figure per hierarchy level, all finite and small
    errs = [float(tok.split("=")[1])
            for tok in rows["serve_quality_int8_dequant"].split()
            if "_max_abs_err=" in tok]
    assert len(errs) >= 2
    assert all(0.0 <= e < 1.0 for e in errs), errs
    hbm = rows["serve_quality_hbm_bytes"]
    for key in ("dense_fp32=", "paged_fp32=", "paged_int8=",
                "fp32_pages=", "int8_pages="):
        assert key in hbm, key
    # int8 pages fit >= 2x the fp32 pages inside the same byte budget
    fp32_pages = int(hbm.split("fp32_pages=")[1].split()[0])
    int8_pages = int(hbm.split("int8_pages=")[1].split()[0])
    assert int8_pages >= 2 * fp32_pages, (fp32_pages, int8_pages)
    ratio = float(rows["serve_concurrency_int8_fixed_hbm"]
                  .split("ratio=")[1].split()[0])
    assert ratio >= 1.5, ratio


def test_bench_kernels_covers_every_mode():
    """The kernels baseline must keep one fwd and one fwd+bwd row per
    banded mode (incl. the shallow/deep 'sub' ratios) so the perf
    trajectory of each kernel stays diffable."""
    with open(os.path.join(ROOT, "BENCH_kernels.json")) as f:
        names = {r["name"] for r in json.load(f)["rows"]}
    for tag in ("l0_bidir", "l0_causal", "coarse_bidir", "coarse_causal",
                "sub_r2", "sub_r16"):
        for suffix in ("fwd", "fwdbwd"):
            assert any(n.startswith(f"kernel_band_{tag}_")
                       and n.endswith(suffix) for n in names), (tag, suffix)


def test_bench_scaling_near_linear_to_16k():
    """The scaling baseline must keep the O(L) claim diffable: an H1D
    row at every sweep length up to 16k with a tokens/s figure, a dense
    comparison at the capped lengths, and fitted log-log slopes --
    near-linear (< 1.6) for H1D, super-linear (> 1.6) for dense."""
    with open(os.path.join(ROOT, "BENCH_scaling.json")) as f:
        payload = json.load(f)
    rows = {r["name"]: r["derived"] for r in payload["rows"]}
    for L in payload["shape"]["lengths"]:
        name = f"scaling_L{L}_h1d"
        assert name in rows, name
        assert "tok_s=" in rows[name]
    assert 16384 in payload["shape"]["lengths"]
    assert "full_us=" in rows[f"scaling_L{payload['shape']['dense_max_L']}"
                              "_h1d"]
    slope_h = float(rows["scaling_slope_h1d"].split("slope=")[1].split()[0])
    slope_f = float(rows["scaling_slope_full"].split("slope=")[1].split()[0])
    assert slope_h < 1.6, slope_h      # near-linear H1D sweep
    assert slope_f > 1.6, slope_f      # quadratic dense baseline
    # tokens/s stays near-flat: the slowest length keeps >= 1/4 of the
    # fastest (a quadratic path would decay ~64x over a 64x L sweep)
    ratio = float(rows["scaling_tok_s_ratio"]
                  .split("min_max_ratio=")[1].split()[0])
    assert ratio >= 0.25, ratio
