"""Tier-1 suite configuration.

Cap XLA's backend optimization effort for the test run: the default
suite is compile-dominated (most tests jit a model or kernel once and
execute it a handful of times), so the O2-style optimization pipeline
buys nothing here but wall-clock -- level 0 cuts the suite ~30% on the
2-vCPU CI host.  This is a compile-time knob only; every parity test
computes both sides under the same flags and all tolerances are
unchanged.  A caller-provided ``XLA_FLAGS`` (perf benchmarking, the
multi-device subprocess tests) is respected as-is.

This must run before the first ``import jax`` anywhere in the test
session, which pytest guarantees by importing conftest first.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")
