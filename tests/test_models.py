"""Per-architecture smoke tests: one forward/train step on CPU with the
reduced same-family config; output shapes + finiteness + decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_smoke_config, get_config
from repro.models import get_model

# default run keeps one representative per heavyweight family axis
# (dense+h1d, SSM; MoE block coverage lives in test_moe.py); the
# remaining architecture smokes are compile heavy (~10-30 s each) and
# run under ``pytest -m slow``
_DEFAULT_ARCHS = {"llama3.2-1b", "mamba2-1.3b"}
ARCH_PARAMS = [
    name if name in _DEFAULT_ARCHS
    else pytest.param(name, marks=pytest.mark.slow)
    for name in ARCH_IDS
]


def make_batch(cfg, key, B=2, S=64):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jnp.ones(
            (B, cfg.prefix_len, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jnp.ones((B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_arch_smoke_train_step(name):
    cfg = get_smoke_config(name)
    fns = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, specs = fns.init(key, cfg)
    # twin trees must match
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(
                jax.tree.map(lambda s: 0, specs,
                             is_leaf=lambda x: hasattr(x, "_normalized_spec")
                             or type(x).__name__ == "PartitionSpec")))
    batch = make_batch(cfg, key)
    loss, metrics = fns.loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: fns.loss(p, cfg, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in leaves) > 0


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_arch_smoke_prefill_decode(name):
    cfg = get_smoke_config(name)
    fns = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params, _ = fns.init(key, cfg)
    B = 2
    batch = make_batch(cfg, key, B=B, S=32)
    logits, caches, pos = fns.prefill(params, cfg, batch, 64)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, caches = fns.decode_step(params, cfg, caches, tok, pos)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


@pytest.mark.parametrize("name", PAPER_IDS)
def test_paper_configs_instantiate(name):
    cfg = get_config(name)
    fns = get_model(cfg)
    # eval_shape: count params without materializing 50-150M floats
    params_shape = jax.eval_shape(
        lambda key: fns.init(key, cfg)[0], jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params_shape))
    if name == "h1d-lm-53m":
        assert 40e6 < n < 70e6, n   # paper: 53M
    if name == "h1d-lm-144m":
        assert 110e6 < n < 180e6, n  # paper: 144M


def test_full_configs_match_assignment():
    """The exact numbers from the assigned pool."""
    expect = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 5632, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for name, (L, d, hq, hkv, ff, vocab) in expect.items():
        cfg = get_config(name)
        assert cfg.num_layers == L and cfg.d_model == d, name
        assert cfg.num_heads == hq and cfg.num_kv_heads == hkv, name
        assert cfg.d_ff == ff and cfg.vocab_size == vocab, name
    m = get_config("mamba2-1.3b")
    assert (m.num_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (48, 2048, 50280, 128)
    q = get_config("qwen2-moe-a2.7b")
    assert (q.moe_experts, q.moe_top_k, q.moe_d_ff) == (60, 4, 1408)
    a = get_config("arctic-480b")
    assert (a.moe_experts, a.moe_top_k, a.moe_dense_residual) == \
        (128, 2, True)
    z = get_config("zamba2-1.2b")
    assert z.ssm_state == 64 and z.family == "hybrid"
    g = get_config("gemma3-4b")
    assert g.sliding_window > 0 and g.global_every == 6


def test_gemma3_local_global_cadence():
    cfg = get_config("gemma3-4b")
    globals_ = [i for i in range(cfg.num_layers)
                if cfg.layer_uses_global_attn(i)]
    assert globals_ == [5, 11, 17, 23, 29]      # 5:1 local:global


def test_vlm_loss_ignores_prefix_positions():
    cfg = get_smoke_config("llava-next-34b")
    fns = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params, _ = fns.init(key, cfg)
    batch = make_batch(cfg, key, B=1, S=32)
    l1, _ = fns.loss(params, cfg, batch)
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] * 2.0
    l2, _ = fns.loss(params, cfg, batch2)
    # prefix embeddings influence the loss (through attention)...
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    # ...but logits are only scored on token positions (shape check)
    from repro.models.transformer import lm_forward
    logits, _ = lm_forward(params, cfg, batch["tokens"],
                           prefix_embeds=batch["patch_embeds"])
    assert logits.shape[1] == batch["tokens"].shape[1]
