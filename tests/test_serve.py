"""Serving engine: greedy generation matches a manual decode loop;
continuous batching slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import ServeEngine, Request


def manual_greedy(cfg, params, prompt, n_new, max_len=96):
    fns = get_model(cfg)
    logits, caches, pos = fns.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]}, max_len)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.array([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, caches = fns.decode_step(params, cfg, caches, tok, pos)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.array([out[-1]], jnp.int32)
        pos = pos + 1
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_engine_matches_manual_greedy(arch):
    cfg = get_smoke_config(arch)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(10, 26) % cfg.vocab_size,
               (np.arange(5, 37) * 3) % cfg.vocab_size]
    eng = ServeEngine(cfg, params, slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        want = manual_greedy(cfg, params, r.prompt, 6)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


@pytest.mark.slow
def test_engine_slot_reuse_more_requests_than_slots():
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i,
                    prompt=((np.arange(8) + i * 7) % cfg.vocab_size)
                    .astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) == 4
        want = manual_greedy(cfg, params, r.prompt, 4)
        assert r.out_tokens == want


def test_prompt_length_bucketing_compile_count_and_parity():
    """Distinct prompt lengths within one power-of-two bucket must share
    a single _prefill1 compilation (regression: per-length jit retraces
    made admission O(#distinct lengths) compiles), and the bucketed
    prefill must still generate exactly what the unpadded path does."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, slots=4, max_len=64)
    lengths = [5, 6, 7, 8]           # one bucket: all pad to 8
    reqs = [Request(uid=i,
                    prompt=((np.arange(n) + 3 * i) % cfg.vocab_size)
                    .astype(np.int32),
                    max_new_tokens=3) for i, n in enumerate(lengths)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    cache_size = getattr(eng._prefill1, "_cache_size", lambda: None)()
    if cache_size is not None:
        assert cache_size == 1, (lengths, cache_size)
    for r in reqs:
        want = manual_greedy(cfg, params, r.prompt, 3, max_len=64)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


def test_engine_host_pos_mirror_tracks_device():
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(3), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=(np.arange(9) % cfg.vocab_size)
                       .astype(np.int32), max_new_tokens=4))
    while eng.step():
        np.testing.assert_array_equal(eng.pos_host, np.asarray(eng.pos))


def test_bucketing_gated_off_for_rolling_and_recurrent_caches():
    """Padding must not reach prefills whose caches are not position
    masked: SSM state scans over pads, and the rolling local cache keeps
    only the last 2*window rows (pads would evict real in-window keys)."""
    import dataclasses
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(4), cfg)
    assert ServeEngine(cfg, params, slots=1, max_len=64)._bucket
    local = dataclasses.replace(cfg, sliding_window=16)
    assert not ServeEngine(local, params, slots=1, max_len=64)._bucket
    # coarse-q leaks pad embeddings into coarse QUERY means (DESIGN 1.2)
    coarse = dataclasses.replace(cfg, causal_mode="coarse-q")
    assert not ServeEngine(coarse, params, slots=1, max_len=64)._bucket
    ssm = get_smoke_config("mamba2-1.3b")
    sparams, _ = get_model(ssm).init(jax.random.PRNGKey(5), ssm)
    assert not ServeEngine(ssm, sparams, slots=1, max_len=64)._bucket
