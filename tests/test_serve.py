"""Serving engine: greedy generation matches a manual decode loop;
continuous batching slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import ServeEngine, Request


def manual_greedy(cfg, params, prompt, n_new, max_len=96):
    fns = get_model(cfg)
    logits, caches, pos = fns.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]}, max_len)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.array([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, caches = fns.decode_step(params, cfg, caches, tok, pos)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.array([out[-1]], jnp.int32)
        pos = pos + 1
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_engine_matches_manual_greedy(arch):
    cfg = get_smoke_config(arch)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(10, 26) % cfg.vocab_size,
               (np.arange(5, 37) * 3) % cfg.vocab_size]
    eng = ServeEngine(cfg, params, slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        want = manual_greedy(cfg, params, r.prompt, 6)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


@pytest.mark.slow
def test_engine_slot_reuse_more_requests_than_slots():
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i,
                    prompt=((np.arange(8) + i * 7) % cfg.vocab_size)
                    .astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) == 4
        want = manual_greedy(cfg, params, r.prompt, 4)
        assert r.out_tokens == want
