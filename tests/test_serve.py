"""Serving engine: greedy generation matches a manual decode loop;
continuous batching slot reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import ServeEngine, Request


# jit the replication loop ONCE per (config, max_len): the smoke config
# is shared across tests, so prefill/decode executables are reused and
# the manual loops stop dominating the suite's wall-clock
_MANUAL_JIT = {}


def _manual_fns(cfg, max_len):
    # repr(cfg) covers EVERY config field: two configs differing in any
    # field (nr, causal_mode, ...) must not share a traced closure
    key = (repr(cfg), max_len)
    if key not in _MANUAL_JIT:
        fns = get_model(cfg)
        _MANUAL_JIT[key] = (
            jax.jit(lambda p, b: fns.prefill(p, cfg, b, max_len)),
            jax.jit(lambda p, c, tok, pos: fns.decode_step(p, cfg, c, tok,
                                                           pos)),
        )
    return _MANUAL_JIT[key]


def manual_greedy(cfg, params, prompt, n_new, max_len=96):
    prefill, decode_step = _manual_fns(cfg, max_len)
    logits, caches, pos = prefill(
        params, {"tokens": jnp.asarray(prompt)[None]})
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.array([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, caches = decode_step(params, caches, tok, pos)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.array([out[-1]], jnp.int32)
        pos = pos + 1
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_engine_matches_manual_greedy(arch):
    cfg = get_smoke_config(arch)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(10, 26) % cfg.vocab_size,
               (np.arange(5, 37) * 3) % cfg.vocab_size]
    eng = ServeEngine(cfg, params, slots=2, max_len=96)
    reqs = [Request(uid=i, prompt=p.astype(np.int32), max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        want = manual_greedy(cfg, params, r.prompt, 6)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


@pytest.mark.slow
def test_engine_slot_reuse_more_requests_than_slots():
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i,
                    prompt=((np.arange(8) + i * 7) % cfg.vocab_size)
                    .astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) == 4
        want = manual_greedy(cfg, params, r.prompt, 4)
        assert r.out_tokens == want


def test_prompt_length_bucketing_compile_count_and_parity():
    """Distinct prompt lengths within one power-of-two bucket must share
    a single _prefill1 compilation (regression: per-length jit retraces
    made admission O(#distinct lengths) compiles), and the bucketed
    prefill must still generate exactly what the unpadded path does."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, slots=4, max_len=64)
    lengths = [5, 6, 7, 8]           # one bucket: all pad to 8
    reqs = [Request(uid=i,
                    prompt=((np.arange(n) + 3 * i) % cfg.vocab_size)
                    .astype(np.int32),
                    max_new_tokens=3) for i, n in enumerate(lengths)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    cache_size = getattr(eng._prefill1, "_cache_size", lambda: None)()
    if cache_size is not None:
        assert cache_size == 1, (lengths, cache_size)
    # parity spot-check at the bucket extremes (most padding / none);
    # the unjitted manual_greedy replication dominates wall-clock
    for r in (reqs[0], reqs[-1]):
        want = manual_greedy(cfg, params, r.prompt, 3, max_len=64)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


def test_engine_host_pos_mirror_tracks_device():
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(3), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=(np.arange(9) % cfg.vocab_size)
                       .astype(np.int32), max_new_tokens=4))
    while eng.step():
        np.testing.assert_array_equal(eng.pos_host, np.asarray(eng.pos))


def test_admission_group_size_padding_bounds_compiles():
    """Batched admission pads the prefill ROW count to a power of two:
    a 3-request group reuses the 4-row executable (dummy row discarded)
    instead of compiling a fresh (3, Lb) shape per group size."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(5), cfg)
    eng = ServeEngine(cfg, params, slots=4, max_len=64)

    def batch(n0, count):
        return [Request(uid=n0 + i,
                        prompt=((np.arange(5 + i) + n0) % cfg.vocab_size)
                        .astype(np.int32), max_new_tokens=2)
                for i in range(count)]

    first = batch(0, 4)                  # group of 4 -> (4, 8) compile
    for r in first:
        eng.submit(r)
    eng.run()
    second = batch(10, 3)                # group of 3 -> padded to 4 rows
    for r in second:
        eng.submit(r)
    eng.run()
    cache_size = getattr(eng._prefill1, "_cache_size", lambda: None)()
    if cache_size is not None:
        assert cache_size == 1, cache_size
    # dummy-row padding must not leak into outputs: spot-check one row
    # of the full group and one of the padded group (the unjitted
    # manual_greedy replication dominates this test's wall-clock)
    for r in (first[0], second[2]):
        want = manual_greedy(cfg, params, r.prompt, 2, max_len=64)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


def test_noncontiguous_free_slot_admission():
    """Slots freed out of order (free = [0, 2] around a busy slot 1)
    must admit a group via the row-index scatter path and still generate
    exactly the unbatched outputs."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(10), cfg)
    eng = ServeEngine(cfg, params, slots=3, max_len=64)
    first = [Request(uid=i, prompt=((np.arange(8) + 2 * i) % cfg.vocab_size)
                     .astype(np.int32), max_new_tokens=n)
             for i, n in enumerate([2, 6, 2])]   # slots 0/2 free early
    for r in first:
        eng.submit(r)
    while eng.step() != 1:       # run until only slot 1 is active
        pass
    assert list(np.nonzero(~eng.active)[0]) == [0, 2]
    late = [Request(uid=10 + i, prompt=((np.arange(6) + 5 * i)
                    % cfg.vocab_size).astype(np.int32), max_new_tokens=3)
            for i in range(2)]
    for r in late:
        eng.submit(r)
    eng.run()
    # spot-check the scatter-admitted rows plus the slot that stayed
    # busy across the scatter (manual_greedy replication is unjitted
    # and dominates wall-clock; the admission path is what's under test)
    for r in late + [first[1]]:
        want = manual_greedy(cfg, params, r.prompt, r.max_new_tokens,
                             max_len=64)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)


def test_max_new_tokens_is_a_hard_cap():
    """max_new_tokens=1 must yield exactly one token (the admit sample)
    -- the admit-time done check; previously every request got >= 2
    because the first done check only ran after a decode step."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(4), cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=((np.arange(8) + i) % cfg.vocab_size)
                    .astype(np.int32), max_new_tokens=n)
            for i, n in enumerate([1, 3])]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [len(r.out_tokens) for r in reqs] == [1, 3]
    for r, n in zip(reqs, [1, 3]):
        assert r.out_tokens == manual_greedy(cfg, params, r.prompt, n,
                                             max_len=64)


def test_admit_first_token_sampled_when_not_greedy():
    """Regression: _admit used to argmax the first generated token even
    with greedy=False; it must sample from the engine key exactly like
    step() does (one split per batched admit call)."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(6), cfg)
    seed = 11
    eng = ServeEngine(cfg, params, slots=1, max_len=64, greedy=False,
                      seed=seed)
    prompt = ((np.arange(9) * 5) % cfg.vocab_size).astype(np.int32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=2)
    eng.submit(req)
    eng.step()
    # replicate the admit computation: pad to the 16-bucket, per-row
    # true_len, first split of the seeded key folded with the
    # destination slot index (slot 0)
    toks = jnp.asarray(np.pad(prompt, (0, 16 - 9)))[None]
    logits, _, _ = fns.prefill(params, cfg, {"tokens": toks}, 64,
                               true_len=jnp.asarray([9], np.int32))
    _, kbase = jax.random.split(jax.random.PRNGKey(seed))
    want = int(jax.random.categorical(jax.random.fold_in(kbase, 0),
                                      logits[0]))
    assert req.out_tokens[0] == want
    # the seed is chosen so the sample differs from argmax -- the old
    # code path would fail here
    assert want != int(jnp.argmax(logits[0]))


def test_admit_sampling_invariant_to_bucket_padding():
    """Regression: _admit used to draw ONE categorical over the padded
    (gp, V) logits, so the gumbel noise tensor -- and therefore a
    request's first sampled token -- changed with the number of dummy
    rows its bucket got.  Per-row keys (fold in the destination slot)
    make the sample depend only on the request's own slot and logits."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(6), cfg)
    prompts = [((np.arange(9) * 5) % cfg.vocab_size).astype(np.int32),
               ((np.arange(12) * 3) % cfg.vocab_size).astype(np.int32),
               ((np.arange(10) * 7) % cfg.vocab_size).astype(np.int32)]

    def first_token(n_submitted):
        eng = ServeEngine(cfg, params, slots=4, max_len=64, greedy=False,
                          seed=11)
        reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=1)
                for i in range(n_submitted)]
        for r in reqs:
            eng.submit(r)
        eng.step()
        return reqs[0].out_tokens[0]

    # group sizes 1, 2 and 3 pad to row counts 1, 2 and 4 (one dummy
    # row in the last case); request 0's first token must not move
    alone = first_token(1)
    assert first_token(2) == alone
    assert first_token(3) == alone


def test_stop_tokens_end_generation_early_greedy():
    """Request.stop_tokens must end generation before max_new_tokens on
    the greedy path (previously max_new_tokens / a full cache were the
    only stop conditions).  The stop token is kept in out_tokens."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(12), cfg)
    prompt = ((np.arange(11) * 3) % cfg.vocab_size).astype(np.int32)
    free = manual_greedy(cfg, params, prompt, 8, max_len=64)
    # first position whose token value has no earlier occurrence, so
    # the truncated stream is unambiguous
    stop_at = next(k for k in range(1, 8) if free[k] not in free[:k])
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=8,
                  stop_tokens=[free[stop_at]])
    eng.submit(req)
    eng.run()
    assert req.out_tokens == free[:stop_at + 1]
    # stop token sampled AT ADMISSION must also terminate immediately
    req2 = Request(uid=1, prompt=prompt.copy(), max_new_tokens=8,
                   stop_tokens=[free[0]])
    eng.submit(req2)
    eng.run()
    assert req2.out_tokens == free[:1]


def test_stop_tokens_end_generation_early_sampled():
    """Stop-token termination must also cover the sampled path (both
    the admission sample and per-step samples), reproducibly via the
    engine seed."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(13), cfg)
    prompt = ((np.arange(9) * 7) % cfg.vocab_size).astype(np.int32)

    def sample_run(stop_tokens):
        eng = ServeEngine(cfg, params, slots=1, max_len=64, greedy=False,
                          seed=21)
        req = Request(uid=0, prompt=prompt.copy(), max_new_tokens=8,
                      stop_tokens=stop_tokens)
        eng.submit(req)
        eng.run()
        return req.out_tokens

    free = sample_run(None)
    assert len(free) == 8
    stop_at = next(k for k in range(1, 8) if free[k] not in free[:k])
    stopped = sample_run([free[stop_at]])
    # same seed => identical sample stream up to (and incl.) the stop
    assert stopped == free[:stop_at + 1]


def test_submit_overflow_policy():
    """Prompts longer than max_len - 1 must be rejected (default) or
    tail-truncated (overflow='truncate'); silent admission used to build
    an over-long prefill cache whose slot write corrupted neighbours."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(7), cfg)
    long_prompt = ((np.arange(40) * 7) % cfg.vocab_size).astype(np.int32)
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(uid=0, prompt=long_prompt, max_new_tokens=2))
    # truncate policy == manually submitting the last max_len-1 tokens
    tr = Request(uid=1, prompt=long_prompt.copy(), max_new_tokens=2)
    eng_t = ServeEngine(cfg, params, slots=1, max_len=32,
                        overflow="truncate")
    eng_t.submit(tr)
    eng_t.run()
    ref = Request(uid=2, prompt=long_prompt[-31:].copy(), max_new_tokens=2)
    eng_r = ServeEngine(cfg, params, slots=1, max_len=32)
    eng_r.submit(ref)
    eng_r.run()
    assert tr.out_tokens == ref.out_tokens


def test_finished_slots_frozen_no_out_of_range_writes():
    """A finished slot must stop advancing pos while other slots keep
    decoding -- a free-running pos walks past the cache rows and the
    clamped update grinds on the last row (regression: pos advanced for
    every slot unconditionally)."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(8), cfg)
    max_len = 64
    eng = ServeEngine(cfg, params, slots=2, max_len=max_len)
    short = Request(uid=0, prompt=(np.arange(24) % cfg.vocab_size)
                    .astype(np.int32), max_new_tokens=2)
    long = Request(uid=1, prompt=(np.arange(8) % cfg.vocab_size)
                   .astype(np.int32), max_new_tokens=53)
    eng.submit(short)
    eng.submit(long)
    frozen_at = None
    while eng.step():
        # mirror stays exact and every position stays a legal cache row
        np.testing.assert_array_equal(eng.pos_host, np.asarray(eng.pos))
        assert int(eng.pos_host.max()) <= max_len - 1
        if not eng.active[0]:         # short (slot 0) finished
            if frozen_at is None:
                frozen_at = int(eng.pos_host[0])
            assert int(eng.pos_host[0]) == frozen_at
    assert frozen_at is not None and len(long.out_tokens) > 20


def test_engine_decode_impl_kernel_parity():
    """The fused decode kernel path generates exactly what the jnp path
    does, through the whole engine (batched slots AND the B=1 uniform
    specialization)."""
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(9), cfg)
    prompts = [((np.arange(n) + 11 * n) % cfg.vocab_size).astype(np.int32)
               for n in (9, 17)]
    # slots=2 covers the batched engine tick; the B=1 uniform kernel
    # path is parity-swept at layer level (test_decode_kernel) and
    # end-to-end in the SP engine test
    outs = {}
    for impl in ("jnp", "pallas_interpret"):
        eng = ServeEngine(cfg, params, slots=2, max_len=64,
                          decode_impl=impl)
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[impl] = [r.out_tokens for r in reqs]
    assert outs["jnp"] == outs["pallas_interpret"]


def test_bucketing_gated_off_for_rolling_and_recurrent_caches():
    """Padding must not reach prefills whose caches are not position
    masked: SSM state scans over pads, and the rolling local cache keeps
    only the last 2*window rows (pads would evict real in-window keys)."""
    import dataclasses
    cfg = get_smoke_config("llama3.2-1b")
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(4), cfg)
    assert ServeEngine(cfg, params, slots=1, max_len=64)._bucket
    local = dataclasses.replace(cfg, sliding_window=16)
    assert not ServeEngine(local, params, slots=1, max_len=64)._bucket
    # coarse-q leaks pad embeddings into coarse QUERY means (DESIGN 1.2)
    coarse = dataclasses.replace(cfg, causal_mode="coarse-q")
    assert not ServeEngine(coarse, params, slots=1, max_len=64)._bucket
    ssm = get_smoke_config("mamba2-1.3b")
    sparams, _ = get_model(ssm).init(jax.random.PRNGKey(5), ssm)
    assert not ServeEngine(ssm, sparams, slots=1, max_len=64)._bucket
