"""Paged hierarchical KV-cache pool + continuous-batching scheduler:
paged-vs-dense greedy token parity (the dense slot engine is the
oracle), allocator/scheduler unit behavior, prefix sharing + COW,
eviction, preemption (swap and recompute), chunked prefill, and
bit-exact page reconstruction.

The randomized/property schedules run under ``REPRO_POOL_CHECK=1``:
the pool re-runs the model checker's invariants
(``analysis/pool_model.check_pool_invariants``) after every mutating
op, so these fuzzed engine runs double as an allocator soundness
sweep."""
import contextlib
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import (ServeEngine, Request,
                         ContinuousBatchingScheduler, QueueEntry)
from repro.serve import paged_cache as pc


_STATE = {}


@contextlib.contextmanager
def _pool_check():
    """Run the enclosed engine schedule with per-op pool invariant
    checking (plain env try/finally: the hypothesis shim replays test
    bodies, which interacts badly with fixture-scoped monkeypatching)."""
    os.environ["REPRO_POOL_CHECK"] = "1"
    try:
        yield
    finally:
        os.environ.pop("REPRO_POOL_CHECK", None)


def _model():
    if "cfg" not in _STATE:
        cfg = get_smoke_config("llama3.2-1b")
        params, _ = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
        _STATE["cfg"], _STATE["params"] = cfg, params
    return _STATE["cfg"], _STATE["params"]


def _workload(seed, n, cfg, prefix_len=21):
    """Mixed prompts: ~half share a prefix (non-page-aligned so partial
    pages + their coarse ancestors get shared and later COW'd)."""
    rng = np.random.default_rng(seed)
    pre = (np.arange(prefix_len) * 5 % cfg.vocab_size).astype(np.int32)
    out = []
    for i in range(n):
        if rng.random() < 0.5:
            p = np.concatenate([pre, rng.integers(
                0, cfg.vocab_size, int(rng.integers(1, 16))).astype(np.int32)])
        else:
            p = rng.integers(0, cfg.vocab_size,
                             int(rng.integers(3, 40))).astype(np.int32)
        out.append((p, int(rng.integers(1, 8))))
    return out


def _run(wl, **kw):
    cfg, params = _model()
    eng = ServeEngine(cfg, params, max_len=64, **kw)
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=m)
            for i, (p, m) in enumerate(wl)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.out_tokens for r in reqs]


_REF = {}


def _dense_ref(seed, n):
    cfg, _ = _model()
    if (seed, n) not in _REF:
        _REF[(seed, n)] = _run(_workload(seed, n, cfg), slots=2)[1]
    return _REF[(seed, n)]


# ---------------------------------------------------------------------------
# engine-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_paged_matches_dense_greedy(impl):
    """Same requests, same greedy tokens -- through the whole engine,
    jnp oracle and fused paged kernels."""
    cfg, _ = _model()
    wl = _workload(3, 6, cfg)
    ref = _dense_ref(3, 6)
    eng, out = _run(wl, slots=2, paged=True, decode_impl=impl)
    assert out == ref
    assert eng.pool.occupancy() == 0.0          # everything released


def test_prefix_sharing_and_cow_with_token_parity():
    """Identical prompts of non-span-aligned length must share pages
    (incl. partial frontier pages and coarse ancestors) at admission and
    privatize them lazily via copy-on-write on the first decode write --
    with tokens still identical to the dense engine."""
    cfg, _ = _model()
    p = (np.arange(30) * 3 % cfg.vocab_size).astype(np.int32)
    wl = [(p.copy(), 4) for _ in range(3)]
    _, ref = _run(wl, slots=3)
    eng, out = _run(wl, slots=3, paged=True, pool_pages=24)
    assert out == ref
    assert eng.pool.stats.shared_maps > 0
    assert eng.pool.stats.cow_copies > 0        # divergent writes COW'd


def test_eviction_under_pool_pressure():
    """A pool far smaller than slots*Lmax forces the prefix registry's
    evictable pages to be reclaimed; token streams must not change."""
    cfg, _ = _model()
    wl = _workload(5, 8, cfg)
    ref = _dense_ref(5, 8)
    eng, out = _run(wl, slots=3, paged=True, pool_pages=10)
    assert out == ref
    assert eng.pool.stats.evictions > 0


def test_preemption_swap_restores_bit_exact_tokens():
    """Pool exhaustion mid-decode preempts the newest request; swap mode
    snapshots its pages and restores them bit-exact, so greedy tokens
    stay IDENTICAL to the never-preempted dense run."""
    cfg, _ = _model()
    wl = _workload(7, 10, cfg)
    ref = _dense_ref(7, 10)
    with _pool_check():
        eng, out = _run(wl, slots=4, paged=True, pool_pages=8,
                        lookahead=4)
    assert eng.preemptions > 0, "schedule no longer exercises preemption"
    assert out == ref


def test_preemption_recompute_resumes_consistently():
    """Recompute mode re-prefills prompt+generated on resume; lengths
    and the pre-preemption token prefix must be preserved even though
    the recomputed cache only matches to ~1e-6 (greedy continuations may
    legitimately drift at argmax near-ties, so only structure is
    asserted here -- bit-parity is swap mode's job)."""
    cfg, _ = _model()
    wl = _workload(7, 10, cfg)
    ref = _dense_ref(7, 10)
    eng, out = _run(wl, slots=4, paged=True, pool_pages=8, lookahead=4,
                    preempt_mode="recompute")
    assert eng.preemptions > 0
    for got, want, (_, m) in zip(out, ref, wl):
        assert len(got) == len(want) == m


@pytest.mark.parametrize("seed", [
    pytest.param(11, marks=pytest.mark.slow), 23])
def test_randomized_admission_eviction_preemption_schedule(seed):
    """Randomized workloads over randomized engine shapes: admission
    order, eviction and preemption schedules all differ from the dense
    run, greedy tokens must not."""
    cfg, _ = _model()
    rng = np.random.default_rng(seed)
    wl = _workload(seed, 8, cfg)
    ref = _dense_ref(seed, 8)
    kw = dict(slots=int(rng.integers(2, 6)),
              pool_pages=int(rng.integers(8, 16)),
              lookahead=int(rng.integers(0, 6)))
    with _pool_check():
        eng, out = _run(wl, paged=True, **kw)
    assert out == ref, kw


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=5, deadline=None)
@pytest.mark.slow
def test_property_random_schedules_match_dense(seed):
    """Property form of the schedule-parity invariant (hypothesis when
    installed): any pool size / lookahead / budget combination yields
    the dense engine's exact greedy streams."""
    cfg, _ = _model()
    rng = np.random.default_rng(seed)
    wl = _workload(seed % 97, 6, cfg)
    _, ref = _run(wl, slots=2)
    kw = dict(slots=int(rng.integers(2, 5)),
              pool_pages=int(rng.integers(7, 20)),
              lookahead=int(rng.integers(0, 5)),
              token_budget=int(rng.integers(16, 64)))
    with _pool_check():
        _, out = _run(wl, paged=True, **kw)
    assert out == ref, kw


def test_chunked_prefill_interleaves_and_matches():
    """prefill_chunk admits long prompts on a short chunk and streams
    the tail through the decode ticks; outputs must equal the dense
    whole-prompt prefill path."""
    cfg, _ = _model()
    wl = _workload(13, 6, cfg)
    ref = _dense_ref(13, 6)
    eng, out = _run(wl, slots=3, paged=True, pool_pages=16,
                    prefill_chunk=6, token_budget=24)
    assert out == ref


def test_reconstruction_bit_exact_against_dense_engine():
    """Mid-flight, every paged slot's MAPPED pages must reconstruct the
    EXACT dense cache rows for their blocks (prompt pages, shared pages,
    decode-written pages, zero-init decode pages) -- run both engines in
    lockstep and compare bit-for-bit.  Only mapped blocks are compared:
    the dense engine's bucketed prefill also writes PAD-token K/V rows
    beyond the prompt, which position masks hide from every attend and
    which the paged engine therefore never allocates at all."""
    cfg, params = _model()
    wl = _workload(17, 2, cfg)
    d = ServeEngine(cfg, params, slots=2, max_len=64)
    g = ServeEngine(cfg, params, slots=2, max_len=64, paged=True,
                    pool_pages=32)
    reqs_d = [Request(uid=i, prompt=p.copy(), max_new_tokens=m)
              for i, (p, m) in enumerate(wl)]
    reqs_g = [Request(uid=i, prompt=p.copy(), max_new_tokens=m)
              for i, (p, m) in enumerate(wl)]
    for rd, rg in zip(reqs_d, reqs_g):
        d.submit(rd)
        g.submit(rg)
    hkv = cfg.num_kv_heads
    nr = cfg.nr
    compared = 0
    for _ in range(4):
        d.step()
        g.step()
        for s in range(2):
            if not g.active[s]:
                continue
            rec = pc.gather_slot_cache(g.caches, g.pool, s, hkv,
                                       g._stacked)
            rows = slice(s * hkv, (s + 1) * hkv)
            lvls = [(rec.k, d.caches.k), (rec.v, d.caches.v)]
            lvls += [(a, b) for a, b in zip(rec.ck, d.caches.ck)]
            lvls += [(a, b) for a, b in zip(rec.cv, d.caches.cv)]
            lev_of = [0, 0] + [i + 1 for i in range(len(rec.ck))] \
                + [i + 1 for i in range(len(rec.cv))]
            for (a, b), l in zip(lvls, lev_of):
                blks = np.nonzero(g.pool.table[l][s] >= 0)[0]
                for blk in blks:
                    cols = slice(blk * nr, (blk + 1) * nr)
                    np.testing.assert_array_equal(
                        np.asarray(a[:, :, cols]),
                        np.asarray(b[:, rows, cols]),
                        err_msg=str((s, l, int(blk))))
                    compared += 1
    assert compared > 20        # the lockstep loop actually compared


# ---------------------------------------------------------------------------
# allocator / scheduler units (no model)
# ---------------------------------------------------------------------------

def test_pool_admit_is_transactional_on_exhaustion():
    """A failed admission must leave NO trace: no mapped blocks, no
    registry keys pointing at never-written pages (regression: a stale
    registration served garbage to the same prompt's retry)."""
    pool = pc.PagePool(slots=2, max_len=64, nr=8, pool_pages=4)
    toks = np.arange(40, dtype=np.int32)     # needs 5 fine pages > 4
    with pytest.raises(pc.PoolExhausted):
        pool.admit(0, toks)
    assert not pool.registry and not pool.key_of
    assert (pool.table[0][0] == -1).all()
    assert all(pool.used(l) == 0 for l in range(pool.M))
    # and the pool still serves a request that fits
    w = pool.admit(0, np.arange(16, dtype=np.int32))
    assert len(w[0]) == 2


def test_pool_refcount_sharing_and_release():
    pool = pc.PagePool(slots=3, max_len=64, nr=8, pool_pages=16)
    toks = np.arange(16, dtype=np.int32)
    pool.admit(0, toks)
    w1 = pool.admit(1, toks)
    assert w1[0] == []                       # full registry hit
    page = int(pool.table[0][0, 0])
    assert pool.table[0][1, 0] == page
    assert pool.refcount[0][page] == 2
    pool.release_slot(0)
    assert pool.refcount[0][page] == 1
    pool.release_slot(1)
    # registered pages park on the evictable LRU, not the free list
    assert (0, page) in pool.evictable
    assert pool.available(0) == pool.usable(0)


def test_pool_cow_on_first_divergent_write():
    pool = pc.PagePool(slots=2, max_len=64, nr=8, pool_pages=16)
    toks = np.arange(12, dtype=np.int32)     # partial page 1 (8..12)
    pool.admit(0, toks)
    pool.admit(1, toks)
    shared = int(pool.table[0][0, 1])
    assert pool.table[0][1, 1] == shared and pool.refcount[0][shared] == 2
    copies = {}
    pool.prepare_tick(0, 12, copies)         # slot 0 writes position 12
    assert int(pool.table[0][0, 1]) != shared     # COW'd away
    assert pool.refcount[0][shared] == 1          # slot 1 keeps original
    assert any(src == shared for src, _ in copies.get(0, []))


def test_scheduler_token_budget_and_lookahead():
    def entry(n, uid):
        return QueueEntry(req=uid, prompt=np.arange(n, dtype=np.int32))

    bucket = lambda s: 1 << max(s - 1, 0).bit_length()
    # legacy semantics: unlimited budget groups consecutive same-bucket
    sched = ContinuousBatchingScheduler()
    groups, rest = sched.plan([entry(5, 0), entry(6, 1), entry(20, 2)],
                              free_slots=4, n_active=0,
                              bucket_len=bucket, can_admit=lambda e: True)
    assert [[e.req for e in g.entries] for g in groups] == [[0, 1], [2]]
    assert not rest
    # budget: 10 tokens admits only the head (5), not 5+6
    sched = ContinuousBatchingScheduler(token_budget=10)
    groups, rest = sched.plan([entry(5, 0), entry(6, 1)], 4, 0,
                              bucket, lambda e: True)
    assert [[e.req for e in g.entries] for g in groups] == [[0]]
    assert [e.req for e in rest] == [1]
    # lookahead: an infeasible head is skipped within the window
    sched = ContinuousBatchingScheduler(lookahead=2)
    groups, rest = sched.plan([entry(30, 0), entry(5, 1)], 1, 1,
                              bucket, lambda e: len(e.prompt) < 10)
    assert [[e.req for e in g.entries] for g in groups] == [[1]]
    assert [e.req for e in rest] == [0]
    # anti-starvation: an idle engine admits its first pick even over
    # budget
    sched = ContinuousBatchingScheduler(token_budget=4)
    groups, _ = sched.plan([entry(30, 0)], 1, 0, bucket, lambda e: True)
    assert [[e.req for e in g.entries] for g in groups] == [[0]]
    # chunking caps the admitted chunk
    sched = ContinuousBatchingScheduler(prefill_chunk=8)
    groups, _ = sched.plan([entry(30, 0)], 1, 0, bucket, lambda e: True)
    assert len(groups[0].chunks[0]) == 8


def test_paged_engine_gating():
    cfg, params = _model()
    import dataclasses
    with pytest.raises(ValueError, match="uniform h1d"):
        ServeEngine(dataclasses.replace(cfg, sliding_window=16), params,
                    slots=1, max_len=64, paged=True)
    ssm = get_smoke_config("mamba2-1.3b")
    sp, _ = get_model(ssm).init(jax.random.PRNGKey(1), ssm)
    with pytest.raises(ValueError, match="uniform h1d"):
        ServeEngine(ssm, sp, slots=1, max_len=64, paged=True)


def test_pool_too_small_for_one_request_raises():
    cfg, params = _model()
    eng = ServeEngine(cfg, params, slots=1, max_len=64, paged=True,
                      pool_pages=2)
    eng.submit(Request(uid=0, prompt=np.arange(10, dtype=np.int32),
                       max_new_tokens=40))
    with pytest.raises(RuntimeError, match="pool"):
        eng.run()


# ---------------------------------------------------------------------------
# int8 quantized pool (cache_dtype='int8')
# ---------------------------------------------------------------------------

def _match_rate(out, ref):
    """Positional greedy token-match rate across all requests."""
    tot = sum(len(w) for w in ref)
    hit = sum(1 for a, b in zip(out, ref)
              for x, y in zip(a, b) if x == y)
    assert all(len(a) == len(b) for a, b in zip(out, ref))
    return hit / tot


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_int8_paged_token_match_rate(impl):
    """int8 paged engine vs the fp32 dense oracle on the standard mixed
    workload: greedy token-match rate >= 0.99 (in practice 1.0 on the
    smoke model -- int8 per-row dequant error rarely flips an argmax)."""
    cfg, _ = _model()
    wl = _workload(3, 6, cfg)
    ref = _dense_ref(3, 6)
    eng, out = _run(wl, slots=2, paged=True, cache_dtype="int8",
                    decode_impl=impl)
    assert _match_rate(out, ref) >= 0.99
    assert eng.pool.occupancy() == 0.0
    assert eng.cache_dtype == "int8"


def test_int8_schedules_eviction_sharing_cow():
    """Admission/eviction/COW schedules through the int8 pool: the same
    pressure configs the fp32 tests pin must still exercise sharing,
    COW and eviction, with token-match rate >= 0.99 vs the dense run."""
    cfg, _ = _model()
    # prefix sharing + COW (identical prompts)
    p = (np.arange(30) * 3 % cfg.vocab_size).astype(np.int32)
    wl = [(p.copy(), 4) for _ in range(3)]
    _, ref = _run(wl, slots=3)
    eng, out = _run(wl, slots=3, paged=True, pool_pages=24,
                    cache_dtype="int8")
    assert _match_rate(out, ref) >= 0.99
    assert eng.pool.stats.shared_maps > 0
    assert eng.pool.stats.cow_copies > 0
    # eviction under pool pressure
    wl = _workload(5, 8, cfg)
    ref = _dense_ref(5, 8)
    eng, out = _run(wl, slots=3, paged=True, pool_pages=10,
                    cache_dtype="int8")
    assert _match_rate(out, ref) >= 0.99
    assert eng.pool.stats.evictions > 0


def test_int8_preemption_swap_restores_bit_exact():
    """Swap-mode preemption snapshots int8 payloads WITH their per-row
    scales and restores them bit-exact: the preempted int8 run must
    produce EXACTLY the same tokens as a never-preempted int8 run (the
    int8 engine is schedule-independent, like the fp32 one)."""
    cfg, _ = _model()
    wl = _workload(7, 10, cfg)
    _, baseline = _run(wl, slots=4, paged=True, pool_pages=64,
                       cache_dtype="int8")
    eng, out = _run(wl, slots=4, paged=True, pool_pages=8, lookahead=4,
                    cache_dtype="int8")
    assert eng.preemptions > 0, "schedule no longer exercises preemption"
    assert out == baseline
    assert _match_rate(out, _dense_ref(7, 10)) >= 0.99


@given(st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=3, deadline=None)
@pytest.mark.slow
def test_property_int8_schedules_self_consistent(seed):
    """Property form for the quantized pool: ANY pool size / lookahead /
    budget combination yields the ample-pool int8 engine's exact greedy
    streams (schedule independence), and >= 0.99 of the dense fp32
    oracle's tokens."""
    cfg, _ = _model()
    rng = np.random.default_rng(seed)
    wl = _workload(seed % 97, 6, cfg)
    _, base = _run(wl, slots=2, paged=True, pool_pages=64,
                   cache_dtype="int8")
    _, ref = _run(wl, slots=2)
    kw = dict(slots=int(rng.integers(2, 5)),
              pool_pages=int(rng.integers(7, 20)),
              lookahead=int(rng.integers(0, 5)),
              token_budget=int(rng.integers(16, 64)))
    with _pool_check():
        _, out = _run(wl, paged=True, cache_dtype="int8", **kw)
    assert out == base, kw
    assert _match_rate(out, ref) >= 0.99, kw


def test_registry_keys_carry_dtype_identity():
    """Regression: prefix-registry keys must include the page's storage
    format -- the same tokens under different cache_dtype/quant_levels
    configs are different bytes and must never collide in a registry."""
    toks = np.arange(16, dtype=np.int32)
    pool_f = pc.PagePool(slots=1, max_len=64, nr=8, pool_pages=16)
    pool_q = pc.PagePool(slots=1, max_len=64, nr=8, pool_pages=16,
                         quant_levels=-1)
    pool_m = pc.PagePool(slots=1, max_len=64, nr=8, pool_pages=16,
                         quant_levels=1)      # fine int8, coarse fp32
    pool_f.admit(0, toks)
    pool_q.admit(0, toks)
    pool_m.admit(0, toks)
    kf, kq, km = (set(p.registry) for p in (pool_f, pool_q, pool_m))
    assert kf and kq and km
    assert not (kf & kq)                      # disjoint across dtypes
    # the mixed pool's fine keys match the int8 pool, coarse the fp32
    assert {k for k in km if k[0] == 0} == {k for k in kq if k[0] == 0}
    assert {k for k in km if k[0] > 0} == {k for k in kf if k[0] > 0}
    for key in pool_q.registry:
        assert key[1] == "int8:rowscale"
    for key in pool_f.registry:
        assert key[1] == "f32"


def test_int8_snapshot_restore_roundtrip_and_dtype_guard():
    """Pool-level swap snapshot of quantized pages restores payloads AND
    scales bit-exact into a fresh pool; restoring into a pool of a
    different cache_dtype raises instead of scattering garbage."""
    import jax.numpy as jnp
    from repro.core import h1d_decode as hd
    nr, Hkv, D = 8, 1, 4
    toks = np.arange(20, dtype=np.int32)

    def mk(quant_levels):
        pool = pc.PagePool(slots=1, max_len=64, nr=nr, pool_pages=8,
                           quant_levels=quant_levels)
        rows = [n * Hkv for n in pool.num_pages]
        if any(pool.quant):
            c = hd.init_quant_paged_pool(rows, nr, D, D,
                                         quant=tuple(pool.quant))
        else:
            c = hd.init_paged_pool(rows, nr, D, D)
        return pool, [c]                      # 1-layer, unstacked

    pool, caches = mk(-1)
    pool.admit(0, toks)
    key = jax.random.PRNGKey(0)
    c = caches[0]
    caches = [c._replace(
        k=jax.random.randint(key, c.k.shape, -127, 128, jnp.int8),
        v=jax.random.randint(key, c.v.shape, -127, 128, jnp.int8),
        ksc=jax.random.uniform(key, c.ksc.shape) + 0.5,
        vsc=jax.random.uniform(key, c.vsc.shape) + 0.5)]
    snap = pc.snapshot_slot(caches, pool, 0, Hkv, stacked=False)
    assert snap[0][3] is not None             # scales captured
    pool2, caches2 = mk(-1)
    caches2 = pc.restore_slot(caches2, pool2, 0, snap, Hkv,
                              stacked=False)
    for l in snap:
        src = np.nonzero(pool.table[l][0] >= 0)[0]
        dst = np.nonzero(pool2.table[l][0] >= 0)[0]
        np.testing.assert_array_equal(src, dst)
        sp = [int(pool.table[l][0, b]) for b in src]
        dp = [int(pool2.table[l][0, b]) for b in dst]
        a, b = caches[0], caches2[0]
        ak, av = (a.k, a.v) if l == 0 else (a.ck[l - 1], a.cv[l - 1])
        bk, bv = (b.k, b.v) if l == 0 else (b.ck[l - 1], b.cv[l - 1])
        asc = a.ksc if l == 0 else a.cksc[l - 1]
        bsc = b.ksc if l == 0 else b.cksc[l - 1]
        np.testing.assert_array_equal(np.asarray(ak)[sp],
                                      np.asarray(bk)[dp])
        np.testing.assert_array_equal(np.asarray(av)[sp],
                                      np.asarray(bv)[dp])
        np.testing.assert_array_equal(np.asarray(asc)[sp],
                                      np.asarray(bsc)[dp])
    pool3, caches3 = mk(0)                    # fp32 pool
    with pytest.raises(ValueError, match="dtype"):
        pc.restore_slot(caches3, pool3, 0, snap, Hkv, stacked=False)
