"""End-to-end system behaviour: the paper's drop-in claim, H1D vs dense
quality signal, and the dry-run tooling units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import ZipfLM
from repro.models import get_model
from repro.models.common import ModelConfig
from repro.train import TrainConfig, init_state, make_train_step


def test_h1d_is_drop_in_replacement():
    """Same config with attention=full vs h1d: identical param trees
    (the paper's drop-in claim, section 8)."""
    import dataclasses
    cfg_h = get_smoke_config("llama3.2-1b")
    cfg_f = dataclasses.replace(cfg_h, attention="full")
    fns = get_model(cfg_h)
    p1, s1 = fns.init(jax.random.PRNGKey(0), cfg_h)
    p2, s2 = fns.init(jax.random.PRNGKey(0), cfg_f)
    assert (jax.tree_util.tree_structure(p1)
            == jax.tree_util.tree_structure(p2))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_h1d_short_train_tracks_dense_attention():
    """Short LM training: H1D loss curve stays close to full attention
    (the quality claim at small scale)."""
    import dataclasses
    base = ModelConfig(name="tiny", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                       d_ff=128, vocab_size=256, attention="h1d", nr=8,
                       tie_embeddings=True)
    data = ZipfLM(vocab_size=256, seq_len=128, batch_per_host=8, seed=0)
    finals = {}
    for attn in ("h1d", "full"):
        cfg = dataclasses.replace(base, attention=attn)
        tc = TrainConfig(peak_lr=3e-3, warmup=5, total_steps=80)
        state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
        step = jax.jit(make_train_step(cfg, tc))
        for i in range(80):
            state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        finals[attn] = float(m["loss"])
    assert abs(finals["h1d"] - finals["full"]) < 0.35, finals


def test_parse_collectives_on_synthetic_hlo():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-reduce.1 = f32[1024,16]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,256]{1,0} all-gather(%y), replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp = f32[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %nothing = f32[2,2]{1,0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["result_bytes"] == 1024 * 16 * 4
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["result_bytes"] == 64 * 256 * 2
    assert out["collective-permute"]["result_bytes"] == 128 * 4
    # ring formula: AR with n=4 => 2*(3/4)*size
    assert abs(out["all-reduce"]["wire_bytes"]
               - 2 * 0.75 * 1024 * 16 * 4) < 1
    assert out["all-to-all"]["count"] == 0


def test_cache_shardings_heuristics():
    from repro.parallel import abstract_mesh, cache_shardings
    # spec-only: abstract mesh needs no real devices (version-compat
    # constructor: the AbstractMesh signature changed across jax 0.4/0.5)
    mesh = abstract_mesh((2, 2), ("data", "model"))
    big = jnp.zeros((8, 64, 4))       # batch-major, divisible by dp*tp
    small = jnp.zeros((3, 64, 4))     # not divisible -> replicated
    sh = cache_shardings(mesh, {"a": big, "b": small}, batch=8, kv_heads=1,
                         long_context=False)
    assert sh["a"].spec == jax.sharding.PartitionSpec(
        ("data",) + ("model",), None, None)
    assert sh["b"].spec == jax.sharding.PartitionSpec()
    # long-context: sequence axis shards over data
    seq = jnp.zeros((4, 128, 16))
    sh2 = cache_shardings(mesh, {"c": seq}, batch=1, kv_heads=4,
                          long_context=True)
    assert "data" in jax.tree_util.tree_leaves(
        [sh2["c"].spec[1]]) or sh2["c"].spec[1] == "data"


def test_input_specs_cover_all_cells():
    """Every (arch x shape) produces well-defined ShapeDtypeStructs."""
    from repro.configs import ARCH_IDS, SHAPES, get_smoke_config
    from repro.launch import specs as S
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        for shape, (seq, batch, kind) in SHAPES.items():
            seq_s, batch_s = 64, 2    # reduced sizes, same code path
            if kind == "train":
                specs = S.train_batch_specs(cfg, seq_s, batch_s)
                assert "tokens" in specs
            elif kind == "prefill":
                specs = S.prefill_batch_specs(cfg, seq_s, batch_s)
            else:
                caches, tok, t = S.decode_arg_specs(cfg, seq_s, batch_s)
                assert tok.shape == (batch_s,)
