"""Batched serving demo: continuous batching with hierarchical KV caches.

    PYTHONPATH=src python examples/serve_batched.py --arch llama3.2-1b
    PYTHONPATH=src python examples/serve_batched.py --paged --pool-pages 24
    PYTHONPATH=src python examples/serve_batched.py --paged --cache-dtype int8

Uses the reduced smoke config (random weights) to demonstrate the engine:
8 requests over 4 slots, greedy decoding, O(nr log L) attention per step.

``--paged`` swaps the dense per-slot caches for the paged hierarchical
cache pool (serve/paged_cache.py): requests sharing the demo's common
prompt prefix map the same physical pages (fine blocks AND their coarse
ancestor rows), pages are copy-on-write, and an undersized pool preempts
and requeues the newest request instead of failing -- same greedy tokens,
a fraction of the cache HBM.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import ServeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--decode-impl", default=None,
                    choices=["auto", "jnp", "pallas", "pallas_interpret"],
                    help="h1d decode tick backend (pallas = fused "
                         "single-launch kernels; 'auto' resolves per "
                         "backend)")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged cache pool with prefix "
                         "sharing + copy-on-write")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="pool size in nr-row pages (small values "
                         "exercise eviction/preemption)")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["fp32", "int8"],
                    help="paged page storage dtype (int8: per-row "
                         "scales, ~4x pages at fixed HBM)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable repro.obs metrics + spans and print a "
                         "summary (implied by --trace-out / --prom-out)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (load it at "
                         "ui.perfetto.dev)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition of the "
                         "final metrics")
    args = ap.parse_args()

    from repro import obs
    if args.telemetry or args.trace_out or args.prom_out:
        obs.enable()

    cfg = get_smoke_config(args.arch)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=128,
                      decode_impl=args.decode_impl, paged=args.paged,
                      pool_pages=args.pool_pages,
                      cache_dtype=args.cache_dtype)

    rng = np.random.default_rng(0)
    # a shared system-prompt prefix makes the paged pool's prefix
    # sharing visible in the stats line
    prefix = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            size=rng.integers(4, 16)).astype(np.int32)
        r = Request(uid=i, prompt=np.concatenate([prefix, tail]),
                    max_new_tokens=args.new_tokens)
        reqs.append(r)
        eng.submit(r)

    t0 = time.perf_counter()
    ticks = 0
    while eng.queue or eng.active.any():
        eng.step()
        ticks += 1
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s "
          f"({ticks} engine ticks, {total / dt:.1f} tok/s on CPU)")
    if args.paged:
        st = eng.pool.stats
        print(f"paged pool: shared_maps={st.shared_maps} "
              f"cow={st.cow_copies} evictions={st.evictions} "
              f"preemptions={eng.preemptions} "
              f"fresh_pages={st.fresh_pages} "
              f"prefix_hit_rate={st.prefix_hit_rate():.2f}")
    for r in reqs[:3]:
        print(f"  req {r.uid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> out={r.out_tokens[:8]}...")
    if obs.enabled():
        snap = obs.export.snapshot()
        c = snap["metrics"]["counters"]
        hbm = sum(v for k, v in c.items()
                  if k.startswith("kernel.hbm_"))
        print(f"telemetry: ticks={c.get('serve.ticks', 0)} "
              f"launches={sum(v for k, v in c.items() if k.startswith('kernel.launches'))} "
              f"analytic_hbm_bytes={hbm} "
              f"trace_events={snap['trace']['events']}")
        if args.trace_out:
            obs.export.write_trace(args.trace_out)
            print(f"telemetry: wrote Chrome trace -> {args.trace_out}")
        if args.prom_out:
            obs.export.write_prometheus(args.prom_out)
            print(f"telemetry: wrote Prometheus text -> {args.prom_out}")


if __name__ == "__main__":
    main()
