"""LRA-style long-sequence classification (paper section 8.1, ListOps):
train an H1D encoder classifier on synthetic ListOps and compare against
the dense-attention baseline.

    PYTHONPATH=src python examples/lra_classification.py --steps 150
"""
import argparse

from benchmarks.bench_lra_listops import base_cfg, train_classifier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()
    for name, cfg in [("h1d", base_cfg("h1d")), ("full", base_cfg("full"))]:
        acc, sps = train_classifier(cfg, seq_len=args.seq_len,
                                    n_steps=args.steps)
        print(f"{name:6s}: eval_acc={acc:.3f} ({sps*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
