"""Quickstart: hierarchical attention as a drop-in, then a tiny LM train.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import h1d_attention, dense_attention
from repro.models.common import ModelConfig
from repro.data import ZipfLM
from repro.train import TrainConfig, init_state, make_train_step


def demo_attention():
    print("== 1. H1D attention vs dense softmax attention ==")
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    B, L, D, nr = 2, 512, 64, 16
    q = jax.random.normal(k1, (B, 1, L, D))
    k = jax.random.normal(k2, (B, L, D))
    v = jax.random.normal(k3, (B, L, D))
    z_h = h1d_attention(q, k, v, nr=nr, causal=True, causal_mode="fine-q")
    z_d = dense_attention(q, k, v, causal=True)
    cos = jnp.sum(z_h * z_d) / (jnp.linalg.norm(z_h) * jnp.linalg.norm(z_d))
    print(f"  L={L}, N_r={nr}: cosine(H1D, dense) = {float(cos):.4f}")
    print(f"  attention work: H1D O(L*nr*logL) vs dense O(L^2) "
          f"= {L * nr * 10} vs {L * L} entries")


def demo_train():
    print("== 2. Train the paper's 53M-config (reduced) for 30 steps ==")
    cfg = ModelConfig(name="demo", family="dense", num_layers=2,
                      d_model=128, num_heads=8, num_kv_heads=8, head_dim=16,
                      d_ff=256, vocab_size=512, attention="h1d", nr=16,
                      tie_embeddings=True)
    tc = TrainConfig(peak_lr=3e-3, warmup=5, total_steps=30)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = ZipfLM(vocab_size=512, seq_len=256, batch_per_host=8, seed=0)
    for i in range(30):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        if i % 10 == 0 or i == 29:
            print(f"  step {i}: loss={float(m['loss']):.4f}")


if __name__ == "__main__":
    demo_attention()
    demo_train()
    print("done.")
