"""End-to-end driver: train a ~100M-parameter H-Transformer-1D LM (the
paper's 53M/144M family) for a few hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch h1d-lm-53m

Kill it mid-run and re-launch: it resumes from the last committed
checkpoint.  Use --mesh 2x2 etc. with
XLA_FLAGS=--xla_force_host_platform_device_count=4 to exercise the
sharded path on CPU.
"""
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--arch", "h1d-lm-53m", "--steps", "300", "--batch", "8",
                "--seq", "512", "--data", "hier", "--ckpt-every", "100",
                "--ckpt-dir", "checkpoints/h1d-lm-53m"]
    # user args override defaults
    train_main(defaults + args)
