#!/usr/bin/env python
"""CI telemetry smoke validator.

Validates the artifacts a ``--telemetry`` serve run wrote -- the Chrome
trace-event JSON and the Prometheus text exposition -- against the
pinned schemas in ``repro.obs.export`` (the same validators the unit
tests use, so CI and tests cannot drift apart).

    python scripts/check_telemetry.py --trace /tmp/trace.json \
        --prom /tmp/metrics.prom [--require-kernel-traffic]

Exits non-zero listing every schema violation.
"""
import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", required=True,
                    help="Chrome trace-event JSON written by --trace-out")
    ap.add_argument("--prom", required=True,
                    help="Prometheus text written by --prom-out")
    ap.add_argument("--require-kernel-traffic", action="store_true",
                    help="fail unless >= 1 kernel.launch instant event "
                         "carries the analytic HBM/FLOP args (needs a "
                         "kernel-path impl, e.g. --decode-impl "
                         "pallas_interpret on CPU)")
    args = ap.parse_args(argv)

    from repro.obs import export

    errs = []
    with open(args.trace) as f:
        doc = json.load(f)
    errs += [f"trace: {e}" for e in export.validate_chrome_trace(
        doc, require_kernel_traffic=args.require_kernel_traffic)]

    with open(args.prom) as f:
        text = f.read()
    required = ("repro_serve_ticks_total", "repro_serve_requests_total",
                "repro_serve_finished_total", "repro_serve_ttft_s_bucket")
    if args.require_kernel_traffic:
        required += ("repro_kernel_launches_total",
                     "repro_kernel_hbm_read_bytes_total",
                     "repro_kernel_flops_total")
    errs += [f"prom: {e}" for e in export.validate_prometheus_text(
        text, require_metrics=required)]

    if errs:
        for e in errs:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    n_ev = len(doc["traceEvents"])
    n_launch = sum(1 for e in doc["traceEvents"]
                   if e.get("name") == "kernel.launch")
    print(f"telemetry OK: {n_ev} trace events "
          f"({n_launch} kernel launches), prometheus text valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
