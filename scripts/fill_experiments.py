"""Fill EXPERIMENTS.md result tables from artifacts/dryrun and
artifacts/roofline.

    PYTHONPATH=src python scripts/fill_experiments.py
"""
import json
import os

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DRY = os.path.join(ROOT, "artifacts", "dryrun")
ROOF = os.path.join(ROOT, "artifacts", "roofline")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

ARCH_ORDER = ["yi-6b", "qwen2.5-14b", "llama3.2-1b", "gemma3-4b",
              "seamless-m4t-medium", "qwen2-moe-a2.7b", "arctic-480b",
              "llava-next-34b", "mamba2-1.3b", "zamba2-1.2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(n):
    if n >= 1e9:
        return f"{n / 1e9:.2f} GB"
    return f"{n / 1e6:.1f} MB"


def dryrun_table():
    rows = ["| arch | shape | mesh | status | temp/device | args/device |"
            " collective wire bytes/device (AG/AR/RS/A2A/CP) | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    n_ok = n_all = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                path = os.path.join(DRY, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(path):
                    continue
                with open(path) as f:
                    r = json.load(f)
                n_all += 1
                if not r.get("ok"):
                    rows.append(f"| {arch} | {shape} | {mesh} | FAIL "
                                f"({r.get('error', '?')[:60]}) | | | | |")
                    continue
                n_ok += 1
                mem = r.get("memory", {})
                c = r.get("collectives", {})
                wire = "/".join(
                    _fmt_bytes(c.get(k, {}).get("wire_bytes", 0))
                    for k in ("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute"))
                rows.append(
                    f"| {arch} | {shape} | {mesh} | OK | "
                    f"{_fmt_bytes(mem.get('temp_size_in_bytes', 0))} | "
                    f"{_fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
                    f"{wire} | {r.get('seconds', 0):.0f} |")
    header = (f"**{n_ok}/{n_all} cells compile** "
              f"(40 arch x shape cells x 2 meshes).\n\n")
    return header + "\n".join(rows)


def roofline_table(root=None):
    root = root or ROOF
    rows = ["| arch | shape | compute ms | memory ms | collective ms |"
            " dominant | useful ratio | what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("compute_s",): "more MXU-efficient tiles / lower remat recompute",
        ("memory_s",): "fuse banded attention (Pallas kernel path), "
                       "wider tiles to raise arithmetic intensity",
        ("collective_s",): "shard differently to cut resharding; overlap "
                           "collectives with compute; compress cross-pod",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            path = os.path.join(root, f"{arch}__{shape}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                r = json.load(f)
            if not r.get("ok"):
                rows.append(f"| {arch} | {shape} | FAIL: "
                            f"{r.get('error','?')[:50]} | | | | | |")
                continue
            t = r["terms_s"]
            dom = r["dominant"]
            hint = hints[(dom,)]
            rows.append(
                f"| {arch} | {shape} | {t['compute_s']*1e3:.2f} | "
                f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
                f"{dom.replace('_s','')} | {r['useful_ratio']:.2f} | "
                f"{hint} |")
    return "\n".join(rows)


def main():
    with open(EXP) as f:
        text = f.read()
    text = text.replace(
        "RESULTS_DRYRUN_TABLE (filled by scripts/fill_experiments.py)",
        dryrun_table())
    text = text.replace("RESULTS_DRYRUN_TABLE", dryrun_table())
    text = text.replace("RESULTS_ROOFLINE_TABLE", roofline_table())
    opt_dir = os.path.join(ROOT, "artifacts", "roofline_opt")
    if os.path.isdir(opt_dir) and os.listdir(opt_dir):
        text = text.replace(
            "RESULTS_ROOFLINE_OPT_TABLE",
            "#### §Roofline-optimized (post-hillclimb defaults, all 40 cells)\n\n"
            + roofline_table(opt_dir))
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
