"""Perf-iteration harness: measure roofline terms for one cell under
config overrides, for the hypothesis -> change -> measure loop.

    PYTHONPATH=src python scripts/perf_cell.py --arch yi-6b \
        --shape train_4k --tag nr32 --set nr=32 --set remat=false

Writes artifacts/roofline/<arch>__<shape>__<tag>.json and prints the
three terms + deltas vs the untagged baseline if present.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json


def parse_override(s):
    k, v = s.split("=", 1)
    if v.lower() in ("true", "false"):
        v = v.lower() == "true"
    else:
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch import roofline as rl

    cfg = get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rec = rl.analyze_cell(args.arch, args.shape, cfg=cfg,
                          tag=f"__{args.tag}")
    base_path = os.path.join(rl.ARTIFACT_DIR,
                             f"{args.arch}__{args.shape}.json")
    if rec.get("ok") and os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        if base.get("ok"):
            for k in ("compute_s", "memory_s", "collective_s"):
                b = base["terms_s"][k]
                n = rec["terms_s"][k]
                d = (n - b) / b * 100 if b else float("nan")
                print(f"  {k}: {b*1e3:.3f} -> {n*1e3:.3f} ms ({d:+.1f}%)")


if __name__ == "__main__":
    main()
