#!/usr/bin/env bash
# Single CI entry point:
#   1. docs reference check (no dangling *.md citations in src/),
#   2. tier-1 test suite (default selection: -m 'not slow'),
#   3. per-test wall-clock budget: any non-slow test whose call phase
#      exceeds 60 s fails the run (shrink it or mark it slow).
#
#   bash scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff) =="
# pyproject.toml carries the [tool.ruff] config; the container image may
# not ship a ruff binary (no network installs), so gate on its presence
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests scripts examples
else
    echo "ruff not installed; skipping lint"
fi

echo "== docs reference check =="
python scripts/check_docs.py

echo "== kernel launch-contract check =="
# statically verify every BlockSpec index map / output coverage / alias /
# scalar-prefetch domain over the full tuning candidate spaces
timeout 60 python -m repro.analysis.check

echo "== distributed ownership + paged-pool model check =="
# SP cross-shard ownership/halo/comm over mesh sizes 1..8 (zero
# devices) and a bounded exhaustive model check of the real PagePool
timeout 60 python -m repro.analysis.check --dist --pool

echo "== tier-1 tests (durations-budgeted) =="
report="$(mktemp)"
trap 'rm -f "$report"' EXIT
# --durations=0 reports every phase >= 5ms; the budget checker reads
# the 'call' rows.  pipefail propagates a pytest failure through tee.
python -m pytest -q --durations=0 "$@" | tee "$report"

echo "== per-test budget =="
python scripts/check_test_budget.py "$report" --budget 60

echo "== kernel launch-policy autotune smoke =="
# measured autotune round-trip on a tiny shape, against a throwaway
# cache dir so CI never touches (or depends on) ~/.cache/repro_tune;
# the second invocation proves the table survives a process boundary
# and is applied without re-measurement
tune_cache="$(mktemp -d)"
REPRO_TUNE_CACHE="$tune_cache" timeout 60 \
    python -m repro.kernels.tuning --autotune-smoke
REPRO_TUNE_CACHE="$tune_cache" timeout 60 \
    python -m repro.kernels.tuning --assert-cached

echo "== examples smoke (serve_batched, dense + paged + int8) =="
# tiny-config end-to-end smokes, held to the same 60 s budget each
timeout 60 python examples/serve_batched.py \
    --requests 4 --slots 2 --new-tokens 4 > /dev/null
timeout 60 python examples/serve_batched.py --paged --pool-pages 24 \
    --requests 4 --slots 2 --new-tokens 4 > /dev/null
timeout 60 python examples/serve_batched.py --paged --cache-dtype int8 \
    --pool-pages 24 --requests 4 --slots 2 --new-tokens 4 > /dev/null
echo "examples OK"

echo "== telemetry smoke (trace + prometheus vs pinned schemas) =="
# telemetry-enabled paged serve; pallas_interpret keeps the launch path
# (and therefore kernel.launch analytic-traffic events) live on CPU.
# The artifacts are validated by the SAME repro.obs.export validators
# the unit tests pin, so CI and tests cannot drift apart.
obs_dir="$(mktemp -d)"
timeout 60 python examples/serve_batched.py --paged --pool-pages 24 \
    --decode-impl pallas_interpret --requests 4 --slots 2 \
    --new-tokens 4 --telemetry --trace-out "$obs_dir/trace.json" \
    --prom-out "$obs_dir/metrics.prom" > /dev/null
timeout 60 python scripts/check_telemetry.py \
    --trace "$obs_dir/trace.json" --prom "$obs_dir/metrics.prom" \
    --require-kernel-traffic
