"""Fail if any test in a ``pytest --durations`` report exceeded the
per-test wall-clock budget (default 60 s for the ``call`` phase).

Usage::

    pytest -q --durations=0 | tee out.txt
    python scripts/check_test_budget.py out.txt [--budget 60]

Run via ``scripts/ci.sh``.  The budget applies to the default
(``-m 'not slow'``) selection: anything heavier belongs behind the
``slow`` marker (see pyproject.toml).
"""
import argparse
import re
import sys

# "   12.34s call     tests/test_foo.py::test_bar[param]"
_DURATION = re.compile(r"^\s*(\d+(?:\.\d+)?)s\s+(call|setup|teardown)\s+(\S+)")


def over_budget(lines, budget: float):
    out = []
    for line in lines:
        m = _DURATION.match(line)
        if m and m.group(2) == "call" and float(m.group(1)) > budget:
            out.append((float(m.group(1)), m.group(3)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="captured pytest output")
    ap.add_argument("--budget", type=float, default=60.0,
                    help="max seconds per test call phase")
    args = ap.parse_args(argv)
    with open(args.report, encoding="utf-8") as f:
        offenders = over_budget(f, args.budget)
    if offenders:
        print(f"FAIL: {len(offenders)} test(s) over the "
              f"{args.budget:.0f}s budget:")
        for secs, test in sorted(offenders, reverse=True):
            print(f"  {secs:8.2f}s  {test}")
        print("Shrink the test or move it behind @pytest.mark.slow.")
        return 1
    print(f"test budget OK (no call over {args.budget:.0f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
