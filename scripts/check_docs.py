"""Fail if any file under src/ cites a repo-root markdown file that does
not exist (e.g. a docstring pointing at DESIGN.md section 2), or if
README/DESIGN/EXPERIMENTS cite a ``src/**/*.py`` / ``tests/**/*.py``
path that does not exist (a renamed module whose docs went stale).

Run directly::

    python scripts/check_docs.py

or via the default pytest run (tests/test_docs.py wires it in), so a PR
that adds a ``SOMETHING.md`` reference without the file fails CI.
"""
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")

#: root docs whose code-path citations must resolve
DOCS = ("README.md", "DESIGN.md", "EXPERIMENTS.md")

# bare repo-root markdown names: FOO.md / foo_bar.md, but not paths like
# docs/foo.md (those are checked relative to the repo root anyway).
_MD_REF = re.compile(r"(?<![\w/.-])([A-Za-z][\w.-]*\.md)\b")


def md_references(path):
    """Yield (lineno, name) for every repo-root *.md cited in ``path``."""
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for name in _MD_REF.findall(line):
                yield lineno, name


def missing_references(src_dir=SRC, root=ROOT):
    """Return [(file, lineno, name)] for cited-but-absent markdown files."""
    missing = []
    for dirpath, _, files in os.walk(src_dir):
        for fn in sorted(files):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            for lineno, name in md_references(path):
                if not os.path.exists(os.path.join(root, name)):
                    missing.append((os.path.relpath(path, root), lineno, name))
    return missing


# code paths cited in the docs: src/... or tests/....py, optionally
# with a trailing :symbol / :lineno qualifier (stripped before lookup)
_PY_REF = re.compile(r"\b((?:src|tests)/[\w./-]+\.py)\b")


def missing_code_paths(root=ROOT, docs=DOCS):
    """Return [(doc, lineno, path)] for cited-but-absent code files."""
    missing = []
    for doc in docs:
        doc_path = os.path.join(root, doc)
        if not os.path.exists(doc_path):
            continue
        with open(doc_path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for ref in _PY_REF.findall(line):
                    if not os.path.exists(os.path.join(root, ref)):
                        missing.append((doc, lineno, ref))
    return missing


def main():
    missing = missing_references()
    for path, lineno, name in missing:
        print(f"{path}:{lineno}: references {name}, which does not exist "
              f"at the repo root")
    stale = missing_code_paths()
    for doc, lineno, ref in stale:
        print(f"{doc}:{lineno}: references {ref}, which does not exist")
    if missing or stale:
        print(f"{len(missing) + len(stale)} dangling doc reference(s)")
        return 1
    print("all repo-root markdown and doc code-path references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
