"""Shared benchmark utilities.

Latency bookkeeping runs on the shared telemetry histogram
(``repro.obs.Histogram``) instead of bespoke ``np.percentile`` code:
with ``keep_samples`` >= the iteration count the reservoir holds every
observation, so :meth:`~repro.obs.metrics.Histogram.quantile` is the
exact order statistic -- medians/percentiles are bit-identical to the
old ``np.median``/``np.percentile`` bookkeeping and the committed
BENCH_*.json baselines stay valid.
"""
import os
import time

import jax

from repro.obs import Histogram


def scale() -> float:
    """BENCH_SCALE env knob: 1.0 = default (CI-sized), larger = closer to
    paper scale."""
    return float(os.environ.get("BENCH_SCALE", "1.0"))


def steps(n: int) -> int:
    return max(10, int(n * scale()))


def time_hist(fn, *args, iters=5, warmup=2) -> Histogram:
    """Time ``iters`` blocking calls into an exact-quantile histogram
    (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    h = Histogram(keep_samples=max(int(iters), 1))
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        h.observe(time.perf_counter() - t0)
    assert h.exact, "keep_samples must cover iters for exact quantiles"
    return h


def time_fn(fn, *args, iters=5, warmup=2):
    """Median microseconds per call (exact -- see :func:`time_hist`)."""
    return time_hist(fn, *args, iters=iters, warmup=warmup).quantile(0.5) \
        * 1e6   # us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
