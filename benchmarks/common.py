"""Shared benchmark utilities."""
import os
import time

import jax
import numpy as np


def scale() -> float:
    """BENCH_SCALE env knob: 1.0 = default (CI-sized), larger = closer to
    paper scale."""
    return float(os.environ.get("BENCH_SCALE", "1.0"))


def steps(n: int) -> int:
    return max(10, int(n * scale()))


def time_fn(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
