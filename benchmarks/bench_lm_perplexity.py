"""Table-2 proxy (One-Billion-Word LM): test perplexity of an H1D
(N_r=16) decoder LM vs the quadratic-attention baseline at matched
parameter count, on the synthetic hierarchical corpus.

Reproduces the paper's *relative* claim: H1D attention matches (or beats)
the dense-attention baseline perplexity with identical capacity, at
linear cost.  (Absolute 1B-word numbers need the real corpus, offline
container => synthetic corpus with planted long-range structure.)
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import HierarchicalLM
from repro.models.common import ModelConfig
from repro.models import get_model
from repro.train import TrainConfig, init_state, make_train_step

from .common import steps, emit


def lm_cfg(attention: str, causal_mode="fine-q"):
    return ModelConfig(
        name=f"lm-{attention}", family="dense", num_layers=2, d_model=128,
        num_heads=8, num_kv_heads=8, head_dim=16, d_ff=512, vocab_size=512,
        attention=attention, nr=16, causal_mode=causal_mode,
        tie_embeddings=True)


def train_lm(cfg, n_steps, seq=256, batch=8, seed=0):
    tc = TrainConfig(peak_lr=3e-3, warmup=max(5, n_steps // 20),
                     total_steps=n_steps, ckpt_every=0)
    state, _ = init_state(jax.random.PRNGKey(seed), cfg, tc)
    step = jax.jit(make_train_step(cfg, tc))
    data = HierarchicalLM(vocab_size=cfg.vocab_size, seq_len=seq,
                          batch_per_host=batch, seed=seed)
    t0 = time.perf_counter()
    for i in range(n_steps):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
    dt = (time.perf_counter() - t0) / n_steps
    # held-out perplexity
    fns = get_model(cfg)
    eval_data = HierarchicalLM(vocab_size=cfg.vocab_size, seq_len=seq,
                               batch_per_host=16, seed=seed + 77)
    nll = 0.0
    ntok = 0.0
    for j in range(4):
        b = jax.tree.map(jnp.asarray, eval_data.batch(j))
        loss, metrics = fns.loss(state.params, cfg, b)
        nll += float(metrics["nll"]) * float(metrics["ntok"])
        ntok += float(metrics["ntok"])
    ppl = float(np.exp(nll / ntok))
    return ppl, dt


def run():
    n = steps(120)
    out = {}
    for name, cfg in [
        ("h1d_nr16", lm_cfg("h1d")),
        ("h1d_nr16_coarseq", lm_cfg("h1d", causal_mode="coarse-q")),
        ("full_baseline", lm_cfg("full")),
    ]:
        ppl, s_per_step = train_lm(cfg, n)
        out[name] = ppl
        emit(f"table2_ppl_{name}", s_per_step * 1e6, f"test_ppl={ppl:.2f}")
    emit("table2_ppl_h1d_vs_full", 0.0,
         f"ratio={out['h1d_nr16'] / out['full_baseline']:.3f}")
    return out


if __name__ == "__main__":
    run()
