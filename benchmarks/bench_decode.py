"""Decode-path microbenchmarks: one serving tick (hierarchical-KV
ancestor update + O(nr log L) attend) per backend, reported as tokens/s
per slot count.

``impl='jnp'`` is the oracle path (one-hot block reads: every band
streams the whole cache level, ~2(M+1) einsum launches per tick);
``impl='pallas'`` (TPU backends only) runs the two fused single-launch
kernels from ``kernels/h1d_decode_kernel`` -- one nr-row HBM read per
needed block (EXPERIMENTS.md P25).  Interpret-mode allclose checks
verify the kernel semantics at bench shapes on any backend.

``--json out.json`` (default name BENCH_decode.json via ``--json``
alone) writes every row as machine-readable JSON so the decode perf
trajectory across PRs can be diffed by tooling.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import h1d_decode as hd

from .common import time_fn, emit

NR, D, G, HKV = 16, 64, 4, 2


def _tick(impl):
    """One decode tick: append the token's K/V (+ ancestors), attend."""
    def f(cache, q, kn, vn, t):
        cache = hd.update_cache(cache, kn, vn, t, impl=impl)
        z = hd.decode_attend(cache, q, t, nr=NR, impl=impl)
        return z, cache
    return f


def _inputs(Lmax, slots, seed=0):
    R = slots * HKV
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    cache = hd.prefill_cache(jax.random.normal(ks[0], (R, Lmax, D)),
                             jax.random.normal(ks[1], (R, Lmax, D)),
                             Lmax, NR)
    q = jax.random.normal(ks[2], (R, G, D))
    kn = jax.random.normal(ks[3], (R, D))
    vn = jax.random.normal(ks[4], (R, D))
    t = jnp.asarray(np.random.default_rng(seed).integers(
        NR, Lmax, size=R).astype(np.int32))
    return cache, q, kn, vn, t


def run(json_path=None):
    impls = ["jnp"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")

    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    for Lmax in (256, 1024):
        for slots in (1, 8, 32):
            args = _inputs(Lmax, slots)
            for impl in impls:
                step = jax.jit(_tick(impl))
                us = time_fn(step, *args, iters=5, warmup=2)
                tok_s = slots * 1e6 / us
                record(f"decode_L{Lmax}_s{slots}_{impl}", us,
                       f"tok_s={tok_s:.0f}")

    # interpret-mode correctness at a reduced shape: the exact kernel
    # programs vs the jnp oracle (attend allclose, update bit-exact).
    cache, q, kn, vn, t = _inputs(256, 2, seed=1)
    z_ref, c_ref = _tick("jnp")(cache, q, kn, vn, t)
    z_ker, c_ker = _tick("pallas_interpret")(cache, q, kn, vn, t)
    err_a = float(jnp.abs(z_ker - z_ref).max())
    err_u = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)))
    record("decode_pallas_interpret_attend_allclose", 0.0,
           f"max_err={err_a:.2e}")
    record("decode_pallas_interpret_update_allclose", 0.0,
           f"max_err={err_u:.2e}")
    assert err_a < 1e-5 and err_u == 0.0

    if json_path:
        from repro.kernels.tuning import get_policy
        payload = {"bench": "decode",
                   "shape": {"nr": NR, "d": D, "G": G, "Hkv": HKV},
                   "backend": jax.default_backend(),
                   "tuning_digest": get_policy().tuning_digest(),
                   "xla_flags": os.environ.get("XLA_FLAGS", ""),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path} ({len(rows)} rows)")
    return {"err_attend": err_a, "err_update": err_u}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_decode.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default name "
                         "BENCH_decode.json)")
    args = ap.parse_args()
    run(json_path=args.json)
