"""Benchmark harness: one benchmark per paper table/figure + kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table2,...]

  table1   -- LRA ListOps proxy (H1D vs full vs local attention)
  table2   -- LM test perplexity at matched params (H1D N_r=16 vs dense)
  scaling  -- run-time vs L: the O(L) vs O(L^2) claim (section 7)
  kernels  -- banded block-attention kernel microbench + allclose
  decode   -- serving tick (hierarchical-KV update + attend) tokens/s
  serve    -- continuous batching under Poisson traffic: dense slots vs
              paged cache pool at fixed HBM (tok/s, p50/p99, occupancy)
  roofline -- summary of artifacts/roofline (if the dry-run ran)
"""
import argparse
import json
import os
import sys
import traceback


def bench_roofline():
    from repro.launch import roofline as rl
    adir = rl.ARTIFACT_DIR
    if not os.path.isdir(adir) or not os.listdir(adir):
        print("roofline_summary,0.0,skipped(no artifacts; run "
              "python -m repro.launch.roofline)")
        return
    n = ok = 0
    worst = (None, 1e9)
    for f in sorted(os.listdir(adir)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(adir, f)) as fh:
            r = json.load(fh)
        if r.get("tag"):
            continue
        n += 1
        if r.get("ok"):
            ok += 1
            t = r["terms_s"]
            peak = max(t.values())
            frac = t["compute_s"] / peak if peak else 0.0
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{peak*1e6:.1f},compute_frac={frac:.2f} "
                  f"dom={r['dominant'].replace('_s','')}")
            if frac < worst[1]:
                worst = (f"{r['arch']}__{r['shape']}", frac)
    print(f"roofline_cells,0.0,ok={ok}/{n} worst_compute_frac="
          f"{worst[1]:.2f}@{worst[0]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,scaling,kernels,"
                         "decode,serve,roofline")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json for benches that "
                         "support machine-readable payloads")
    args, _ = ap.parse_known_args()
    want = set(args.only.split(",")) if args.only else None
    json_benches = {"kernels", "decode", "serve", "scaling"}

    def on(name):
        return want is None or name in want

    print("name,us_per_call,derived")
    failures = 0
    jobs = []
    if on("kernels"):
        from benchmarks.bench_kernels import run as r
        jobs.append(("kernels", r))
    if on("decode"):
        from benchmarks.bench_decode import run as r
        jobs.append(("decode", r))
    if on("serve"):
        from benchmarks.bench_serve import run as r
        jobs.append(("serve", r))
    if on("scaling"):
        from benchmarks.bench_scaling import run as r
        jobs.append(("scaling", r))
    if on("table2"):
        from benchmarks.bench_lm_perplexity import run as r
        jobs.append(("table2", r))
    if on("table1"):
        from benchmarks.bench_lra_listops import run as r
        jobs.append(("table1", r))
    if on("roofline"):
        jobs.append(("roofline", bench_roofline))
    for name, fn in jobs:
        try:
            if args.json and name in json_benches:
                fn(json_path=f"BENCH_{name}.json")
            else:
                fn()
        except Exception as e:
            failures += 1
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
