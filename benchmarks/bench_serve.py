"""Serve-path benchmark: continuous batching under synthetic Poisson
traffic, dense slot cache vs paged cache pool at FIXED cache HBM.

Workload: ``--requests`` arrivals with exponential inter-arrival times
(measured in engine ticks, seeded), each prompt = one SHARED prefix of
``--prefix`` tokens (the system-prompt pattern that paged prefix
sharing exploits) plus a unique tail.  Both engines run the identical
request list; reported per engine:

* tokens/s (wall-clock) and us per generated token;
* p50 / p99 request latency in engine ticks (completion - arrival);
* peak admitted concurrency;
* paged only: pool occupancy peak + sharing / COW / eviction /
  preemption counters.

The headline comparison fixes the cache-HBM budget at the DENSE
engine's cache footprint and gives the paged engine whatever pool fits
the same bytes: prefix sharing + on-demand page allocation admit >= 2x
the concurrent requests (EXPERIMENTS.md P27).

``--json out.json`` (default name BENCH_serve.json via ``--json``
alone) writes every row as machine-readable JSON so the serve perf
trajectory across PRs can be diffed by tooling.
"""
import argparse
import json
import os
import time

import jax
import numpy as np

from .common import emit

ARCH = "llama3.2-1b"
MAX_LEN = 128
DENSE_SLOTS = 2
PAGED_SLOTS = 8
NEW_TOKENS = 8


def _build(cfg, params, paged, pool_pages, decode_impl=None):
    from repro.serve import ServeEngine
    kw = dict(slots=PAGED_SLOTS if paged else DENSE_SLOTS,
              max_len=MAX_LEN, decode_impl=decode_impl)
    if paged:
        kw.update(paged=True, pool_pages=pool_pages, lookahead=4)
    return ServeEngine(cfg, params, **kw)


def _workload(cfg, n, prefix_len, seed=0, rate=2.0):
    """(arrival_tick, prompt, max_new) triples; shared prefix + unique
    tail, Poisson arrivals at ``rate`` requests/tick."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 8))).astype(np.int32)
        out.append((int(t), np.concatenate([prefix, tail]), NEW_TOKENS))
    return out


def _drive(eng, workload):
    """Tick loop with arrivals; returns (wall_s, ticks, latencies,
    peak_concurrency, peak_occupancy, total_tokens)."""
    from repro.serve import Request
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=m)
            for i, (_, p, m) in enumerate(workload)]
    arrivals = [a for a, _, _ in workload]
    done_at = {}
    pending = list(range(len(reqs)))
    tick = 0
    peak_c = 0
    peak_occ = 0.0
    t0 = time.perf_counter()
    submitted = set()
    while pending or eng.queue or eng.active.any():
        while pending and arrivals[pending[0]] <= tick:
            submitted.add(pending[0])
            eng.submit(reqs[pending.pop(0)])
        eng.step()
        peak_c = max(peak_c, int(eng.active.sum()))
        if eng.pool is not None:
            peak_occ = max(peak_occ, eng.pool.occupancy())
        # done = has left both the queue and every slot (covers early
        # termination via stop tokens or a full cache, where
        # len(out_tokens) never reaches max_new_tokens)
        in_flight = {id(e.req) for e in eng.queue}
        in_flight |= {id(r) for r in eng.req if r is not None}
        for i in submitted:
            if i not in done_at and id(reqs[i]) not in in_flight:
                done_at[i] = tick
        tick += 1
    wall = time.perf_counter() - t0
    lat = np.array([done_at[i] - arrivals[i] for i in range(len(reqs))])
    total = sum(len(r.out_tokens) for r in reqs)
    return wall, tick, lat, peak_c, peak_occ, total, \
        [r.out_tokens for r in reqs]


def run(json_path=None, requests=12, prefix_len=64):
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serve import paged_cache as pc

    cfg = get_smoke_config(ARCH)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    wl = _workload(cfg, requests, prefix_len)

    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    # fixed-HBM budget: the dense engine's total cache bytes
    dense = _build(cfg, params, paged=False, pool_pages=None)
    dense_bytes = pc.pool_bytes(dense.caches)
    # largest paged pool that fits the same bytes (the hierarchy's
    # coarse pools ride along, so usable fine pages exceed the naive
    # slots * Lmax/nr equivalence)
    pool_pages = 4 * DENSE_SLOTS * (MAX_LEN // cfg.nr)
    while pool_pages > 1:
        probe = _build(cfg, params, paged=True, pool_pages=pool_pages)
        if pc.pool_bytes(probe.caches) <= dense_bytes:
            break
        pool_pages -= 1
    del probe

    wall, ticks, lat, conc_d, _, total_d, out_d = _drive(dense, wl)
    record("serve_dense_tok_s", wall / max(total_d, 1) * 1e6,
           f"tok_s={total_d / wall:.1f} ticks={ticks} "
           f"concurrency={conc_d}")
    record("serve_dense_latency", float(np.percentile(lat, 50)) * 1e6,
           f"p50_ticks={np.percentile(lat, 50):.0f} "
           f"p99_ticks={np.percentile(lat, 99):.0f}")

    paged = _build(cfg, params, paged=True, pool_pages=pool_pages)
    wall, ticks, lat, conc_p, occ, total_p, out_p = _drive(paged, wl)
    st = paged.pool.stats
    record("serve_paged_tok_s", wall / max(total_p, 1) * 1e6,
           f"tok_s={total_p / wall:.1f} ticks={ticks} "
           f"concurrency={conc_p} pool_occupancy_peak={occ:.2f}")
    record("serve_paged_latency", float(np.percentile(lat, 50)) * 1e6,
           f"p50_ticks={np.percentile(lat, 50):.0f} "
           f"p99_ticks={np.percentile(lat, 99):.0f}")
    record("serve_paged_pool", 0.0,
           f"pages={pool_pages} shared={st.shared_maps} "
           f"cow={st.cow_copies} evict={st.evictions} "
           f"preempt={paged.preemptions}")
    record("serve_concurrency_fixed_hbm", 0.0,
           f"dense={conc_d} paged={conc_p} "
           f"ratio={conc_p / max(conc_d, 1):.1f} "
           f"hbm_bytes={dense_bytes}")
    # greedy parity guard: the baseline must never record a paged
    # engine that drifts from the dense oracle
    match = out_d == out_p
    record("serve_paged_token_parity", 0.0, f"identical={match}")
    assert match, "paged token stream diverged from dense oracle"
    assert conc_p >= 2 * conc_d, (
        f"paged concurrency {conc_p} < 2x dense {conc_d} at fixed HBM")

    if json_path:
        payload = {"bench": "serve",
                   "shape": {"arch": ARCH, "max_len": MAX_LEN,
                             "nr": cfg.nr, "requests": requests,
                             "prefix_len": prefix_len,
                             "dense_slots": DENSE_SLOTS,
                             "paged_slots": PAGED_SLOTS,
                             "new_tokens": NEW_TOKENS},
                   "backend": jax.default_backend(),
                   "xla_flags": os.environ.get("XLA_FLAGS", ""),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default name "
                         "BENCH_serve.json)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prefix", type=int, default=64)
    args = ap.parse_args()
    run(json_path=args.json, requests=args.requests,
        prefix_len=args.prefix)
