"""Serve-path benchmark: continuous batching under synthetic Poisson
traffic, dense slot cache vs paged cache pool at FIXED cache HBM.

Workload: ``--requests`` arrivals with exponential inter-arrival times
(measured in engine ticks, seeded), each prompt = one SHARED prefix of
``--prefix`` tokens (the system-prompt pattern that paged prefix
sharing exploits) plus a unique tail.  Both engines run the identical
request list; reported per engine:

* tokens/s (wall-clock) and us per generated token;
* p50 / p99 request latency in engine ticks (completion - arrival);
* peak admitted concurrency;
* paged only: pool occupancy peak + sharing / COW / eviction /
  preemption counters.

The headline comparison fixes the cache-HBM budget at the DENSE
engine's cache footprint and gives the paged engine whatever pool fits
the same bytes: prefix sharing + on-demand page allocation admit >= 2x
the concurrent requests (EXPERIMENTS.md P27).

The same fixed-HBM budget is then handed to the int8-quantized pool
(``cache_dtype='int8'``, per-row scales): ~3x the pages of the fp32
pool, so another >= 1.5x concurrency on top of the fp32 paged engine --
reported together with the quality side of that trade as a
concurrency-vs-quality curve: greedy token-match rate vs the dense
fp32 oracle, per-level max dequantization error on real cache content,
and cache bytes per dtype (EXPERIMENTS.md P28).

``--json out.json`` (default name BENCH_serve.json via ``--json``
alone) writes every row as machine-readable JSON so the serve perf
trajectory across PRs can be diffed by tooling.
"""
import argparse
import json
import os
import time

import jax
import numpy as np

from repro.obs import Histogram

from .common import emit


def _tuning_digest():
    from repro.kernels.tuning import get_policy
    return get_policy().tuning_digest()

ARCH = "llama3.2-1b"
MAX_LEN = 128
DENSE_SLOTS = 2
PAGED_SLOTS = 8
INT8_SLOTS = 16
NEW_TOKENS = 8


def _build(cfg, params, paged, pool_pages, decode_impl=None,
           cache_dtype=None, slots=None):
    from repro.serve import ServeEngine
    if slots is None:
        slots = PAGED_SLOTS if paged else DENSE_SLOTS
    kw = dict(slots=slots, max_len=MAX_LEN, decode_impl=decode_impl)
    if paged:
        kw.update(paged=True, pool_pages=pool_pages, lookahead=4,
                  cache_dtype=cache_dtype)
    return ServeEngine(cfg, params, **kw)


def _workload(cfg, n, prefix_len, seed=0, rate=2.0):
    """(arrival_tick, prompt, max_new) triples; shared prefix + unique
    tail, Poisson arrivals at ``rate`` requests/tick."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 8))).astype(np.int32)
        out.append((int(t), np.concatenate([prefix, tail]), NEW_TOKENS))
    return out


def _drive(eng, workload):
    """Tick loop with arrivals; returns (wall_s, ticks, latencies,
    peak_concurrency, peak_occupancy, total_tokens)."""
    from repro.serve import Request
    reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=m)
            for i, (_, p, m) in enumerate(workload)]
    arrivals = [a for a, _, _ in workload]
    done_at = {}
    pending = list(range(len(reqs)))
    tick = 0
    peak_c = 0
    peak_occ = 0.0
    t0 = time.perf_counter()
    submitted = set()
    while pending or eng.queue or eng.active.any():
        while pending and arrivals[pending[0]] <= tick:
            submitted.add(pending[0])
            eng.submit(reqs[pending.pop(0)])
        eng.step()
        peak_c = max(peak_c, int(eng.active.sum()))
        if eng.pool is not None:
            peak_occ = max(peak_occ, eng.pool.occupancy())
        # done = has left both the queue and every slot (covers early
        # termination via stop tokens or a full cache, where
        # len(out_tokens) never reaches max_new_tokens)
        in_flight = {id(e.req) for e in eng.queue}
        in_flight |= {id(r) for r in eng.req if r is not None}
        for i in submitted:
            if i not in done_at and id(reqs[i]) not in in_flight:
                done_at[i] = tick
        tick += 1
    wall = time.perf_counter() - t0
    # request latency (ticks) on the shared telemetry histogram: the
    # reservoir covers every request, so quantiles are the exact order
    # statistics the old np.percentile bookkeeping computed
    lat = Histogram("serve.request_latency_ticks",
                    keep_samples=max(len(reqs), 1))
    for i in range(len(reqs)):
        lat.observe(done_at[i] - arrivals[i])
    total = sum(len(r.out_tokens) for r in reqs)
    return wall, tick, lat, peak_c, peak_occ, total, \
        [r.out_tokens for r in reqs]


def run(json_path=None, requests=12, prefix_len=64):
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serve import paged_cache as pc

    cfg = get_smoke_config(ARCH)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    wl = _workload(cfg, requests, prefix_len)

    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    # fixed-HBM budget: the dense engine's total cache bytes
    dense = _build(cfg, params, paged=False, pool_pages=None)
    dense_bytes = pc.pool_bytes(dense.caches)

    def _paged_bytes(pages, quant_levels=0):
        """Cache bytes for a paged pool WITHOUT building an engine
        (pool geometry alone fixes the footprint)."""
        pool = pc.PagePool(slots=PAGED_SLOTS, max_len=MAX_LEN, nr=cfg.nr,
                           pool_pages=pages, quant_levels=quant_levels)
        return pc.pool_bytes(pc.init_paged_caches(cfg, pool))

    def _fit_pages(quant_levels=0, start=1):
        """Largest pool that fits the dense budget (the hierarchy's
        coarse pools ride along, so usable fine pages exceed the naive
        slots * Lmax/nr equivalence; int8 pools fit ~3x more)."""
        pages = start
        while _paged_bytes(pages + 1, quant_levels) <= dense_bytes:
            pages += 1
        return pages

    pool_pages = _fit_pages(quant_levels=0)

    wall, ticks, lat, conc_d, _, total_d, out_d = _drive(dense, wl)
    record("serve_dense_tok_s", wall / max(total_d, 1) * 1e6,
           f"tok_s={total_d / wall:.1f} ticks={ticks} "
           f"concurrency={conc_d}")
    record("serve_dense_latency", lat.quantile(0.5) * 1e6,
           f"p50_ticks={lat.quantile(0.5):.0f} "
           f"p99_ticks={lat.quantile(0.99):.0f}")

    paged = _build(cfg, params, paged=True, pool_pages=pool_pages)
    wall, ticks, lat, conc_p, occ, total_p, out_p = _drive(paged, wl)
    st = paged.pool.stats
    record("serve_paged_tok_s", wall / max(total_p, 1) * 1e6,
           f"tok_s={total_p / wall:.1f} ticks={ticks} "
           f"concurrency={conc_p} pool_occupancy_peak={occ:.2f}")
    record("serve_paged_latency", lat.quantile(0.5) * 1e6,
           f"p50_ticks={lat.quantile(0.5):.0f} "
           f"p99_ticks={lat.quantile(0.99):.0f}")
    record("serve_paged_pool", 0.0,
           f"pages={pool_pages} shared={st.shared_maps} "
           f"cow={st.cow_copies} evict={st.evictions} "
           f"preempt={paged.preemptions}")
    record("serve_prefix_hit_rate", 0.0,
           f"hit_rate={st.prefix_hit_rate():.3f} "
           f"hits={st.prefix_hits} misses={st.prefix_misses}")
    record("serve_concurrency_fixed_hbm", 0.0,
           f"dense={conc_d} paged={conc_p} "
           f"ratio={conc_p / max(conc_d, 1):.1f} "
           f"hbm_bytes={dense_bytes}")
    # greedy parity guard: the baseline must never record a paged
    # engine that drifts from the dense oracle
    match = out_d == out_p
    record("serve_paged_token_parity", 0.0, f"identical={match}")
    assert match, "paged token stream diverged from dense oracle"
    assert conc_p >= 2 * conc_d, (
        f"paged concurrency {conc_p} < 2x dense {conc_d} at fixed HBM")

    # --- int8 quantized pool at the SAME fixed HBM budget ----------------
    # (concurrency-vs-quality curve: what the extra pages buy, what the
    # quantization costs)
    import jax.numpy as jnp
    from repro.core import quantization as qz

    int8_pages = _fit_pages(quant_levels=-1, start=pool_pages)
    quant = _build(cfg, params, paged=True, pool_pages=int8_pages,
                   cache_dtype="int8", slots=INT8_SLOTS)
    wall, ticks, lat, conc_q, occ_q, total_q, out_q = _drive(quant, wl)
    stq = quant.pool.stats
    record("serve_paged_int8_tok_s", wall / max(total_q, 1) * 1e6,
           f"tok_s={total_q / wall:.1f} ticks={ticks} "
           f"concurrency={conc_q} pool_occupancy_peak={occ_q:.2f}")
    record("serve_paged_int8_latency", lat.quantile(0.5) * 1e6,
           f"p50_ticks={lat.quantile(0.5):.0f} "
           f"p99_ticks={lat.quantile(0.99):.0f}")
    record("serve_paged_int8_pool", 0.0,
           f"pages={int8_pages} shared={stq.shared_maps} "
           f"cow={stq.cow_copies} evict={stq.evictions} "
           f"preempt={quant.preemptions}")

    # quality: greedy token-match rate vs the dense fp32 oracle
    tot = sum(len(w) for w in out_d)
    hit = sum(1 for a, b in zip(out_q, out_d)
              for x, y in zip(a, b) if x == y)
    rate = hit / max(tot, 1)
    record("serve_quality_int8_match", 0.0,
           f"match_rate={rate:.4f} tokens={tot}")
    assert rate >= 0.99, (
        f"int8 token-match rate {rate:.4f} < 0.99 vs dense oracle")

    # quality: per-level max dequantization error on the REAL cache
    # content the dense run produced (coarse k rows are pairwise means
    # -> shrinking dynamic range; coarse v rows are pairwise sums)
    cs = dense.caches if isinstance(dense.caches, list) else [dense.caches]
    lvl_err = []
    for l in range(1 + len(cs[0].ck)):
        e = 0.0
        for c in cs:
            for x in ((c.k, c.v) if l == 0
                      else (c.ck[l - 1], c.cv[l - 1])):
                x = jnp.asarray(x)
                q8, s8 = qz.quantize_int8(x, axis=-1)
                e = max(e, float(jnp.max(jnp.abs(
                    qz.dequantize_int8(q8, s8) - x))))
        lvl_err.append(e)
    record("serve_quality_int8_dequant", 0.0,
           " ".join(f"l{l}_max_abs_err={e:.2e}"
                    for l, e in enumerate(lvl_err)))

    # quality: cache bytes per storage dtype at the shared budget
    record("serve_quality_hbm_bytes", 0.0,
           f"dense_fp32={dense_bytes} "
           f"paged_fp32={_paged_bytes(pool_pages)} "
           f"paged_int8={_paged_bytes(int8_pages, -1)} "
           f"fp32_pages={pool_pages} int8_pages={int8_pages}")

    record("serve_concurrency_int8_fixed_hbm", 0.0,
           f"fp32_paged={conc_p} int8_paged={conc_q} "
           f"ratio={conc_q / max(conc_p, 1):.2f} "
           f"hbm_bytes={dense_bytes}")
    assert conc_q >= 1.5 * conc_p, (
        f"int8 concurrency {conc_q} < 1.5x fp32 paged {conc_p} "
        "at fixed HBM")

    if json_path:
        payload = {"bench": "serve",
                   "shape": {"arch": ARCH, "max_len": MAX_LEN,
                             "nr": cfg.nr, "requests": requests,
                             "prefix_len": prefix_len,
                             "dense_slots": DENSE_SLOTS,
                             "paged_slots": PAGED_SLOTS,
                             "int8_slots": INT8_SLOTS,
                             "new_tokens": NEW_TOKENS},
                   "backend": jax.default_backend(),
                   "tuning_digest": _tuning_digest(),
                   "xla_flags": os.environ.get("XLA_FLAGS", ""),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default name "
                         "BENCH_serve.json)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prefix", type=int, default=64)
    args = ap.parse_args()
    run(json_path=args.json, requests=args.requests,
        prefix_len=args.prefix)
