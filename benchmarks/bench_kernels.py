"""Kernel microbenchmarks: banded block attention (the compute hot-spot).

Per mode this reports BOTH passes -- ``fwd`` and ``fwd+bwd`` wall-clock
of the blocked-jnp path on the host backend, plus the fused Pallas
kernels (forward and the hand-written backward, EXPERIMENTS.md P23) when
a TPU backend is available.  The fine-q causal coarse levels are
benchmarked as ``mode='sub'`` at a shallow and a deep ratio
(EXPERIMENTS.md P24).  Interpret-mode allclose checks verify the kernel
semantics (forward AND gradients) at bench shapes; on-TPU wall-clock for
the perf ledger is the perf pass's job.

``--json out.json`` (default name BENCH_kernels.json via ``--json``
alone) additionally writes every row as machine-readable JSON so the
perf trajectory across PRs can be diffed by tooling.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import band_attention, band_attention_ref

from .common import time_fn, emit


def _loss(fn):
    def f(q, k, v, w):
        y, dn, m = fn(q, k, v, w)
        z = y / jnp.maximum(dn, 1e-9)[..., None]
        return jnp.sum(z ** 2) + jnp.sum(jnp.tanh(m))
    return f


def run(json_path=None):
    B, G, L, d, nr = 1, 4, 2048, 64, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, G, L, d))
    k = jax.random.normal(k2, (B, L, d))
    v = jax.random.normal(k3, (B, L, d))
    w = jnp.ones((B, L))
    impls = ["jnp"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")

    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    # mode=None entries are the symmetric same-length-KV levels; the
    # ('sub', ratio) entries are fine-q causal coarse levels with
    # ratio-x coarser K/V/W (shallow level 1 and a deep level).
    cases = [("l0_bidir", 1), ("l0_causal", 1),
             ("coarse_bidir", 1), ("coarse_causal", 1),
             ("sub", 2), ("sub", 16)]
    for mode, ratio in cases:
        if mode == "sub":
            Lk = L // ratio
            kk, vv, ww = k[:, :Lk], v[:, :Lk], w[:, :Lk]
            nbands = 1
            tag = f"sub_r{ratio}"
        else:
            kk, vv, ww = k, v, w
            nbands = 2 if mode.endswith("causal") else 3
            tag = mode
        flops = 2 * B * G * L * nr * nbands * d * 2   # S and Y matmuls
        for impl in impls:
            fwd = jax.jit(
                lambda q, k, v, w, m=mode, r=ratio, i=impl: band_attention(
                    q, k, v, w, nr=nr, mode=m, ratio=r, impl=i))
            us = time_fn(fwd, q, kk, vv, ww, iters=3, warmup=1)
            record(f"kernel_band_{tag}_{impl}_fwd", us,
                   f"gflops={flops / us / 1e3:.2f}")
            fwdbwd = jax.jit(jax.grad(
                _loss(lambda *a, m=mode, r=ratio, i=impl: band_attention(
                    *a, nr=nr, mode=m, ratio=r, impl=i)),
                argnums=(0, 1, 2, 3)))
            us = time_fn(fwdbwd, q, kk, vv, ww, iters=3, warmup=1)
            # bwd recomputes S and runs dS@K, dS^T@Q, A^T@GY: ~2.5x fwd
            record(f"kernel_band_{tag}_{impl}_fwdbwd", us,
                   f"gflops={3.5 * flops / us / 1e3:.2f}")

    # interpret-mode correctness at reduced shapes: forward and backward
    # of the Pallas kernels vs the dense oracle.
    qs, ks, vs, ws = q[:, :1, :256], k[:, :256], v[:, :256], w[:, :256]
    err_f = err_b = 0.0
    for mode, ratio in (("l0_causal", 1), ("coarse_bidir", 1), ("sub", 4)):
        kk, vv, ww = (x[:, :256 // ratio] for x in (ks, vs, ws))
        ys = band_attention(qs, kk, vv, ww, nr=nr, mode=mode, ratio=ratio,
                            impl="pallas_interpret")
        yr = band_attention_ref(qs, kk, vv, ww, nr=nr, mode=mode,
                                ratio=ratio)
        err_f = max(err_f, max(float(jnp.abs(a - b).max())
                               for a, b in zip(ys, yr)))
        gk = jax.grad(_loss(lambda *a, m=mode, r=ratio: band_attention(
            *a, nr=nr, mode=m, ratio=r, impl="pallas_interpret")),
            argnums=(0, 1, 2, 3))(qs, kk, vv, ww)
        gr = jax.grad(_loss(lambda *a, m=mode, r=ratio: band_attention_ref(
            *a, nr=nr, mode=m, ratio=r)), argnums=(0, 1, 2, 3))(qs, kk, vv, ww)
        # scale-aware: bench gradients reach O(500), so normalize by the
        # reference magnitude (f32 accumulation-order noise is ~1e-7 rel)
        err_b = max(err_b, max(
            float(jnp.abs(a - b).max() / (1.0 + jnp.abs(b).max()))
            for a, b in zip(gk, gr)))
    record("kernel_pallas_interpret_fwd_allclose", 0.0, f"max_err={err_f:.2e}")
    record("kernel_pallas_interpret_bwd_allclose", 0.0, f"max_err={err_b:.2e}")
    assert err_f < 1e-4 and err_b < 1e-4

    if json_path:
        from repro.kernels.tuning import get_policy
        payload = {"bench": "kernels",
                   "shape": {"B": B, "G": G, "L": L, "d": d, "nr": nr},
                   "backend": jax.default_backend(),
                   "tuning_digest": get_policy().tuning_digest(),
                   "xla_flags": os.environ.get("XLA_FLAGS", ""),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path} ({len(rows)} rows)")
    return {"err_fwd": err_f, "err_bwd": err_b}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default name "
                         "BENCH_kernels.json)")
    args = ap.parse_args()
    run(json_path=args.json)
