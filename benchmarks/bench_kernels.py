"""Kernel microbenchmarks: banded block attention (the compute hot-spot)
-- jnp blocked path timing on CPU + allclose check of the Pallas kernel
in interpret mode.  On-TPU wall-clock is the perf pass's job; here the
derived column verifies semantics and reports achieved arithmetic.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import band_attention, band_attention_ref

from .common import time_fn, emit


def run():
    B, G, L, d, nr = 1, 4, 2048, 64, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, G, L, d))
    k = jax.random.normal(k2, (B, L, d))
    v = jax.random.normal(k3, (B, L, d))
    w = jnp.ones((B, L))
    for mode in ("l0_bidir", "l0_causal", "coarse_bidir", "coarse_causal"):
        fn = jax.jit(lambda q, k, v, w, m=mode: band_attention(
            q, k, v, w, nr=nr, mode=m, impl="jnp"))
        us = time_fn(fn, q, k, v, w, iters=3, warmup=1)
        nbands = 2 if mode.endswith("causal") else 3
        flops = 2 * B * G * L * nr * nbands * d * 2   # S and Y matmuls
        emit(f"kernel_band_{mode}", us,
             f"gflops_at_cpu={flops / us / 1e3:.2f}")
    # interpret-mode correctness at bench shapes
    ys = band_attention(q[:, :1, :256], k[:, :256], v[:, :256], w[:, :256],
                        nr=nr, mode="l0_causal", impl="pallas_interpret")
    yr = band_attention_ref(q[:, :1, :256], k[:, :256], v[:, :256],
                            w[:, :256], nr=nr, mode="l0_causal")
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(ys, yr))
    emit("kernel_pallas_interpret_allclose", 0.0, f"max_err={err:.2e}")
    assert err < 1e-4
    return {"err": err}


if __name__ == "__main__":
    run()
