"""Kernel microbenchmarks: banded block attention (the compute hot-spot).

Per mode this reports BOTH passes -- ``fwd`` and ``fwd+bwd`` wall-clock
of the blocked-jnp path on the host backend, plus the fused Pallas
kernels (forward and the hand-written backward, EXPERIMENTS.md P23) when
a TPU backend is available.  Interpret-mode allclose checks verify the
kernel semantics (forward AND gradients) at bench shapes; on-TPU
wall-clock for the perf ledger is the perf pass's job.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import band_attention, band_attention_ref

from .common import time_fn, emit


def _loss(fn):
    def f(q, k, v, w):
        y, dn, m = fn(q, k, v, w)
        z = y / jnp.maximum(dn, 1e-9)[..., None]
        return jnp.sum(z ** 2) + jnp.sum(jnp.tanh(m))
    return f


def run():
    B, G, L, d, nr = 1, 4, 2048, 64, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, G, L, d))
    k = jax.random.normal(k2, (B, L, d))
    v = jax.random.normal(k3, (B, L, d))
    w = jnp.ones((B, L))
    impls = ["jnp"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    for mode in ("l0_bidir", "l0_causal", "coarse_bidir", "coarse_causal"):
        nbands = 2 if mode.endswith("causal") else 3
        flops = 2 * B * G * L * nr * nbands * d * 2   # S and Y matmuls
        for impl in impls:
            fwd = jax.jit(lambda q, k, v, w, m=mode, i=impl: band_attention(
                q, k, v, w, nr=nr, mode=m, impl=i))
            us = time_fn(fwd, q, k, v, w, iters=3, warmup=1)
            emit(f"kernel_band_{mode}_{impl}_fwd", us,
                 f"gflops={flops / us / 1e3:.2f}")
            fwdbwd = jax.jit(jax.grad(
                _loss(lambda *a, m=mode, i=impl: band_attention(
                    *a, nr=nr, mode=m, impl=i)), argnums=(0, 1, 2, 3)))
            us = time_fn(fwdbwd, q, k, v, w, iters=3, warmup=1)
            # bwd recomputes S and runs dS@K, dS^T@Q, A^T@GY: ~2.5x fwd
            emit(f"kernel_band_{mode}_{impl}_fwdbwd", us,
                 f"gflops={3.5 * flops / us / 1e3:.2f}")

    # interpret-mode correctness at reduced shapes: forward and backward
    # of the Pallas kernels vs the dense oracle.
    qs, ks, vs, ws = q[:, :1, :256], k[:, :256], v[:, :256], w[:, :256]
    err_f = err_b = 0.0
    for mode in ("l0_causal", "coarse_bidir"):
        ys = band_attention(qs, ks, vs, ws, nr=nr, mode=mode,
                            impl="pallas_interpret")
        yr = band_attention_ref(qs, ks, vs, ws, nr=nr, mode=mode)
        err_f = max(err_f, max(float(jnp.abs(a - b).max())
                               for a, b in zip(ys, yr)))
        gk = jax.grad(_loss(lambda *a, m=mode: band_attention(
            *a, nr=nr, mode=m, impl="pallas_interpret")),
            argnums=(0, 1, 2, 3))(qs, ks, vs, ws)
        gr = jax.grad(_loss(lambda *a, m=mode: band_attention_ref(
            *a, nr=nr, mode=m)), argnums=(0, 1, 2, 3))(qs, ks, vs, ws)
        # scale-aware: bench gradients reach O(500), so normalize by the
        # reference magnitude (f32 accumulation-order noise is ~1e-7 rel)
        err_b = max(err_b, max(
            float(jnp.abs(a - b).max() / (1.0 + jnp.abs(b).max()))
            for a, b in zip(gk, gr)))
    emit("kernel_pallas_interpret_fwd_allclose", 0.0, f"max_err={err_f:.2e}")
    emit("kernel_pallas_interpret_bwd_allclose", 0.0, f"max_err={err_b:.2e}")
    assert err_f < 1e-4 and err_b < 1e-4
    return {"err_fwd": err_f, "err_bwd": err_b}


if __name__ == "__main__":
    run()
