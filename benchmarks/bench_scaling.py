"""Complexity-scaling benchmark: run time of H1D vs full attention as a
function of sequence length (the paper's O(L) vs O(L^2) claim,
section 7), plus the linear-memory property of the banded kernels.

The H1D sweep runs to L=16k on the CPU backend with ``impl='auto'`` --
every level resolves through the process ``KernelPolicy``
(``repro.kernels.tuning``), which on CPU picks the blocked linear-memory
program (the same tiling as the fused kernels; the *interpreted* kernel
bodies are a parity tool, not a perf surface: the interpreter re-slices
full operands per grid step, which is O(L) per tile and would measure
the interpreter, not the algorithm).  The dense baseline stops at 4k
where its O(L^2) score tensor already reaches 16M entries.

Reports per-L tokens/s and the fitted log-log slope: ~1 for H1D
(near-linear tokens/s across the sweep), ~2 for dense attention.

``--json out.json`` (default name BENCH_scaling.json via ``--json``
alone) writes every row plus the active tuning-table digest so the
committed baseline pins the environment it was measured under.
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.core import h1d_attention, dense_attention
from repro.kernels.tuning import get_policy

from .common import time_fn, emit

LENGTHS = [256, 512, 1024, 2048, 4096, 8192, 16384]
DENSE_MAX_L = 4096


def run(json_path=None):
    d, nr = 32, 16
    policy = get_policy()
    impl = "auto"
    resolved = policy.resolve_impl(impl)
    key = jax.random.PRNGKey(0)
    h1d_jit = jax.jit(lambda q, k, v: h1d_attention(
        q, k, v, nr=nr, causal=True, causal_mode="fine-q", impl=impl))
    full_jit = jax.jit(lambda q, k, v: dense_attention(q, k, v, causal=True))

    rows = []

    def record(name, us, derived):
        emit(name, us, derived)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    t_h1d, t_full, full_ls = [], [], []
    for L in LENGTHS:
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (1, 1, L, d))
        k = jax.random.normal(k2, (1, L, d))
        v = jax.random.normal(k3, (1, L, d))
        us_h = time_fn(h1d_jit, q, k, v, iters=3, warmup=1)
        t_h1d.append(us_h)
        derived = f"tok_s={L / us_h * 1e6:.0f} impl={impl}->{resolved}"
        if L <= DENSE_MAX_L:
            us_f = time_fn(full_jit, q, k, v, iters=3, warmup=1)
            t_full.append(us_f)
            full_ls.append(L)
            derived += f" full_us={us_f:.1f}"
        record(f"scaling_L{L}_h1d", us_h, derived)
    logL = np.log(np.asarray(LENGTHS, float))
    slope_h = float(np.polyfit(logL, np.log(t_h1d), 1)[0])
    slope_f = float(np.polyfit(np.log(np.asarray(full_ls, float)),
                               np.log(t_full), 1)[0])
    record("scaling_slope_h1d", 0.0,
           f"slope={slope_h:.2f} (linear ~1, L<=16k)")
    record("scaling_slope_full", 0.0,
           f"slope={slope_f:.2f} (quadratic ~2, L<={DENSE_MAX_L})")
    # near-linear tokens/s: the slowest length keeps >= 1/4 the tokens/s
    # of the fastest (a quadratic path decays ~64x over this sweep)
    tok_s = [L / us * 1e6 for L, us in zip(LENGTHS, t_h1d)]
    record("scaling_tok_s_ratio", 0.0,
           f"min_max_ratio={min(tok_s) / max(tok_s):.2f} "
           f"min={min(tok_s):.0f} max={max(tok_s):.0f}")
    # memory: banded similarity tensors are O(L * nr) vs O(L^2)
    L = LENGTHS[-1]
    h1d_elems = L * nr * 3 + sum((L >> l) * nr for l in range(1, 8))
    record("scaling_attn_matrix_elems", 0.0,
           f"h1d={h1d_elems} dense={L * L} ratio={L * L / h1d_elems:.1f}x")

    if json_path:
        payload = {"bench": "scaling",
                   "shape": {"B": 1, "G": 1, "d": d, "nr": nr,
                             "lengths": LENGTHS,
                             "dense_max_L": DENSE_MAX_L, "impl": impl},
                   "backend": jax.default_backend(),
                   "tuning_digest": policy.tuning_digest(),
                   "xla_flags": os.environ.get("XLA_FLAGS", ""),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {json_path} ({len(rows)} rows)")
    return {"slope_h1d": slope_h, "slope_full": slope_f}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_scaling.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON (default name "
                         "BENCH_scaling.json)")
    args = ap.parse_args()
    run(json_path=args.json)
