"""Complexity-scaling benchmark: run time of H1D vs full attention as a
function of sequence length (the paper's O(L) vs O(L^2) claim,
section 7), plus the linear-memory property of the banded kernels.

Reports the fitted log-log slope: ~1 for H1D, ~2 for dense attention.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import h1d_attention, dense_attention

from .common import time_fn, emit


def run():
    d, nr = 32, 16
    lengths = [256, 512, 1024, 2048, 4096]
    t_h1d, t_full = [], []
    key = jax.random.PRNGKey(0)
    h1d_jit = jax.jit(lambda q, k, v: h1d_attention(
        q, k, v, nr=nr, causal=True, causal_mode="fine-q"))
    full_jit = jax.jit(lambda q, k, v: dense_attention(q, k, v, causal=True))
    for L in lengths:
        k1, k2, k3 = jax.random.split(key, 3)
        q = jax.random.normal(k1, (1, 1, L, d))
        k = jax.random.normal(k2, (1, L, d))
        v = jax.random.normal(k3, (1, L, d))
        us_h = time_fn(h1d_jit, q, k, v, iters=3, warmup=1)
        us_f = time_fn(full_jit, q, k, v, iters=3, warmup=1)
        t_h1d.append(us_h)
        t_full.append(us_f)
        emit(f"scaling_L{L}_h1d", us_h, f"full_us={us_f:.1f}")
    logL = np.log(np.asarray(lengths, float))
    slope_h = float(np.polyfit(logL, np.log(t_h1d), 1)[0])
    slope_f = float(np.polyfit(logL, np.log(t_full), 1)[0])
    emit("scaling_slope_h1d", 0.0, f"slope={slope_h:.2f} (linear ~1)")
    emit("scaling_slope_full", 0.0, f"slope={slope_f:.2f} (quadratic ~2)")
    # memory: banded similarity tensors are O(L * nr) vs O(L^2)
    L = 4096
    h1d_elems = L * nr * 3 + sum((L >> l) * nr for l in range(1, 8))
    emit("scaling_attn_matrix_elems", 0.0,
         f"h1d={h1d_elems} dense={L * L} ratio={L * L / h1d_elems:.1f}x")
    return {"slope_h1d": slope_h, "slope_full": slope_f}


if __name__ == "__main__":
    run()
