"""Table-1 proxy (LRA ListOps): H1D vs full vs local attention encoders on
synthetic ListOps -- the task where the paper gains most (+12.3).

Offline proxy of the paper's Table 1: same task family, reduced scale
(model/steps sized for 1 CPU core; raise BENCH_SCALE to approach paper
scale).  The claim being reproduced is *relative*: H1D >= full attention
accuracy and >> local attention at long range.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import ListOps
from repro.data.listops import VOCAB, NUM_CLASSES
from repro.models.common import ModelConfig
from repro.models.classifier import (classifier_init, classifier_loss,
                                     classifier_logits)
from repro.optim import adamw, apply_updates, cosine_schedule

from .common import steps, emit


def base_cfg(attention: str, window: int = 0):
    return ModelConfig(
        name=f"lra-{attention}", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=VOCAB, attention=attention, nr=8,
        sliding_window=window, global_every=10 ** 6 if window else 0)


def train_classifier(cfg, seq_len=256, n_steps=150, batch=16, seed=0):
    key = jax.random.PRNGKey(seed)
    params, _ = classifier_init(key, cfg, NUM_CLASSES)
    opt = adamw(cosine_schedule(2e-3, 10, n_steps), weight_decay=0.01)
    opt_state = opt.init(params)
    data = ListOps(seq_len=seq_len, batch_per_host=batch, seed=seed,
                   max_depth=4, breadth=3)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: classifier_loss(p, cfg, batch), has_aux=True)(params)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss, m["acc"]

    t0 = time.perf_counter()
    for i in range(n_steps):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, loss, acc = step(params, opt_state, b)
    train_s = time.perf_counter() - t0

    # held-out eval
    eval_data = ListOps(seq_len=seq_len, batch_per_host=64, seed=seed + 999,
                        max_depth=4, breadth=3)
    b = jax.tree.map(jnp.asarray, eval_data.batch(0))
    logits = classifier_logits(params, cfg, b["tokens"], b["mask"])
    acc = float((jnp.argmax(logits, -1) == b["label"]).mean())
    return acc, train_s / max(n_steps, 1)


def run():
    n = steps(150)
    results = {}
    for name, cfg in [("h1d", base_cfg("h1d")),
                      ("full", base_cfg("full")),
                      ("local", base_cfg("full", window=16))]:
        acc, s_per_step = train_classifier(cfg, n_steps=n)
        results[name] = acc
        emit(f"table1_listops_{name}_acc", s_per_step * 1e6,
             f"eval_acc={acc:.3f}")
    # paper-shaped claims (soft): h1d should not trail full attention by
    # much, and should beat the local-window baseline
    emit("table1_listops_h1d_minus_full", 0.0,
         f"delta={results['h1d'] - results['full']:+.3f}")
    emit("table1_listops_h1d_minus_local", 0.0,
         f"delta={results['h1d'] - results['local']:+.3f}")
    return results


if __name__ == "__main__":
    run()
