"""Pallas TPU kernels for the H-Transformer-1D compute hot-spots."""
from .ops import band_attention, resolve_tq
from .h1d_block import (band_attention_fwd, band_attention_sub_fwd,
                        band_mask, MODES, SUB_MODE)
from .h1d_block_bwd import band_attention_bwd, band_attention_sub_bwd
from .h1d_decode_kernel import decode_attend_fused, update_cache_fused
from .ref import band_attention_ref
from .tuning import (KernelPolicy, IMPLS, FAMILIES, canonical_impl,
                     get_policy, set_policy)

__all__ = ["band_attention", "band_attention_fwd", "band_attention_bwd",
           "band_attention_sub_fwd", "band_attention_sub_bwd",
           "band_mask", "band_attention_ref", "resolve_tq",
           "decode_attend_fused", "update_cache_fused",
           "MODES", "SUB_MODE",
           "KernelPolicy", "IMPLS", "FAMILIES", "canonical_impl",
           "get_policy", "set_policy"]
