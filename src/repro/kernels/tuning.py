"""Kernel launch policy: backend detection, impl resolution, candidate
enumeration and cached autotuning for every fused-kernel launch.

Every launch decision the repo used to hand-set -- ``impl=`` strings,
``tq`` tile hints, wide-vs-deep ``sub`` layouts, decode grids -- now
resolves through one :class:`KernelPolicy` object (DESIGN.md section
10).  Resolution order, per launch:

    explicit override  >  on-disk tuning table  >  committed defaults

* **Override**: an explicit ``tq=``/``impl=`` kwarg from the caller
  bypasses tuning entirely (it is still legalized by
  :func:`resolve_tq` and validated by :func:`canonical_impl`).
* **Table**: a versioned JSON tuning table under
  ``~/.cache/repro_tune/<backend>/<family>.json`` (override the root
  with ``$REPRO_TUNE_CACHE``), keyed by shape bucket + dtype + mode and
  written by the measured :meth:`KernelPolicy.autotune_band` pass.
  Corrupt / stale / version-mismatched files fall back to the defaults
  with a ``RuntimeWarning`` -- never a crash, never silent.
* **Defaults**: a deterministic table committed with the source
  (``tuning_defaults.json``) so tier-1 CI is hermetic -- no measurement
  ever runs implicitly.

``impl='auto'`` picks the backend-appropriate implementation: the fused
Pallas kernels on TPU/GPU, the blocked-XLA program on CPU (where it is
both the gradient/decode oracle and the fast path; the interpreted
kernels remain an explicit opt-in for CI parity).  Unknown impl strings
raise ``ValueError`` listing :data:`IMPLS`.

Every resolution is appended to an in-process decision log
(``policy.decisions``) so tests and benchmarks can assert which config
a launch actually used; ``tuning_digest()`` hashes the defaults plus
all on-disk tables for the active backend, and rides in every
BENCH_*.json payload so committed baselines pin the tuning environment
they were measured under.

This module deliberately imports nothing from the kernel modules at
import time (they import it); measurement helpers import lazily.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

# canonical impl enum: the single source of truth for every ``impl=`` /
# ``attn_impl`` / ``decode_impl`` knob in the repo
IMPLS = ("auto", "jnp", "pallas", "pallas_interpret")

# kernel families with distinct launch-config search spaces.  band fwd
# and the fused dQ/dKVW backward share one tq (custom_vjp nondiff arg),
# but are enumerated separately so a future split stays cheap; the
# 'sub' families carry the wide/deep layout in their candidates; the
# decode families have a fixed one-program-per-row grid.
FAMILIES = (
    "band_fwd", "band_bwd",
    "sub_fwd", "sub_bwd",
    "decode_attend", "decode_update",
    "decode_attend_paged", "decode_update_paged",
    "decode_attend_paged_quant", "decode_update_paged_quant",
)

TABLE_VERSION = 1
_DEFAULTS_PATH = os.path.join(os.path.dirname(__file__),
                              "tuning_defaults.json")
_SUB = "sub"


def canonical_impl(impl: str) -> str:
    """Validate ``impl`` against the canonical enum.  Raises
    ``ValueError`` naming the allowed set on anything else -- unknown
    strings must never fall through to an arbitrary code path."""
    if impl not in IMPLS:
        raise ValueError(
            f"unknown impl {impl!r}: allowed impls are {IMPLS}")
    return impl


def detect_backend() -> str:
    """'tpu' | 'gpu' | 'cpu' from the active JAX default backend."""
    import jax
    b = jax.default_backend()
    if b in ("tpu", "gpu", "cuda", "rocm"):
        return "tpu" if b == "tpu" else "gpu"
    return "cpu"


def resolve_tq(L: int, nr: int, tq: int, mode: str, ratio: int = 1) -> int:
    """Largest kernel query-tile size <= the ``tq`` hint that is valid
    for (L, nr, mode).

    Symmetric modes need ``tq % nr == 0 and L % tq == 0``; ``sub``
    additionally needs the tile to align with the ``nq = nr * ratio``
    query blocks (``tq % nq == 0 or nq % tq == 0``), which the
    power-of-two hierarchy shapes always admit.  Raises on shapes no
    tile can cover (L not a multiple of nr), naming the caller's
    mode/ratio so multi-level traces stay debuggable.
    """
    if L % nr:
        raise ValueError(
            f"band_attention[mode={mode}, ratio={ratio}]: L={L} is not a "
            f"multiple of nr={nr}; no kernel tiling exists (pad the "
            f"sequence first)")
    cap = min(tq, L)
    if cap < nr:
        raise ValueError(
            f"band_attention[mode={mode}, ratio={ratio}]: tq hint {tq} < "
            f"nr={nr} cannot tile L={L}")
    if mode == _SUB:
        # hierarchy shapes: L = nr * 2**M -- any nr * 2**j <= cap divides
        # L and is compatible with the nq = nr * 2**l query blocks.
        t = nr
        while t * 2 <= cap and L % (t * 2) == 0:
            t *= 2
        return t
    for t in range((cap // nr) * nr, nr - 1, -nr):
        if L % t == 0:
            return t
    raise ValueError(
        f"band_attention[mode={mode}, ratio={ratio}]: no tile divides "
        f"L={L} (nr={nr})")


def shape_bucket(L: int) -> int:
    """Sequence lengths bucket to the next power of two: tuning entries
    generalize across nearby L without per-length re-measurement."""
    b = 1
    while b < L:
        b *= 2
    return b


def table_key(L: int, nr: int, mode: str, ratio: int = 1,
              dtype: str = "float32") -> str:
    return f"L{shape_bucket(L)}_nr{nr}_{mode}_r{ratio}_{dtype}"


def _load_defaults(path: Optional[str] = None) -> Dict[str, Any]:
    try:
        with open(path or _DEFAULTS_PATH) as f:
            return json.load(f)
    except (OSError, ValueError) as e:  # pragma: no cover - repo file
        warnings.warn(f"repro_tune: committed defaults unreadable "
                      f"({e}); using built-in fallbacks", RuntimeWarning)
        return {"version": TABLE_VERSION, "tables": {}}


class KernelPolicy:
    """One launch-policy object per process (see :func:`get_policy`).

    Owns backend detection, ``impl='auto'`` resolution, per-family
    candidate enumeration, the override > table > default resolution
    order, the measured autotune pass and its persisted tables, and the
    decision log that makes each of those choices assertable.
    """

    def __init__(self, backend: Optional[str] = None,
                 cache_dir: Optional[str] = None,
                 defaults_path: Optional[str] = None):
        self.backend = backend or detect_backend()
        env_dir = os.environ.get("REPRO_TUNE_CACHE")
        if env_dir is not None and ("\0" in env_dir
                                    or not env_dir.strip()):
            # a malformed override must not crash mid-autotune: every
            # later filesystem call would raise ValueError on the NUL
            # (or scatter tables into a '' relative path)
            warnings.warn(
                f"repro_tune: REPRO_TUNE_CACHE={env_dir!r} is not a "
                f"usable path; using the default cache dir",
                RuntimeWarning)
            env_dir = None
        self.cache_dir = (cache_dir or env_dir
                          or os.path.expanduser("~/.cache/repro_tune"))
        self.defaults = _load_defaults(defaults_path)
        self._tables: Dict[str, Dict[str, Any]] = {}
        self._memo: Dict[Tuple[str, str], Tuple[Dict[str, Any], str]] = {}
        self.decisions: collections.deque = collections.deque(maxlen=512)

    # -- impl resolution ----------------------------------------------------

    def resolve_impl(self, impl: str, family: str = "band") -> str:
        """Canonicalize ``impl`` and resolve ``'auto'`` to the backend
        default: fused Pallas kernels on TPU/GPU, blocked XLA on CPU
        (the oracle path, which doubles as the fast CPU path)."""
        impl = canonical_impl(impl)
        if impl != "auto":
            return impl
        resolved = "pallas" if self.backend in ("tpu", "gpu") else "jnp"
        self._log(family, f"impl@{self.backend}", "auto",
                  {"impl": resolved})
        return resolved

    def kernel_impl(self) -> str:
        """The impl that exercises the fused kernel *bodies* on this
        backend (what the autotuner measures): compiled on TPU/GPU,
        interpreted on CPU."""
        return "pallas" if self.backend in ("tpu", "gpu") else \
            "pallas_interpret"

    # -- candidate enumeration ----------------------------------------------

    def candidates(self, family: str, *, L: int, nr: int,
                   mode: str = "l0_bidir", ratio: int = 1,
                   rows: Optional[int] = None,
                   max_tq: int = 512,
                   d: Optional[int] = None, dv: Optional[int] = None,
                   B: int = 1, G: int = 1, dtype: str = "float32",
                   vmem_budget: Optional[int] = None
                   ) -> List[Dict[str, Any]]:
        """Legal launch configs for one kernel family at one shape.

        Band/sub families enumerate power-of-two ``tq`` multiples of
        ``nr`` that divide L (the grid is ``L/tq`` query tiles); sub
        candidates carry the wide/deep layout implied by ``tq`` vs the
        ``nq = nr * ratio`` query block.  Decode families launch one
        program per cache row -- the grid is fixed by the batch, so the
        config space is the single ``(rows,)`` grid.

        With a head dim ``d``, each band/sub candidate is additionally
        sized against the static VMEM budget
        (``repro.analysis.vmem``): over-budget configs are dropped
        before any measurement and logged as ``rejected:vmem``;
        survivors carry their ``vmem_bytes`` estimate.
        """
        if family not in FAMILIES:
            raise ValueError(f"unknown kernel family {family!r}: "
                             f"allowed families are {FAMILIES}")
        if family.startswith("decode"):
            return [{"grid": (int(rows),) if rows is not None else "rows"}]
        out: List[Dict[str, Any]] = []
        nq = nr * ratio
        t = nr
        while t <= min(L, max_tq):
            if L % t == 0:
                if mode == _SUB:
                    out.append({"tq": t,
                                "layout": "wide" if nq <= t else "deep"})
                else:
                    out.append({"tq": t, "layout": "band"})
            t *= 2
        if d is None:
            return out
        from repro.analysis import vmem as vmem_mod
        budget = (vmem_mod.default_budget() if vmem_budget is None
                  else int(vmem_budget))
        key = table_key(L, nr, mode, ratio, dtype)
        kept: List[Dict[str, Any]] = []
        for cand in out:
            nbytes = vmem_mod.band_launch_bytes(
                family, L=L, nr=nr, mode=mode, ratio=ratio,
                tq=cand["tq"], d=d, dv=dv, B=B, G=G, dtype=dtype)
            if nbytes > budget:
                self._log(family, key, "rejected:vmem",
                          dict(cand, vmem_bytes=int(nbytes),
                               budget=int(budget),
                               reason=f"vmem {int(nbytes)} > "
                                      f"budget {int(budget)}"))
            else:
                kept.append(dict(cand, vmem_bytes=int(nbytes)))
        return kept

    # -- resolution: override > table > default ------------------------------

    def band_tq(self, *, L: int, nr: int, mode: str, ratio: int = 1,
                dtype: str = "float32", override: Optional[int] = None,
                family: Optional[str] = None) -> int:
        """The ``tq`` hint for one band launch.  An explicit caller
        ``override`` bypasses tuning (logged as such); otherwise the
        on-disk table entry for this shape bucket wins, then the
        committed defaults.  The caller still legalizes the hint via
        :func:`resolve_tq`."""
        if family is None:
            family = "sub_fwd" if mode == _SUB else "band_fwd"
        key = table_key(L, nr, mode, ratio, dtype)
        if override is not None:
            self._log(family, key, "override", {"tq": int(override)})
            return int(override)
        mk = (family, key)
        if mk in self._memo:
            cfg, src = self._memo[mk]
            self._log(family, key, src, cfg)
            return int(cfg["tq"])
        entries = self._entries(family)
        if key in entries and "tq" in entries[key]:
            cfg, src = {"tq": int(entries[key]["tq"])}, "table"
        else:
            cfg, src = {"tq": self._default_tq(family, mode)}, "default"
        self._memo[mk] = (cfg, src)
        self._log(family, key, src, cfg)
        return int(cfg["tq"])

    def note_launch(self, family: str, **config) -> None:
        """Record a launch whose config space is trivial (the decode
        kernels' one-program-per-row grid) so the decision log covers
        every kernel family, not just the tiled ones."""
        self._log(family, "grid", "default",
                  dict(config, grid=config.get("grid", "rows")))

    def _default_tq(self, family: str, mode: str) -> int:
        fam = self.defaults.get("tables", {}).get(family, {})
        ent = fam.get(f"mode:{mode}", fam.get("default", {}))
        return int(ent.get("tq", 128))

    # -- on-disk tables -----------------------------------------------------

    def _table_path(self, family: str) -> str:
        return os.path.join(self.cache_dir, self.backend, f"{family}.json")

    def _entries(self, family: str) -> Dict[str, Any]:
        if family in self._tables:
            return self._tables[family]
        path = self._table_path(family)
        entries: Dict[str, Any] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    table = json.load(f)
                if not isinstance(table, dict):
                    raise ValueError("not a JSON object")
                if table.get("version") != TABLE_VERSION:
                    warnings.warn(
                        f"repro_tune: tuning table {path} has version "
                        f"{table.get('version')!r} != {TABLE_VERSION}; "
                        f"ignoring it (falling back to defaults)",
                        RuntimeWarning)
                elif table.get("backend") not in (None, self.backend):
                    warnings.warn(
                        f"repro_tune: tuning table {path} was measured on "
                        f"backend {table.get('backend')!r}, not "
                        f"{self.backend!r}; ignoring it (falling back to "
                        f"defaults)", RuntimeWarning)
                else:
                    entries = dict(table.get("entries", {}))
            except (OSError, ValueError) as e:
                warnings.warn(
                    f"repro_tune: corrupt tuning table {path} ({e}); "
                    f"falling back to defaults", RuntimeWarning)
        self._tables[family] = entries
        return entries

    def _save_table(self, family: str) -> Optional[str]:
        """Persist one family's tuning table.  An unwritable cache dir
        (read-only $REPRO_TUNE_CACHE, container filesystems) degrades
        to in-memory tables with a ``RuntimeWarning`` -- the autotune
        sweep keeps its measured entries for this process instead of
        aborting mid-sweep."""
        path = self._table_path(family)
        payload = {"version": TABLE_VERSION, "backend": self.backend,
                   "kernel": family,
                   "entries": self._tables.get(family, {})}
        tmp = path + ".tmp"
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except (OSError, ValueError) as e:
            # ValueError: embedded NUL from a cache_dir passed directly
            # to the constructor (the env override is sanitized there)
            warnings.warn(
                f"repro_tune: cannot persist tuning table {path} ({e}); "
                f"keeping measured entries in memory only", RuntimeWarning)
            return None
        return path

    # -- measured autotune pass ---------------------------------------------

    def autotune_band(self, *, L: int, nr: int, mode: str, ratio: int = 1,
                      d: int = 64, B: int = 1, G: int = 1,
                      impl: Optional[str] = None, iters: int = 2,
                      warmup: int = 1,
                      family: Optional[str] = None,
                      vmem_budget: Optional[int] = None) -> Dict[str, Any]:
        """Measure every legal candidate config for one band family at
        one shape bucket, persist the winner to the on-disk table, and
        return the entry.  A table hit returns without re-measuring
        (that is the point of the cache); autotuning never runs
        implicitly -- callers opt in.  Candidates whose static VMEM
        estimate exceeds the budget are rejected before measurement
        (``rejected:vmem`` in the decision log).
        """
        if family is None:
            family = "sub_fwd" if mode == _SUB else "band_fwd"
        key = table_key(L, nr, mode, ratio)
        entries = self._entries(family)
        if key in entries:
            cfg = {"tq": int(entries[key]["tq"])}
            self._memo[(family, key)] = (cfg, "table")
            self._log(family, key, "table", cfg)
            return dict(entries[key])
        impl = self.kernel_impl() if impl is None else \
            self.resolve_impl(impl, family)
        best: Optional[Tuple[Dict[str, Any], float]] = None
        for cand in self.candidates(family, L=L, nr=nr, mode=mode,
                                    ratio=ratio, d=d, B=B, G=G,
                                    vmem_budget=vmem_budget):
            fn = self._band_runner(cand["tq"], L=L, nr=nr, mode=mode,
                                   ratio=ratio, d=d, B=B, G=G, impl=impl,
                                   grad=family.endswith("bwd"))
            us = self._measure(fn, iters=iters, warmup=warmup)
            if best is None or us < best[1]:
                best = (cand, us)
        assert best is not None, (
            f"no measurable candidates for {family} {key} (all rejected? "
            f"see rejected:vmem decision-log entries)")
        entry = dict(best[0], us=round(best[1], 1), impl=impl,
                     source="measured")
        entries[key] = entry
        self._save_table(family)
        cfg = {"tq": int(entry["tq"])}
        self._memo[(family, key)] = (cfg, "measured")
        self._log(family, key, "measured", cfg)
        return dict(entry)

    def _band_runner(self, tq: int, *, L, nr, mode, ratio, d, B, G, impl,
                     grad: bool):
        import jax
        import jax.numpy as jnp
        from repro.kernels import ops

        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        Lk = L // ratio if mode == _SUB else L
        q = jax.random.normal(ks[0], (B, G, L, d))
        k = jax.random.normal(ks[1], (B, Lk, d))
        v = jax.random.normal(ks[2], (B, Lk, d))
        w = jnp.ones((B, Lk))

        def call(q, k, v, w):
            y, dn, m = ops.band_attention(q, k, v, w, nr=nr, mode=mode,
                                          ratio=ratio, impl=impl, tq=tq)
            return jnp.sum(y) + jnp.sum(dn) + jnp.sum(m)

        fn = jax.jit(jax.grad(call, argnums=(0, 1, 2))) if grad \
            else jax.jit(call)
        return lambda: fn(q, k, v, w)

    def _measure(self, fn, iters: int = 2, warmup: int = 1) -> float:
        """Median-free simple wall-clock: mean microseconds per call
        after ``warmup`` compile/warm calls.  Separated out so tests can
        count (or stub) measurements."""
        import jax
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(max(iters, 1)):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / max(iters, 1) * 1e6

    # -- observability ------------------------------------------------------

    def _log(self, family: str, key: str, source: str,
             config: Dict[str, Any]) -> None:
        self.decisions.append({"family": family, "key": key,
                               "source": source, "config": dict(config)})

    def tuning_digest(self) -> str:
        """Stable 12-hex digest over the committed defaults plus every
        readable on-disk table for the active backend.  BENCH_*.json
        payloads carry it so a baseline regenerated under different
        tuning state is visible in the diff."""
        tables: Dict[str, Any] = {}
        bdir = os.path.join(self.cache_dir, self.backend)
        if os.path.isdir(bdir):
            for f in sorted(os.listdir(bdir)):
                if f.endswith(".json"):
                    tables[f[:-5]] = self._entries(f[:-5])
        blob = {"version": TABLE_VERSION, "backend": self.backend,
                "defaults": self.defaults, "tables": tables}
        return hashlib.sha1(
            json.dumps(blob, sort_keys=True).encode()).hexdigest()[:12]


_POLICY: Optional[KernelPolicy] = None


def get_policy() -> KernelPolicy:
    """The process-wide launch policy (constructed on first use)."""
    global _POLICY
    if _POLICY is None:
        _POLICY = KernelPolicy()
    return _POLICY


def set_policy(policy: Optional[KernelPolicy]) -> Optional[KernelPolicy]:
    """Swap the process policy (tests, benchmarks).  Returns the
    previous one so callers can restore it."""
    global _POLICY
    prev, _POLICY = _POLICY, policy
    return prev


def _main(argv=None):  # pragma: no cover - CLI smoke (scripts/ci.sh)
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--autotune-smoke", action="store_true",
                    help="measured autotune round-trip on a tiny shape "
                         "(respects $REPRO_TUNE_CACHE)")
    ap.add_argument("--assert-cached", action="store_true",
                    help="assert a prior --autotune-smoke's table is "
                         "applied WITHOUT measuring (cross-process "
                         "round-trip; pair with the same "
                         "$REPRO_TUNE_CACHE)")
    ap.add_argument("--L", type=int, default=64)
    ap.add_argument("--nr", type=int, default=16)
    args = ap.parse_args(argv)
    p = KernelPolicy()
    print(f"backend={p.backend} cache_dir={p.cache_dir}")
    if args.assert_cached:
        # a fresh process over the same cache dir: the table must win
        # and no measurement may run
        p._measure = None  # any measurement attempt would TypeError
        tq = p.band_tq(L=args.L, nr=args.nr, mode="l0_causal")
        src = p.decisions[-1]["source"]
        assert src == "table", (src, list(p.decisions))
        print(f"cross-process round-trip OK: tq={tq} source={src}")
    if args.autotune_smoke:
        for family, mode, ratio in (("band_fwd", "l0_causal", 1),
                                    ("sub_fwd", "sub", 2)):
            e = p.autotune_band(L=args.L, nr=args.nr, mode=mode,
                                ratio=ratio, d=16)
            print(f"{family} {mode} r{ratio}: {e}")
        # reload in a fresh policy: the measured entry must win
        p2 = KernelPolicy(cache_dir=p.cache_dir)
        tq = p2.band_tq(L=args.L, nr=args.nr, mode="l0_causal")
        src = p2.decisions[-1]["source"]
        assert src == "table", (src, list(p2.decisions))
        print(f"round-trip OK: tq={tq} source={src}")
    print(f"tuning_digest={p.tuning_digest()}")


if __name__ == "__main__":  # pragma: no cover
    _main()
