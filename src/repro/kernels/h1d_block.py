"""Pallas TPU kernel: fused banded block attention for H-Transformer-1D.

This is the compute hot-spot of the paper (Algorithm 1 steps 2/4/5): for
one hierarchy level, every query block attends its self/prev/next key
blocks, with the level masks, producing the unnormalized output ``Y``,
the normalizer contribution ``D`` and the row-max ``m`` in ONE VMEM pass
-- no (L x L) or even (L x 3*nr) attention tensor ever hits HBM.

TPU adaptation (DESIGN.md section 2): the paper's logical block size
``nr`` (16 in the LM experiments) is far below the 128x128 MXU tile, so
the kernel processes *groups* of blocks: a TQ-row query tile (TQ >= 128)
against its own TQ-key tile plus the ``nr``-wide halo edges of the two
neighbouring tiles.  The band/quadrant/causal masks are generated from
global indices with ``broadcasted_iota`` -- no mask tensors in HBM.

Grid: ``(B, G, Lq // TQ)``; GQA is handled by letting the K/V/W
BlockSpec index maps ignore the group axis ``g`` (no KV replication in
HBM).  All matmuls accumulate in float32.

Modes (must mirror ``repro.kernels.ref``):
  * ``l0_bidir``     -- level-0 tridiagonal
  * ``l0_causal``    -- level-0 causal (tril diagonal + sub-diagonal)
  * ``coarse_bidir`` -- level>=1 bi-diagonal with quadrant exclusions
  * ``coarse_causal``-- level>=1 sub-diagonal with quadrant exclusion
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.0e38
_MIN_M = -1e30

MODES = ("l0_bidir", "l0_causal", "coarse_bidir", "coarse_causal")


def band_mask(qi, ki, nr: int, mode: str, lk: int):
    """Allowed-mask from *global* row/col indices (broadcastable shapes).

    Single source of truth for the band structure -- used both inside the
    kernel (with iota-generated indices) and by the jnp reference.
    """
    inb = (ki >= 0) & (ki < lk)
    bq = qi // nr
    bk = ki // nr
    diff = bq - bk
    if mode == "l0_bidir":
        allow = jnp.abs(diff) <= 1
    elif mode == "l0_causal":
        allow = ((diff == 0) & (ki <= qi)) | (diff == 1)
    else:
        half = nr // 2
        base = (diff == 1) if mode == "coarse_causal" else (jnp.abs(diff) == 1)
        sub_excl = (diff == 1) & ((qi % nr) < half) & ((ki % nr) >= half)
        sup_excl = (diff == -1) & ((qi % nr) >= half) & ((ki % nr) < half)
        allow = base & ~sub_excl & ~sup_excl
    return allow & inb


def _fwd_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         y_ref, dn_ref, m_ref) = refs
    else:
        (q_ref, ks_ref, kp_ref, kn_ref, vs_ref, vp_ref, vn_ref,
         ws_ref, wp_ref, wn_ref, y_ref, dn_ref, m_ref) = refs

    it = pl.program_id(2)
    f32 = jnp.float32

    q = q_ref[0, 0].astype(f32)                       # (TQ, d)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def term(k, v, w, k0):
        """k: (TK, d), v: (TK, dv), w: (TK,), k0: global col offset."""
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        s = jax.lax.dot_general(
            q, k.astype(f32), (((1,), (1,)), ((), ())),
            preferred_element_type=f32)                # (TQ, TK)
        allow = band_mask(qi, ki, nr, mode, lk) & (w[None, :] > 0)
        return jnp.where(allow, s, NEG_INF), v.astype(f32), w.astype(f32)

    terms = [
        term(ks_ref[0], vs_ref[0], ws_ref[0], it * tq),
        term(kp_ref[0, tq - nr:, :], vp_ref[0, tq - nr:, :],
             wp_ref[0, tq - nr:], it * tq - nr),
    ]
    if not causal:
        terms.append(
            term(kn_ref[0, :nr, :], vn_ref[0, :nr, :], wn_ref[0, :nr],
                 (it + 1) * tq))

    m = jnp.maximum(
        functools.reduce(jnp.maximum, [s.max(axis=1) for s, _, _ in terms]),
        _MIN_M)                                        # (TQ,)
    y = None
    dn = None
    for s, v, w in terms:
        a = jnp.exp(s - m[:, None])
        yt = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
        dt = jnp.sum(a * w[None, :], axis=1)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt

    y_ref[0, 0] = y.astype(y_ref.dtype)
    dn_ref[0, 0] = dn.astype(dn_ref.dtype)
    m_ref[0, 0] = m.astype(m_ref.dtype)


def band_attention_fwd(
    q: jnp.ndarray,   # (B, G, L, d) -- pre-scaled queries
    k: jnp.ndarray,   # (B, L, d)
    v: jnp.ndarray,   # (B, L, dv)
    w: jnp.ndarray,   # (B, L) key weights (>0 == valid)
    *,
    nr: int,
    mode: str,
    tq: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused banded block attention.  Returns float32 (y, dn, m):
    y (B, G, L, dv), dn (B, G, L), m (B, G, L)."""
    assert mode in MODES, mode
    B, G, L, d = q.shape
    dv = v.shape[-1]
    assert L % tq == 0 and tq % nr == 0 and tq >= nr, (L, tq, nr)
    nt = L // tq
    causal = mode.endswith("causal")
    f32 = jnp.float32

    self_map = lambda b, g, i: (b, i, 0)
    prev_map = lambda b, g, i: (b, jnp.maximum(i - 1, 0), 0)
    next_map = lambda b, g, i: (b, jnp.minimum(i + 1, nt - 1), 0)
    wself_map = lambda b, g, i: (b, i)
    wprev_map = lambda b, g, i: (b, jnp.maximum(i - 1, 0))
    wnext_map = lambda b, g, i: (b, jnp.minimum(i + 1, nt - 1))

    in_specs = [pl.BlockSpec((1, 1, tq, d), lambda b, g, i: (b, g, i, 0))]
    inputs = [q]
    kmaps = [self_map, prev_map] + ([] if causal else [next_map])
    wmaps = [wself_map, wprev_map] + ([] if causal else [wnext_map])
    for mp in kmaps:
        in_specs.append(pl.BlockSpec((1, tq, d), mp))
        inputs.append(k)
    for mp in kmaps:
        in_specs.append(pl.BlockSpec((1, tq, dv), mp))
        inputs.append(v)
    for mp in wmaps:
        in_specs.append(pl.BlockSpec((1, tq), mp))
        inputs.append(w)

    out_shape = (
        jax.ShapeDtypeStruct((B, G, L, dv), f32),
        jax.ShapeDtypeStruct((B, G, L), f32),
        jax.ShapeDtypeStruct((B, G, L), f32),
    )
    out_specs = (
        pl.BlockSpec((1, 1, tq, dv), lambda b, g, i: (b, g, i, 0)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
    )

    kernel = functools.partial(_fwd_kernel, nr=nr, mode=mode, tq=tq, lk=L)
    return pl.pallas_call(
        kernel,
        grid=(B, G, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
