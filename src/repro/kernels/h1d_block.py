"""Pallas TPU kernel: fused banded block attention for H-Transformer-1D.

This is the compute hot-spot of the paper (Algorithm 1 steps 2/4/5): for
one hierarchy level, every query block attends its self/prev/next key
blocks, with the level masks, producing the unnormalized output ``Y``,
the normalizer contribution ``D`` and the row-max ``m`` in ONE VMEM pass
-- no (L x L) or even (L x 3*nr) attention tensor ever hits HBM.

TPU adaptation (DESIGN.md section 2): the paper's logical block size
``nr`` (16 in the LM experiments) is far below the 128x128 MXU tile, so
the kernel processes *groups* of blocks: a TQ-row query tile (TQ >= 128)
against its own TQ-key tile plus the ``nr``-wide halo edges of the two
neighbouring tiles.  The band/quadrant/causal masks are generated from
global indices with ``broadcasted_iota`` -- no mask tensors in HBM.

Grid: ``(B, G, Lq // TQ)``; GQA is handled by letting the K/V/W
BlockSpec index maps ignore the group axis ``g`` (no KV replication in
HBM).  All matmuls accumulate in float32.

Modes (must mirror ``repro.kernels.ref``):
  * ``l0_bidir``     -- level-0 tridiagonal
  * ``l0_causal``    -- level-0 causal (tril diagonal + sub-diagonal)
  * ``coarse_bidir`` -- level>=1 bi-diagonal with quadrant exclusions
  * ``coarse_causal``-- level>=1 sub-diagonal with quadrant exclusion
  * ``sub``          -- level>=1 leak-free causal with FINE queries
    (``causal_mode='fine-q'``): queries keep length ``Lq`` while K/V/W
    are the level-l coarse sequence of length ``Lk = Lq / ratio``
    (``ratio = 2**l``).  Query block I (``nr * ratio`` fine rows)
    attends coarse key block I-1 under the 'sub' quadrant exclusion --
    the same partition as ``core.h1d_attention._level_fine_q``, fused
    into one VMEM pass per query tile (DESIGN.md section 2).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.contracts import launch

NEG_INF = -3.0e38
_MIN_M = -1e30

MODES = ("l0_bidir", "l0_causal", "coarse_bidir", "coarse_causal")
SUB_MODE = "sub"   # fine-q causal level>=1: fine queries x coarse keys

# operand names (contract diagnostics) for sub_kv_specs' two layouts
SUB_KV_NAMES = {
    "wide": ("k_self", "k_prev", "v_self", "v_prev", "w_self", "w_prev"),
    "deep": ("k_blk", "v_blk", "w_blk"),
}


def band_mask(qi, ki, nr: int, mode: str, lk: int, ratio: int = 1):
    """Allowed-mask from *global* row/col indices (broadcastable shapes).

    Single source of truth for the band structure -- used both inside the
    kernel (with iota-generated indices) and by the jnp reference.

    ``mode='sub'``: ``qi`` are FINE query indices, ``ki`` level-l coarse
    key indices, ``ratio = 2**l``.  A fine query in coarse-resolution
    block I attends coarse key block I-1; the quadrant exclusion drops
    (first-half queries x last-half keys) of the span -- those pairs are
    covered at a finer level.  ``qi // ratio`` maps a fine query to its
    coarse row, after which the structure is exactly ``coarse_causal``.
    """
    if mode == SUB_MODE:
        return band_mask(qi // ratio, ki, nr, "coarse_causal", lk)
    inb = (ki >= 0) & (ki < lk)
    bq = qi // nr
    bk = ki // nr
    diff = bq - bk
    if mode == "l0_bidir":
        allow = jnp.abs(diff) <= 1
    elif mode == "l0_causal":
        allow = ((diff == 0) & (ki <= qi)) | (diff == 1)
    else:
        half = nr // 2
        base = (diff == 1) if mode == "coarse_causal" else (jnp.abs(diff) == 1)
        sub_excl = (diff == 1) & ((qi % nr) < half) & ((ki % nr) >= half)
        sup_excl = (diff == -1) & ((qi % nr) >= half) & ((ki % nr) < half)
        allow = base & ~sub_excl & ~sup_excl
    return allow & inb


def _fwd_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         y_ref, dn_ref, m_ref) = refs
    else:
        (q_ref, ks_ref, kp_ref, kn_ref, vs_ref, vp_ref, vn_ref,
         ws_ref, wp_ref, wn_ref, y_ref, dn_ref, m_ref) = refs

    it = pl.program_id(2)
    f32 = jnp.float32

    q = q_ref[0, 0].astype(f32)                       # (TQ, d)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def term(k, v, w, k0):
        """k: (TK, d), v: (TK, dv), w: (TK,), k0: global col offset."""
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        s = jax.lax.dot_general(
            q, k.astype(f32), (((1,), (1,)), ((), ())),
            preferred_element_type=f32)                # (TQ, TK)
        allow = band_mask(qi, ki, nr, mode, lk) & (w[None, :] > 0)
        return jnp.where(allow, s, NEG_INF), v.astype(f32), w.astype(f32)

    # halo refs are exact nr-row blocks (the BlockSpecs fetch only the
    # needed edge of the neighbouring tile, not the whole tile)
    terms = [
        term(ks_ref[0], vs_ref[0], ws_ref[0], it * tq),
        term(kp_ref[0], vp_ref[0], wp_ref[0], it * tq - nr),
    ]
    if not causal:
        terms.append(
            term(kn_ref[0], vn_ref[0], wn_ref[0], (it + 1) * tq))

    m = jnp.maximum(
        functools.reduce(jnp.maximum, [s.max(axis=1) for s, _, _ in terms]),
        _MIN_M)                                        # (TQ,)
    y = None
    dn = None
    for s, v, w in terms:
        a = jnp.exp(s - m[:, None])
        yt = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
        dt = jnp.sum(a * w[None, :], axis=1)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt

    y_ref[0, 0] = y.astype(y_ref.dtype)
    dn_ref[0, 0] = dn.astype(dn_ref.dtype)
    m_ref[0, 0] = m.astype(m_ref.dtype)


def _fwd_sub_kernel(*refs, nr: int, ratio: int, tq: int, lk: int):
    """Fine-q causal forward: fine query tile x shifted coarse KV band.

    Two static layouts (the wrapper normalizes ``tq`` so exactly one
    applies):
      * nq <= tq ("wide tile"): the tile covers >= 1 whole query blocks;
        its keys are the coarse window [it*tqc - nr, (it+1)*tqc - nr),
        i.e. the nr-wide tail of the PREV coarse tile plus the head of
        the SELF coarse tile -- the same halo machinery as the l0 modes.
      * nq > tq ("deep level"): the tile lies inside ONE query block I,
        whose keys are the single coarse block I-1 (nr rows).
    """
    nq = nr * ratio
    if nq <= tq:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         y_ref, dn_ref, m_ref) = refs
    else:
        q_ref, kb_ref, vb_ref, wb_ref, y_ref, dn_ref, m_ref = refs

    it = pl.program_id(2)
    f32 = jnp.float32
    q = q_ref[0, 0].astype(f32)                       # (TQ, d)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def term(k, v, w, k0):
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        s = jax.lax.dot_general(
            q, k.astype(f32), (((1,), (1,)), ((), ())),
            preferred_element_type=f32)               # (TQ, TK)
        allow = band_mask(qi, ki, nr, SUB_MODE, lk, ratio) & (w[None, :] > 0)
        return jnp.where(allow, s, NEG_INF), v.astype(f32), w.astype(f32)

    if nq <= tq:
        tqc = tq // ratio                             # coarse rows per tile
        # prev-halo refs are exact nr-row coarse blocks (see sub_kv_specs)
        terms = [term(kp_ref[0], vp_ref[0], wp_ref[0], it * tqc - nr)]
        if tqc > nr:
            terms.append(term(ks_ref[0, :tqc - nr, :], vs_ref[0, :tqc - nr, :],
                              ws_ref[0, :tqc - nr], it * tqc))
    else:
        s_blk = nq // tq                              # query tiles per block
        k0 = (it // s_blk - 1) * nr                   # coarse block I-1
        terms = [term(kb_ref[0], vb_ref[0], wb_ref[0], k0)]

    m = jnp.maximum(
        functools.reduce(jnp.maximum, [s.max(axis=1) for s, _, _ in terms]),
        _MIN_M)
    y = None
    dn = None
    for s, v, w in terms:
        a = jnp.exp(s - m[:, None])
        yt = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
        dt = jnp.sum(a * w[None, :], axis=1)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt

    y_ref[0, 0] = y.astype(y_ref.dtype)
    dn_ref[0, 0] = dn.astype(dn_ref.dtype)
    m_ref[0, 0] = m.astype(m_ref.dtype)


def sub_kv_specs(nr: int, ratio: int, tq: int):
    """BlockSpec builder for the coarse K/V/W operands of the ``sub``
    mode on a (b, g, i) query-tile grid (forward / dQ kernels).

    Returns ``(build, layout)``: ``build(k, v, w, d, dv)`` yields the
    (specs, inputs) lists in the unpack order of the sub kernels, and
    ``layout`` is 'wide' (self coarse tile + exact nr-row prev-halo
    block, nq <= tq) or 'deep' (single coarse block I-1, nq > tq)."""
    nq = nr * ratio
    if nq <= tq:
        tqc = tq // ratio
        tbc = tqc // nr          # nr-row coarse blocks per coarse tile
        self_map = lambda b, g, i: (b, i, 0)
        # prev-halo: the single nr-row coarse block just before this
        # tile's coarse window (exact fetch, index map in nr units)
        prev_map = lambda b, g, i: (b, jnp.maximum(i * tbc - 1, 0), 0)
        wself_map = lambda b, g, i: (b, i)
        wprev_map = lambda b, g, i: (b, jnp.maximum(i * tbc - 1, 0))

        def build(k, v, w, d_, dv_):
            specs = [pl.BlockSpec((1, tqc, d_), self_map),
                     pl.BlockSpec((1, nr, d_), prev_map),
                     pl.BlockSpec((1, tqc, dv_), self_map),
                     pl.BlockSpec((1, nr, dv_), prev_map),
                     pl.BlockSpec((1, tqc), wself_map),
                     pl.BlockSpec((1, nr), wprev_map)]
            return specs, [k, k, v, v, w, w]
        return build, "wide"
    s_blk = nq // tq
    blk_map = lambda b, g, i: (b, jnp.maximum(i // s_blk - 1, 0), 0)
    wblk_map = lambda b, g, i: (b, jnp.maximum(i // s_blk - 1, 0))

    def build(k, v, w, d_, dv_):
        specs = [pl.BlockSpec((1, nr, d_), blk_map),
                 pl.BlockSpec((1, nr, dv_), blk_map),
                 pl.BlockSpec((1, nr), wblk_map)]
        return specs, [k, v, w]
    return build, "deep"


def band_attention_sub_fwd(
    q: jnp.ndarray,   # (B, G, Lq, d) -- pre-scaled FINE queries
    k: jnp.ndarray,   # (B, Lk, d)  level-l coarse keys, Lk = Lq / ratio
    v: jnp.ndarray,   # (B, Lk, dv) level-l coarse values (pairwise sums)
    w: jnp.ndarray,   # (B, Lk)     level-l coarse key weights
    *,
    nr: int,
    ratio: int,
    tq: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused fine-q causal level (mode='sub').  Returns float32
    y (B, G, Lq, dv), dn (B, G, Lq), m (B, G, Lq)."""
    B, G, Lq, d = q.shape
    Lk = k.shape[1]
    dv = v.shape[-1]
    nq = nr * ratio
    assert ratio >= 2 and Lq == Lk * ratio, (Lq, Lk, ratio)
    assert Lq % tq == 0 and tq % nr == 0, (Lq, tq, nr)
    assert (tq % nq == 0) or (nq % tq == 0), (tq, nq)
    if nq <= tq:
        assert (tq // ratio) % nr == 0, (tq, ratio, nr)
    nt = Lq // tq
    f32 = jnp.float32

    in_specs = [pl.BlockSpec((1, 1, tq, d), lambda b, g, i: (b, g, i, 0))]
    build, layout = sub_kv_specs(nr, ratio, tq)
    kv_specs, kv_inputs = build(k, v, w, d, dv)
    in_specs += kv_specs
    inputs = [q] + kv_inputs

    out_shape = (
        jax.ShapeDtypeStruct((B, G, Lq, dv), f32),
        jax.ShapeDtypeStruct((B, G, Lq), f32),
        jax.ShapeDtypeStruct((B, G, Lq), f32),
    )
    out_specs = (
        pl.BlockSpec((1, 1, tq, dv), lambda b, g, i: (b, g, i, 0)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
    )

    kernel = functools.partial(_fwd_sub_kernel, nr=nr, ratio=ratio, tq=tq,
                               lk=Lk)
    return launch(
        kernel, family="sub_fwd", grid=(B, G, nt),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shape,
        operands=inputs, interpret=interpret,
        in_names=("q",) + SUB_KV_NAMES[layout],
        out_names=("y", "dn", "m"),
        meta=dict(mode=SUB_MODE, nr=nr, ratio=ratio, tq=tq, lk=Lk,
                  layout=layout))


def band_attention_fwd(
    q: jnp.ndarray,   # (B, G, L, d) -- pre-scaled queries
    k: jnp.ndarray,   # (B, L, d)
    v: jnp.ndarray,   # (B, L, dv)
    w: jnp.ndarray,   # (B, L) key weights (>0 == valid)
    *,
    nr: int,
    mode: str,
    tq: int = 128,
    ratio: int = 1,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused banded block attention.  Returns float32 (y, dn, m):
    y (B, G, L, dv), dn (B, G, L), m (B, G, L).

    ``mode='sub'`` is the fine-q causal coarse level: q keeps the fine
    length while k/v/w are ``ratio``x coarser (see module docstring)."""
    if mode == SUB_MODE:
        return band_attention_sub_fwd(q, k, v, w, nr=nr, ratio=ratio,
                                      tq=tq, interpret=interpret)
    assert mode in MODES, mode
    B, G, L, d = q.shape
    dv = v.shape[-1]
    assert L % tq == 0 and tq % nr == 0 and tq >= nr, (L, tq, nr)
    nt = L // tq
    causal = mode.endswith("causal")
    f32 = jnp.float32

    # self operand: the full tile; halo operands: exact nr-row blocks
    # at the neighbouring tile's edge (index maps count nr-row blocks),
    # so halo HBM fetch is nr rows, not tq, per tensor per grid step.
    nb = L // nr
    tb = tq // nr
    self_map = lambda b, g, i: (b, i, 0)
    prev_map = lambda b, g, i: (b, jnp.maximum(i * tb - 1, 0), 0)
    next_map = lambda b, g, i: (b, jnp.minimum((i + 1) * tb, nb - 1), 0)
    wself_map = lambda b, g, i: (b, i)
    wprev_map = lambda b, g, i: (b, jnp.maximum(i * tb - 1, 0))
    wnext_map = lambda b, g, i: (b, jnp.minimum((i + 1) * tb, nb - 1))

    in_specs = [pl.BlockSpec((1, 1, tq, d), lambda b, g, i: (b, g, i, 0))]
    inputs = [q]
    kmaps = [(tq, self_map), (nr, prev_map)] + (
        [] if causal else [(nr, next_map)])
    wmaps = [(tq, wself_map), (nr, wprev_map)] + (
        [] if causal else [(nr, wnext_map)])
    for rows, mp in kmaps:
        in_specs.append(pl.BlockSpec((1, rows, d), mp))
        inputs.append(k)
    for rows, mp in kmaps:
        in_specs.append(pl.BlockSpec((1, rows, dv), mp))
        inputs.append(v)
    for rows, mp in wmaps:
        in_specs.append(pl.BlockSpec((1, rows), mp))
        inputs.append(w)

    out_shape = (
        jax.ShapeDtypeStruct((B, G, L, dv), f32),
        jax.ShapeDtypeStruct((B, G, L), f32),
        jax.ShapeDtypeStruct((B, G, L), f32),
    )
    out_specs = (
        pl.BlockSpec((1, 1, tq, dv), lambda b, g, i: (b, g, i, 0)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
        pl.BlockSpec((1, 1, tq), lambda b, g, i: (b, g, i)),
    )

    kernel = functools.partial(_fwd_kernel, nr=nr, mode=mode, tq=tq, lk=L)
    halo = ("self", "prev") if causal else ("self", "prev", "next")
    return launch(
        kernel, family="band_fwd", grid=(B, G, nt),
        in_specs=in_specs, out_specs=out_specs, out_shape=out_shape,
        operands=inputs, interpret=interpret,
        in_names=("q",) + tuple(f"{a}_{h}" for a in "kvw" for h in halo),
        out_names=("y", "dn", "m"),
        meta=dict(mode=mode, nr=nr, tq=tq, lk=L))
