"""Jit'd differentiable wrapper around the banded block attention kernel.

``band_attention(q, k, v, w, nr=..., mode=..., impl=...)``:

* ``impl='pallas'``            -- Pallas TPU kernel forward.
* ``impl='pallas_interpret'``  -- Pallas kernel in interpret mode (CPU
  validation path; executes the kernel body in Python).
* ``impl='jnp'``               -- blocked XLA implementation (used for the
  multi-pod dry-run on host-platform devices and as the backward body).

The custom VJP uses the pure-jnp reference as the differentiable body:
forward runs the fused kernel, backward is ``jax.vjp`` of the reference
(numerically identical math), so gradients are exact w.r.t. the kernel
semantics.  A hand-written Pallas backward is a recorded perf-pass item
(EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import h1d_block
from . import ref as kref


def _blocked_jnp(q, k, v, w, *, nr: int, mode: str):
    """O(L * nr) blocked XLA implementation (linear-memory reference).

    Mirrors the kernel tiling but with plain jnp ops; this is what the
    distributed dry-run lowers (Pallas TPU kernels cannot compile for the
    host platform).

    ``k``/``v`` may be (B, L, d) (shared across the G query groups) or
    (B, G, L, d) (per-head KV, GSPMD-friendly: the head axis flows
    through every einsum, so the partitioner never sees size-1 dims or
    sharded-axis splits).
    """
    from repro.core import hierarchy as hc

    B, G, L, d = q.shape
    kv_g = k.ndim == 4
    f32 = jnp.float32
    causal = mode.endswith("causal")
    qb = hc.block(q.astype(f32), nr)                    # (B,G,NB,nr,d)
    kb = hc.block(k.astype(f32), nr)
    vb = hc.block(v.astype(f32), nr)
    wb = hc.block(w.astype(f32), nr, axis=-1)
    nb = qb.shape[-3]
    s_eq = "bgnqd,bgnkd->bgnqk" if kv_g else "bgnqd,bnkd->bgnqk"
    y_eq = "bgnqk,bgnkv->bgnqv" if kv_g else "bgnqk,bnkv->bgnqv"
    w_allow = (lambda wt: (wt > 0)[:, None, :, None, :])

    terms = []

    def add(offset):
        kt = hc.shift_blocks(kb, offset)
        vt = hc.shift_blocks(vb, offset)
        wt = hc.shift_blocks(wb, offset, block_axis=-2)
        qi = jnp.arange(nr)[:, None] + jnp.arange(nb)[:, None, None] * nr
        ki = qi.transpose(0, 2, 1) + offset * nr
        allow = h1d_block.band_mask(qi, ki, nr, mode, L)      # (nb, nr, nr)
        s = jnp.einsum(s_eq, qb, kt, preferred_element_type=f32)
        allow = allow[None, None] & w_allow(wt)
        terms.append((jnp.where(allow, s, h1d_block.NEG_INF), vt, wt))

    add(0)
    add(-1)
    if not causal:
        add(1)

    m = jnp.maximum(
        functools.reduce(jnp.maximum, [t[0].max(-1) for t in terms]),
        h1d_block._MIN_M)
    y = dn = None
    for s, vt, wt in terms:
        a = jnp.exp(s - m[..., None])
        yt = jnp.einsum(y_eq, a, vt, preferred_element_type=f32)
        dt = jnp.einsum("bgnqk,bnk->bgnq", a, wt,
                        preferred_element_type=f32)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt
    return (hc.unblock(y, axis=-3), hc.unblock(dn, axis=-2),
            hc.unblock(m, axis=-2))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _band_attention_kernel(q, k, v, w, nr, mode, tq, interpret):
    return h1d_block.band_attention_fwd(
        q, k, v, w, nr=nr, mode=mode, tq=tq, interpret=interpret)


def _fwd(q, k, v, w, nr, mode, tq, interpret):
    out = h1d_block.band_attention_fwd(
        q, k, v, w, nr=nr, mode=mode, tq=tq, interpret=interpret)
    return out, (q, k, v, w)


def _bwd(nr, mode, tq, interpret, res, cts):
    q, k, v, w = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_, w_: kref.band_attention_ref(
            q_, k_, v_, w_, nr=nr, mode=mode), q, k, v, w)
    return vjp(cts)


_band_attention_kernel.defvjp(_fwd, _bwd)


def band_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    *, nr: int, mode: str, impl: str = "jnp", tq: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded block attention for one hierarchy level.  See module doc."""
    L = q.shape[-2]
    if impl == "jnp" or L < tq:
        return _blocked_jnp(q, k, v, w, nr=nr, mode=mode)
    if impl in ("pallas", "pallas_interpret"):
        return _band_attention_kernel(
            q, k, v, w, nr, mode, tq, impl == "pallas_interpret")
    raise ValueError(f"unknown impl {impl!r}")
