"""Jit'd differentiable wrapper around the banded block attention kernel.

``band_attention(q, k, v, w, nr=..., mode=..., impl=...)``:

* ``impl='pallas'``            -- Pallas TPU kernel forward.
* ``impl='pallas_interpret'``  -- Pallas kernel in interpret mode (CPU
  validation path; executes the kernel body in Python).
* ``impl='jnp'``               -- blocked XLA implementation (used for the
  multi-pod dry-run on host-platform devices and as the backward body).

The custom VJP runs hand-written fused Pallas kernels in BOTH passes
(EXPERIMENTS.md P23): forward saves only its inputs plus its ``(y, dn,
m)`` outputs, and the backward in ``h1d_block_bwd`` recomputes the
banded scores per tile in VMEM -- no per-level band tensor is ever
re-materialized in HBM.  The ``impl='jnp'`` path stays a plain
differentiable XLA program (``jax.vjp`` of :func:`_blocked_jnp` /
:func:`_blocked_sub_jnp`) and is the gradient oracle the kernel backward
is tested against.

``mode='sub'`` (with ``ratio=2**l``) is the fine-q causal coarse level:
queries keep the fine length L while k/v/w are the level-l coarsened
sequence of length ``L / ratio`` -- see ``h1d_block`` for the fused
kernel and DESIGN.md section 2 for the tiling.

Tile-size policy: every launch resolves through the process
:class:`repro.kernels.tuning.KernelPolicy` (DESIGN.md section 10).
``impl`` is validated against the canonical enum (``'auto'`` resolves
per backend); ``tq=None`` (the default) asks the policy for the tuned /
default tile, while an explicit ``tq`` is an override that bypasses
tuning.  Either way the hint is legalized by ``resolve_tq`` -- shrunk
to the largest tile compatible with (L, nr, mode) instead of silently
falling back to XLA, so kernel benchmarks and parity tests always
measure what they claim to.  A truly incompatible shape (L not a
multiple of nr) raises.

Mesh-aware dispatch: inside an ``sp_scope(mesh)`` region
(``repro.parallel.sp_attention``), kernel-path calls whose sequence
length shards over the ``data`` axis route through
``sp_band_attention`` -- each shard runs this module's unmodified
kernels on its local rows and the boundary blocks arrive via one
packed ``ppermute`` halo exchange per direction.  Shapes too short to
keep an ``nr``-row block per shard stay on the single-launch kernel
(still ``pallas``, never a silent ``jnp`` downgrade).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import h1d_block
from . import h1d_block_bwd
from . import tuning
from .tuning import resolve_tq  # re-exported; historically lived here


def _blocked_jnp(q, k, v, w, *, nr: int, mode: str):
    """O(L * nr) blocked XLA implementation (linear-memory reference).

    Mirrors the kernel tiling but with plain jnp ops; this is what the
    distributed dry-run lowers (Pallas TPU kernels cannot compile for the
    host platform).

    ``k``/``v`` may be (B, L, d) (shared across the G query groups) or
    (B, G, L, d) (per-head KV, GSPMD-friendly: the head axis flows
    through every einsum, so the partitioner never sees size-1 dims or
    sharded-axis splits).
    """
    from repro.core import hierarchy as hc

    B, G, L, d = q.shape
    kv_g = k.ndim == 4
    f32 = jnp.float32
    causal = mode.endswith("causal")
    qb = hc.block(q.astype(f32), nr)                    # (B,G,NB,nr,d)
    kb = hc.block(k.astype(f32), nr)
    vb = hc.block(v.astype(f32), nr)
    wb = hc.block(w.astype(f32), nr, axis=-1)
    nb = qb.shape[-3]
    s_eq = "bgnqd,bgnkd->bgnqk" if kv_g else "bgnqd,bnkd->bgnqk"
    y_eq = "bgnqk,bgnkv->bgnqv" if kv_g else "bgnqk,bnkv->bgnqv"
    w_allow = (lambda wt: (wt > 0)[:, None, :, None, :])

    terms = []

    def add(offset):
        kt = hc.shift_blocks(kb, offset)
        vt = hc.shift_blocks(vb, offset)
        wt = hc.shift_blocks(wb, offset, block_axis=-2)
        qi = jnp.arange(nr)[:, None] + jnp.arange(nb)[:, None, None] * nr
        ki = qi.transpose(0, 2, 1) + offset * nr
        allow = h1d_block.band_mask(qi, ki, nr, mode, L)      # (nb, nr, nr)
        s = jnp.einsum(s_eq, qb, kt, preferred_element_type=f32)
        allow = allow[None, None] & w_allow(wt)
        terms.append((jnp.where(allow, s, h1d_block.NEG_INF), vt, wt))

    add(0)
    add(-1)
    if not causal:
        add(1)

    m = jnp.maximum(
        functools.reduce(jnp.maximum, [t[0].max(-1) for t in terms]),
        h1d_block._MIN_M)
    y = dn = None
    for s, vt, wt in terms:
        a = jnp.exp(s - m[..., None])
        yt = jnp.einsum(y_eq, a, vt, preferred_element_type=f32)
        dt = jnp.einsum("bgnqk,bnk->bgnq", a, wt,
                        preferred_element_type=f32)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt
    return (hc.unblock(y, axis=-3), hc.unblock(dn, axis=-2),
            hc.unblock(m, axis=-2))


def _blocked_sub_jnp(q, k, v, w, *, nr: int, ratio: int):
    """Blocked XLA implementation of ``mode='sub'`` (fine-q causal coarse
    level): fine query blocks of ``nq = nr * ratio`` rows against the
    previous coarse key block, masked by ``band_mask`` -- the same
    partition as the Pallas sub kernel, kept as its gradient oracle.
    """
    from repro.core import hierarchy as hc

    B, G, Lq, d = q.shape
    Lk = k.shape[1]
    kv_g = k.ndim == 4
    f32 = jnp.float32
    nq = nr * ratio
    qb = hc.block(q.astype(f32), nq)                    # (B,G,NB,nq,d)
    kt = hc.shift_blocks(hc.block(k.astype(f32), nr), -1)
    vt = hc.shift_blocks(hc.block(v.astype(f32), nr), -1)
    wt = hc.shift_blocks(hc.block(w.astype(f32), nr, axis=-1), -1,
                         block_axis=-2)
    nb = qb.shape[-3]
    qi = jnp.arange(nq)[:, None] + jnp.arange(nb)[:, None, None] * nq
    ki = (jnp.arange(nr)[None, :] + (jnp.arange(nb)[:, None, None] - 1) * nr)
    allow = h1d_block.band_mask(qi, ki, nr, "sub", Lk, ratio)  # (nb, nq, nr)
    s_eq = "bgnqd,bgnkd->bgnqk" if kv_g else "bgnqd,bnkd->bgnqk"
    y_eq = "bgnqk,bgnkv->bgnqv" if kv_g else "bgnqk,bnkv->bgnqv"
    s = jnp.einsum(s_eq, qb, kt, preferred_element_type=f32)
    allow = allow[None, None] & (wt > 0)[:, None, :, None, :]
    s = jnp.where(allow, s, h1d_block.NEG_INF)
    m = jnp.maximum(s.max(-1), h1d_block._MIN_M)
    a = jnp.exp(s - m[..., None])
    y = jnp.einsum(y_eq, a, vt, preferred_element_type=f32)
    dn = jnp.einsum("bgnqk,bnk->bgnq", a, wt, preferred_element_type=f32)
    return (hc.unblock(y, axis=-3), hc.unblock(dn, axis=-2),
            hc.unblock(m, axis=-2))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _band_attention_kernel(q, k, v, w, nr, mode, tq, ratio, interpret):
    return h1d_block.band_attention_fwd(
        q, k, v, w, nr=nr, mode=mode, tq=tq, ratio=ratio,
        interpret=interpret)


def _fwd(q, k, v, w, nr, mode, tq, ratio, interpret):
    out = h1d_block.band_attention_fwd(
        q, k, v, w, nr=nr, mode=mode, tq=tq, ratio=ratio,
        interpret=interpret)
    y, dn, m = out
    # (y, dn, m) are the whole softmax residual: the backward recomputes
    # scores from (q, k, w, m) and needs y/dn only for the row-wise
    # delta term -- nothing tile-shaped is saved.
    return out, (q, k, v, w, y, dn, m)


def _bwd(nr, mode, tq, ratio, interpret, res, cts):
    q, k, v, w, y, dn, m = res
    gy, gdn, gm = cts
    return h1d_block_bwd.band_attention_bwd(
        q, k, v, w, y, dn, m, gy, gdn, gm,
        nr=nr, mode=mode, tq=tq, ratio=ratio, interpret=interpret)


_band_attention_kernel.defvjp(_fwd, _bwd)


def band_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
    *, nr: int, mode: str, impl: str = "jnp", tq: Optional[int] = None,
    ratio: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded block attention for one hierarchy level.  See module doc."""
    policy = tuning.get_policy()
    impl = policy.resolve_impl(impl)
    L = q.shape[-2]
    if impl == "jnp":
        if mode == h1d_block.SUB_MODE:
            return _blocked_sub_jnp(q, k, v, w, nr=nr, ratio=ratio)
        return _blocked_jnp(q, k, v, w, nr=nr, mode=mode)
    # impl is 'pallas' or 'pallas_interpret' (the enum admits nothing else)
    ctx = _sp_ctx()
    if ctx is not None and _sp_shardable(L, ctx, nr, mode, ratio):
        from repro.parallel.sp_attention import sp_band_attention
        return sp_band_attention(q, k, v, w, nr=nr, mode=mode,
                                 ratio=ratio, impl=impl, tq=tq,
                                 mesh=ctx[0], axis=ctx[1])
    hint = policy.band_tq(L=L, nr=nr, mode=mode, ratio=ratio,
                          dtype=str(q.dtype), override=tq)
    tq = resolve_tq(L, nr, hint, mode, ratio)
    return _band_attention_kernel(
        q, k, v, w, nr, mode, tq, ratio, impl == "pallas_interpret")


def _sp_ctx():
    """Active sequence-parallel scope, or None (lazy import: parallel ->
    kernels is the forward direction)."""
    from repro.parallel.sp_attention import sp_ctx
    return sp_ctx()


def _sp_shardable(L, ctx, nr, mode, ratio) -> bool:
    """True when (L, mode) keeps at least one whole query block per
    shard -- the condition for the SP halo-exchange path.  Shorter
    shapes stay on the single-launch kernel."""
    d = dict(ctx[0].shape).get(ctx[1], 1)
    if L % d:
        return False
    lloc = L // d
    blk = nr * ratio if mode == h1d_block.SUB_MODE else nr
    return lloc % blk == 0 and lloc >= blk
