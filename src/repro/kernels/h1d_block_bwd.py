"""Hand-written Pallas TPU backward for the banded block attention.

Flash-style recompute backward (DESIGN.md section 3): the forward saves
only its inputs and its three outputs ``(y, dn, m)`` -- no (L x 3*nr)
score or probability tensor ever hits HBM.  Both backward kernels
re-materialize the banded scores per tile in VMEM from ``(q, k, w, m)``
using the shared :func:`~repro.kernels.h1d_block.band_mask` helper, so
the band semantics cannot drift between passes.

Math.  Forward (per level, per query row ``i``):

    s_ij = q_i . k_j         (NEG_INF off-band / where w_j == 0)
    m_i  = max(max_j s_ij, _MIN_M)
    a_ij = exp(s_ij - m_i)
    y_i  = sum_j a_ij v_j,   dn_i = sum_j a_ij w_j

Given output cotangents ``(gy, gdn, gm)``:

    delta_i  = gy_i . y_i + gdn_i * dn_i     (= sum_j a_ij * da_ij)
    gmh_i    = gm_i - delta_i                (cotangent reaching m)
    da_ij    = gy_i . v_j + gdn_i * w_j
    ds_ij    = a_ij * da_ij + (gmh_i / c_i) * 1[s_ij == m_i]
    dq_i     = sum_j ds_ij k_j
    dk_j     = sum_{g,i} ds_ij q_i
    dv_j     = sum_{g,i} a_ij  gy_i
    dw_j     = sum_{g,i} a_ij  gdn_i

``c_i`` counts the argmax ties of row ``i`` (JAX's ``reduce_max`` VJP
splits the cotangent equally among ties); ``delta`` needs only the saved
outputs, which is why ``(y, dn, m)`` are the whole residual.

Two kernels (mirroring the FlashAttention-2 split):

* ``_dq_kernel``   -- query-tile grid ``(B, G, L//TQ)``.  Each tile sees
  its full band (self tile + nr-wide halo edges of both neighbours), so
  it also computes the row tie-count and emits the per-row max-gradient
  scale ``gmn = gmh / c`` consumed by the key-grid pass.
* ``_dkvw_kernel`` -- key-tile grid ``(B, L//TQ, G)`` with ``g``
  innermost: dK/dV/dW blocks accumulate across the GQA group axis in
  VMEM (output index maps ignore ``g``), so shared-KV gradients never
  materialize a per-group copy in HBM.  Halo contributions come from the
  first ``nr`` query rows of tile ``t+1`` (which read this tile's last
  ``nr`` keys as their 'prev' band) and -- bidirectional modes only --
  the last ``nr`` query rows of tile ``t-1``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .h1d_block import band_mask, NEG_INF, _MIN_M, MODES


def _recompute(q, k, w, m, qi, ki, *, nr: int, mode: str, lk: int):
    """Re-materialize one band: masked scores -> (a, ind).

    q: (nq, d) f32; k: (nk, d) f32; w: (nk,) f32; m: (nq,) f32 saved
    row-max; qi: (nq, 1) / ki: (1, nk) global indices.  Returns
    ``a = exp(s - m)`` (exactly 0 off-band via the NEG_INF mask) and the
    argmax indicator ``ind = (s == m)`` as f32.  Query rows outside
    [0, lk) (clamped neighbour tiles at the sequence edges) are masked
    here -- ``band_mask`` itself only bounds-checks keys.
    """
    f32 = jnp.float32
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32)
    allow = band_mask(qi, ki, nr, mode, lk) & (w[None, :] > 0)
    allow = allow & (qi >= 0) & (qi < lk)
    s = jnp.where(allow, s, NEG_INF)
    a = jnp.exp(s - m[:, None])
    ind = (s == m[:, None]).astype(f32)
    return a, ind


def _dq_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs
    else:
        (q_ref, ks_ref, kp_ref, kn_ref, vs_ref, vp_ref, vn_ref,
         ws_ref, wp_ref, wn_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs

    it = pl.program_id(2)
    f32 = jnp.float32
    q = q_ref[0, 0].astype(f32)                        # (TQ, d)
    m = m_ref[0, 0].astype(f32)                        # (TQ,)
    gy = gy_ref[0, 0].astype(f32)                      # (TQ, dv)
    gdn = gdn_ref[0, 0].astype(f32)                    # (TQ,)
    gmh = gmh_ref[0, 0].astype(f32)                    # (TQ,)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def band(k, v, w, k0):
        k, v, w = k.astype(f32), v.astype(f32), w.astype(f32)
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        a, ind = _recompute(q, k, w, m, qi, ki, nr=nr, mode=mode, lk=lk)
        da = jax.lax.dot_general(gy, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
        da = da + gdn[:, None] * w[None, :]
        return a * da, ind, k

    bands = [
        band(ks_ref[0], vs_ref[0], ws_ref[0], it * tq),
        band(kp_ref[0, tq - nr:, :], vp_ref[0, tq - nr:, :],
             wp_ref[0, tq - nr:], it * tq - nr),
    ]
    if not causal:
        bands.append(band(kn_ref[0, :nr, :], vn_ref[0, :nr, :],
                          wn_ref[0, :nr], (it + 1) * tq))

    count = functools.reduce(
        jnp.add, [ind.sum(axis=1) for _, ind, _ in bands])   # (TQ,)
    gmn = jnp.where(count > 0, gmh / jnp.maximum(count, 1.0), 0.0)

    dq = None
    for ds0, ind, k in bands:
        ds = ds0 + gmn[:, None] * ind
        dqt = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=f32)
        dq = dqt if dq is None else dq + dqt

    dq_ref[0, 0] = dq.astype(dq_ref.dtype)
    gmn_ref[0, 0] = gmn.astype(gmn_ref.dtype)


def _dkvw_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (k_ref, v_ref, w_ref,
         qs_ref, qn_ref, gys_ref, gyn_ref, gdns_ref, gdnn_ref,
         ms_ref, mn_ref, gmns_ref, gmnn_ref,
         dk_ref, dv_ref, dw_ref) = refs
    else:
        (k_ref, v_ref, w_ref,
         qs_ref, qn_ref, qp_ref, gys_ref, gyn_ref, gyp_ref,
         gdns_ref, gdnn_ref, gdnp_ref, ms_ref, mn_ref, mp_ref,
         gmns_ref, gmnn_ref, gmnp_ref,
         dk_ref, dv_ref, dw_ref) = refs

    it = pl.program_id(1)
    g = pl.program_id(2)
    f32 = jnp.float32
    k = k_ref[0].astype(f32)                           # (TK, d)
    v = v_ref[0].astype(f32)                           # (TK, dv)
    w = w_ref[0].astype(f32)                           # (TK,)

    def band(qrows, gyrows, gdnrows, mrows, gmnrows, q0,
             krows, vrows, wrows, k0):
        """One (query-rows x key-rows) band; returns its dK/dV/dW."""
        nq = qrows.shape[0]
        nk = krows.shape[0]
        qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (nq, 1), 0)
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, nk), 1)
        a, ind = _recompute(qrows, krows, wrows, mrows, qi, ki,
                            nr=nr, mode=mode, lk=lk)
        da = jax.lax.dot_general(gyrows, vrows, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
        da = da + gdnrows[:, None] * wrows[None, :]
        ds = a * da + gmnrows[:, None] * ind
        dk_b = jax.lax.dot_general(ds, qrows, (((0,), (0,)), ((), ())),
                                   preferred_element_type=f32)   # (nk, d)
        dv_b = jax.lax.dot_general(a, gyrows, (((0,), (0,)), ((), ())),
                                   preferred_element_type=f32)   # (nk, dv)
        dw_b = jnp.sum(a * gdnrows[:, None], axis=0)             # (nk,)
        return dk_b, dv_b, dw_b

    # self band: query tile `it` against this whole key tile.
    dk, dvv, dw = band(
        qs_ref[0, 0].astype(f32), gys_ref[0, 0].astype(f32),
        gdns_ref[0, 0].astype(f32), ms_ref[0, 0].astype(f32),
        gmns_ref[0, 0].astype(f32), it * tq, k, v, w, it * tq)

    # prev-halo: the first nr query rows of tile it+1 read this tile's
    # last nr keys as their 'prev' band.
    dk_h, dv_h, dw_h = band(
        qn_ref[0, 0, :nr, :].astype(f32), gyn_ref[0, 0, :nr, :].astype(f32),
        gdnn_ref[0, 0, :nr].astype(f32), mn_ref[0, 0, :nr].astype(f32),
        gmnn_ref[0, 0, :nr].astype(f32), (it + 1) * tq,
        k[tq - nr:], v[tq - nr:], w[tq - nr:], it * tq + tq - nr)
    dk = dk + jnp.pad(dk_h, ((tq - nr, 0), (0, 0)))
    dvv = dvv + jnp.pad(dv_h, ((tq - nr, 0), (0, 0)))
    dw = dw + jnp.pad(dw_h, ((tq - nr, 0),))

    if not causal:
        # next-halo: the last nr query rows of tile it-1 read this
        # tile's first nr keys as their 'next' band.
        dk_h, dv_h, dw_h = band(
            qp_ref[0, 0, tq - nr:, :].astype(f32),
            gyp_ref[0, 0, tq - nr:, :].astype(f32),
            gdnp_ref[0, 0, tq - nr:].astype(f32),
            mp_ref[0, 0, tq - nr:].astype(f32),
            gmnp_ref[0, 0, tq - nr:].astype(f32), it * tq - nr,
            k[:nr], v[:nr], w[:nr], it * tq)
        dk = dk + jnp.pad(dk_h, ((0, tq - nr), (0, 0)))
        dvv = dvv + jnp.pad(dv_h, ((0, tq - nr), (0, 0)))
        dw = dw + jnp.pad(dw_h, ((0, tq - nr),))

    # accumulate across the (innermost) GQA group axis: the output
    # blocks' index maps ignore g, so the block stays resident in VMEM.
    @pl.when(g == 0)
    def _init():
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dvv.astype(dv_ref.dtype)
        dw_ref[0] = dw.astype(dw_ref.dtype)

    @pl.when(g > 0)
    def _acc():
        dk_ref[0] += dk.astype(dk_ref.dtype)
        dv_ref[0] += dvv.astype(dv_ref.dtype)
        dw_ref[0] += dw.astype(dw_ref.dtype)


def band_attention_bwd(
    q: jnp.ndarray,    # (B, G, L, d) -- pre-scaled queries (fwd input)
    k: jnp.ndarray,    # (B, L, d)
    v: jnp.ndarray,    # (B, L, dv)
    w: jnp.ndarray,    # (B, L)
    y: jnp.ndarray,    # (B, G, L, dv) f32 -- saved fwd outputs
    dn: jnp.ndarray,   # (B, G, L) f32
    m: jnp.ndarray,    # (B, G, L) f32
    gy: jnp.ndarray,   # cotangents of (y, dn, m)
    gdn: jnp.ndarray,
    gm: jnp.ndarray,
    *,
    nr: int,
    mode: str,
    tq: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused backward.  Returns (dq, dk, dv, dw) in the input dtypes."""
    assert mode in MODES, mode
    B, G, L, d = q.shape
    dv = v.shape[-1]
    assert L % tq == 0 and tq % nr == 0 and tq >= nr, (L, tq, nr)
    nt = L // tq
    causal = mode.endswith("causal")
    f32 = jnp.float32

    gy = gy.astype(f32)
    gdn = gdn.astype(f32)
    gm = gm.astype(f32)
    # delta_i = sum_j a_ij da_ij, from saved outputs alone.
    delta = jnp.sum(gy * y, axis=-1) + gdn * dn
    gmh = gm - delta                                    # (B, G, L)

    self_map = lambda b, g_, i: (b, i, 0)
    prev_map = lambda b, g_, i: (b, jnp.maximum(i - 1, 0), 0)
    next_map = lambda b, g_, i: (b, jnp.minimum(i + 1, nt - 1), 0)
    wself_map = lambda b, g_, i: (b, i)
    wprev_map = lambda b, g_, i: (b, jnp.maximum(i - 1, 0))
    wnext_map = lambda b, g_, i: (b, jnp.minimum(i + 1, nt - 1))
    qtile_map = lambda b, g_, i: (b, g_, i, 0)
    rtile_map = lambda b, g_, i: (b, g_, i)

    # ---- pass 1: dQ (query-tile grid) + per-row max-grad scale ------------
    in_specs = [pl.BlockSpec((1, 1, tq, d), qtile_map)]
    inputs = [q]
    kmaps = [self_map, prev_map] + ([] if causal else [next_map])
    wmaps = [wself_map, wprev_map] + ([] if causal else [wnext_map])
    for mp in kmaps:
        in_specs.append(pl.BlockSpec((1, tq, d), mp))
        inputs.append(k)
    for mp in kmaps:
        in_specs.append(pl.BlockSpec((1, tq, dv), mp))
        inputs.append(v)
    for mp in wmaps:
        in_specs.append(pl.BlockSpec((1, tq), mp))
        inputs.append(w)
    in_specs += [pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq, dv), qtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map)]
    inputs += [m, gy, gdn, gmh]

    dq, gmn = pl.pallas_call(
        functools.partial(_dq_kernel, nr=nr, mode=mode, tq=tq, lk=L),
        grid=(B, G, nt),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 1, tq, d), qtile_map),
                   pl.BlockSpec((1, 1, tq), rtile_map)),
        out_shape=(jax.ShapeDtypeStruct((B, G, L, d), f32),
                   jax.ShapeDtypeStruct((B, G, L), f32)),
        interpret=interpret,
    )(*inputs)

    # ---- pass 2: dK/dV/dW (key-tile grid, g innermost accumulates) --------
    kv_self = lambda b, i, g_: (b, i, 0)
    w_self = lambda b, i, g_: (b, i)
    q_self = lambda b, i, g_: (b, g_, i, 0)
    q_next = lambda b, i, g_: (b, g_, jnp.minimum(i + 1, nt - 1), 0)
    q_prev = lambda b, i, g_: (b, g_, jnp.maximum(i - 1, 0), 0)
    r_self = lambda b, i, g_: (b, g_, i)
    r_next = lambda b, i, g_: (b, g_, jnp.minimum(i + 1, nt - 1))
    r_prev = lambda b, i, g_: (b, g_, jnp.maximum(i - 1, 0))

    qmaps = [q_self, q_next] + ([] if causal else [q_prev])
    rmaps = [r_self, r_next] + ([] if causal else [r_prev])

    in_specs = [pl.BlockSpec((1, tq, d), kv_self),
                pl.BlockSpec((1, tq, dv), kv_self),
                pl.BlockSpec((1, tq), w_self)]
    inputs = [k, v, w]
    for mp in qmaps:
        in_specs.append(pl.BlockSpec((1, 1, tq, d), mp))
        inputs.append(q)
    for mp in qmaps:
        in_specs.append(pl.BlockSpec((1, 1, tq, dv), mp))
        inputs.append(gy)
    for tensor in (gdn, m, gmn):
        for mp in rmaps:
            in_specs.append(pl.BlockSpec((1, 1, tq), mp))
            inputs.append(tensor)

    dk, dvv, dw = pl.pallas_call(
        functools.partial(_dkvw_kernel, nr=nr, mode=mode, tq=tq, lk=L),
        grid=(B, nt, G),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, tq, d), kv_self),
                   pl.BlockSpec((1, tq, dv), kv_self),
                   pl.BlockSpec((1, tq), w_self)),
        out_shape=(jax.ShapeDtypeStruct((B, L, d), f32),
                   jax.ShapeDtypeStruct((B, L, dv), f32),
                   jax.ShapeDtypeStruct((B, L), f32)),
        interpret=interpret,
    )(*inputs)

    return (dq.astype(q.dtype), dk.astype(k.dtype),
            dvv.astype(v.dtype), dw.astype(w.dtype))
