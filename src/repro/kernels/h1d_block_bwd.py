"""Hand-written Pallas TPU backward for the banded block attention.

Flash-style recompute backward (DESIGN.md section 3): the forward saves
only its inputs and its three outputs ``(y, dn, m)`` -- no (L x 3*nr)
score or probability tensor ever hits HBM.  Both backward kernels
re-materialize the banded scores per tile in VMEM from ``(q, k, w, m)``
using the shared :func:`~repro.kernels.h1d_block.band_mask` helper, so
the band semantics cannot drift between passes.

Math.  Forward (per level, per query row ``i``):

    s_ij = q_i . k_j         (NEG_INF off-band / where w_j == 0)
    m_i  = max(max_j s_ij, _MIN_M)
    a_ij = exp(s_ij - m_i)
    y_i  = sum_j a_ij v_j,   dn_i = sum_j a_ij w_j

Given output cotangents ``(gy, gdn, gm)``:

    delta_i  = gy_i . y_i + gdn_i * dn_i     (= sum_j a_ij * da_ij)
    gmh_i    = gm_i - delta_i                (cotangent reaching m)
    da_ij    = gy_i . v_j + gdn_i * w_j
    ds_ij    = a_ij * da_ij + (gmh_i / c_i) * 1[s_ij == m_i]
    dq_i     = sum_j ds_ij k_j
    dk_j     = sum_{g,i} ds_ij q_i
    dv_j     = sum_{g,i} a_ij  gy_i
    dw_j     = sum_{g,i} a_ij  gdn_i

``c_i`` counts the argmax ties of row ``i`` (JAX's ``reduce_max`` VJP
splits the cotangent equally among ties); ``delta`` needs only the saved
outputs, which is why ``(y, dn, m)`` are the whole residual.

Two kernels (mirroring the FlashAttention-2 split):

* ``_dq_kernel``   -- query-tile grid ``(B, G, L//TQ)``.  Each tile sees
  its full band (self tile + nr-wide halo edges of both neighbours), so
  it also computes the row tie-count and emits the per-row max-gradient
  scale ``gmn = gmh / c`` consumed by the key-grid pass.
* ``_dkvw_kernel`` -- key-tile grid ``(B, L//TQ, G)`` with ``g``
  innermost: dK/dV/dW blocks accumulate across the GQA group axis in
  VMEM (output index maps ignore ``g``), so shared-KV gradients never
  materialize a per-group copy in HBM.  Halo contributions come from the
  first ``nr`` query rows of tile ``t+1`` (which read this tile's last
  ``nr`` keys as their 'prev' band) and -- bidirectional modes only --
  the last ``nr`` query rows of tile ``t-1``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.contracts import launch

from .h1d_block import (band_mask, sub_kv_specs, NEG_INF, MODES, SUB_MODE,
                        SUB_KV_NAMES)


def _recompute(q, k, w, m, qi, ki, *, nr: int, mode: str, lk: int,
               ratio: int = 1, lq: int = None):
    """Re-materialize one band: masked scores -> (a, ind).

    q: (nq, d) f32; k: (nk, d) f32; w: (nk,) f32; m: (nq,) f32 saved
    row-max; qi: (nq, 1) / ki: (1, nk) global indices.  Returns
    ``a = exp(s - m)`` (exactly 0 off-band via the NEG_INF mask) and the
    argmax indicator ``ind = (s == m)`` as f32.  Query rows outside
    [0, lq) (clamped neighbour tiles at the sequence edges) are masked
    here -- ``band_mask`` itself only bounds-checks keys.  ``lq``
    defaults to ``lk``; the ``sub`` mode passes the fine query length
    (= lk * ratio) since its key axis is coarse.
    """
    f32 = jnp.float32
    lq = lk if lq is None else lq
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=f32)
    allow = band_mask(qi, ki, nr, mode, lk, ratio) & (w[None, :] > 0)
    allow = allow & (qi >= 0) & (qi < lq)
    s = jnp.where(allow, s, NEG_INF)
    a = jnp.exp(s - m[:, None])
    ind = (s == m[:, None]).astype(f32)
    return a, ind


def _dq_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs
    else:
        (q_ref, ks_ref, kp_ref, kn_ref, vs_ref, vp_ref, vn_ref,
         ws_ref, wp_ref, wn_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs

    it = pl.program_id(2)
    f32 = jnp.float32
    q = q_ref[0, 0].astype(f32)                        # (TQ, d)
    m = m_ref[0, 0].astype(f32)                        # (TQ,)
    gy = gy_ref[0, 0].astype(f32)                      # (TQ, dv)
    gdn = gdn_ref[0, 0].astype(f32)                    # (TQ,)
    gmh = gmh_ref[0, 0].astype(f32)                    # (TQ,)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def band(k, v, w, k0):
        k, v, w = k.astype(f32), v.astype(f32), w.astype(f32)
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        a, ind = _recompute(q, k, w, m, qi, ki, nr=nr, mode=mode, lk=lk)
        da = jax.lax.dot_general(gy, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
        da = da + gdn[:, None] * w[None, :]
        return a * da, ind, k

    # halo refs are exact nr-row blocks (see band_attention_fwd's specs)
    bands = [
        band(ks_ref[0], vs_ref[0], ws_ref[0], it * tq),
        band(kp_ref[0], vp_ref[0], wp_ref[0], it * tq - nr),
    ]
    if not causal:
        bands.append(band(kn_ref[0], vn_ref[0], wn_ref[0], (it + 1) * tq))

    count = functools.reduce(
        jnp.add, [ind.sum(axis=1) for _, ind, _ in bands])   # (TQ,)
    gmn = jnp.where(count > 0, gmh / jnp.maximum(count, 1.0), 0.0)

    dq = None
    for ds0, ind, k in bands:
        ds = ds0 + gmn[:, None] * ind
        dqt = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=f32)
        dq = dqt if dq is None else dq + dqt

    dq_ref[0, 0] = dq.astype(dq_ref.dtype)
    gmn_ref[0, 0] = gmn.astype(gmn_ref.dtype)


def _dkvw_kernel(*refs, nr: int, mode: str, tq: int, lk: int):
    causal = mode.endswith("causal")
    if causal:
        (k_ref, v_ref, w_ref,
         qs_ref, qn_ref, gys_ref, gyn_ref, gdns_ref, gdnn_ref,
         ms_ref, mn_ref, gmns_ref, gmnn_ref,
         dk_ref, dv_ref, dw_ref) = refs
    else:
        (k_ref, v_ref, w_ref,
         qs_ref, qn_ref, qp_ref, gys_ref, gyn_ref, gyp_ref,
         gdns_ref, gdnn_ref, gdnp_ref, ms_ref, mn_ref, mp_ref,
         gmns_ref, gmnn_ref, gmnp_ref,
         dk_ref, dv_ref, dw_ref) = refs

    it = pl.program_id(1)
    g = pl.program_id(2)
    f32 = jnp.float32
    k = k_ref[0].astype(f32)                           # (TK, d)
    v = v_ref[0].astype(f32)                           # (TK, dv)
    w = w_ref[0].astype(f32)                           # (TK,)

    def band(qrows, gyrows, gdnrows, mrows, gmnrows, q0,
             krows, vrows, wrows, k0):
        """One (query-rows x key-rows) band; returns its dK/dV/dW."""
        nq = qrows.shape[0]
        nk = krows.shape[0]
        qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (nq, 1), 0)
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, nk), 1)
        a, ind = _recompute(qrows, krows, wrows, mrows, qi, ki,
                            nr=nr, mode=mode, lk=lk)
        da = jax.lax.dot_general(gyrows, vrows, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
        da = da + gdnrows[:, None] * wrows[None, :]
        ds = a * da + gmnrows[:, None] * ind
        dk_b = jax.lax.dot_general(ds, qrows, (((0,), (0,)), ((), ())),
                                   preferred_element_type=f32)   # (nk, d)
        dv_b = jax.lax.dot_general(a, gyrows, (((0,), (0,)), ((), ())),
                                   preferred_element_type=f32)   # (nk, dv)
        dw_b = jnp.sum(a * gdnrows[:, None], axis=0)             # (nk,)
        return dk_b, dv_b, dw_b

    # self band: query tile `it` against this whole key tile.
    dk, dvv, dw = band(
        qs_ref[0, 0].astype(f32), gys_ref[0, 0].astype(f32),
        gdns_ref[0, 0].astype(f32), ms_ref[0, 0].astype(f32),
        gmns_ref[0, 0].astype(f32), it * tq, k, v, w, it * tq)

    # prev-halo: the first nr query rows of tile it+1 read this tile's
    # last nr keys as their 'prev' band (refs are exact nr-row blocks).
    dk_h, dv_h, dw_h = band(
        qn_ref[0, 0].astype(f32), gyn_ref[0, 0].astype(f32),
        gdnn_ref[0, 0].astype(f32), mn_ref[0, 0].astype(f32),
        gmnn_ref[0, 0].astype(f32), (it + 1) * tq,
        k[tq - nr:], v[tq - nr:], w[tq - nr:], it * tq + tq - nr)
    dk = dk + jnp.pad(dk_h, ((tq - nr, 0), (0, 0)))
    dvv = dvv + jnp.pad(dv_h, ((tq - nr, 0), (0, 0)))
    dw = dw + jnp.pad(dw_h, ((tq - nr, 0),))

    if not causal:
        # next-halo: the last nr query rows of tile it-1 read this
        # tile's first nr keys as their 'next' band.
        dk_h, dv_h, dw_h = band(
            qp_ref[0, 0].astype(f32),
            gyp_ref[0, 0].astype(f32),
            gdnp_ref[0, 0].astype(f32),
            mp_ref[0, 0].astype(f32),
            gmnp_ref[0, 0].astype(f32), it * tq - nr,
            k[:nr], v[:nr], w[:nr], it * tq)
        dk = dk + jnp.pad(dk_h, ((0, tq - nr), (0, 0)))
        dvv = dvv + jnp.pad(dv_h, ((0, tq - nr), (0, 0)))
        dw = dw + jnp.pad(dw_h, ((0, tq - nr),))

    # accumulate across the (innermost) GQA group axis: the output
    # blocks' index maps ignore g, so the block stays resident in VMEM.
    @pl.when(g == 0)
    def _init():
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dvv.astype(dv_ref.dtype)
        dw_ref[0] = dw.astype(dw_ref.dtype)

    @pl.when(g > 0)
    def _acc():
        dk_ref[0] += dk.astype(dk_ref.dtype)
        dv_ref[0] += dvv.astype(dv_ref.dtype)
        dw_ref[0] += dw.astype(dw_ref.dtype)


def _dq_sub_kernel(*refs, nr: int, ratio: int, tq: int, lk: int):
    """Fine-q causal dQ pass: mirrors ``_fwd_sub_kernel``'s band layout
    (wide: prev-tail + self-head coarse window; deep: single coarse
    block I-1) and emits the per-row max-gradient scale ``gmn``."""
    nq = nr * ratio
    if nq <= tq:
        (q_ref, ks_ref, kp_ref, vs_ref, vp_ref, ws_ref, wp_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs
    else:
        (q_ref, kb_ref, vb_ref, wb_ref,
         m_ref, gy_ref, gdn_ref, gmh_ref, dq_ref, gmn_ref) = refs

    it = pl.program_id(2)
    f32 = jnp.float32
    q = q_ref[0, 0].astype(f32)                        # (TQ, d)
    m = m_ref[0, 0].astype(f32)
    gy = gy_ref[0, 0].astype(f32)
    gdn = gdn_ref[0, 0].astype(f32)
    gmh = gmh_ref[0, 0].astype(f32)
    qi = it * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)

    def band(k, v, w, k0):
        k, v, w = k.astype(f32), v.astype(f32), w.astype(f32)
        tk = k.shape[0]
        ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, tk), 1)
        a, ind = _recompute(q, k, w, m, qi, ki, nr=nr, mode=SUB_MODE,
                            lk=lk, ratio=ratio, lq=lk * ratio)
        da = jax.lax.dot_general(gy, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
        da = da + gdn[:, None] * w[None, :]
        return a * da, ind, k

    if nq <= tq:
        tqc = tq // ratio
        # prev-halo refs are exact nr-row coarse blocks (sub_kv_specs)
        bands = [band(kp_ref[0], vp_ref[0], wp_ref[0], it * tqc - nr)]
        if tqc > nr:
            bands.append(band(ks_ref[0, :tqc - nr, :], vs_ref[0, :tqc - nr, :],
                              ws_ref[0, :tqc - nr], it * tqc))
    else:
        s_blk = nq // tq
        bands = [band(kb_ref[0], vb_ref[0], wb_ref[0],
                      (it // s_blk - 1) * nr)]

    count = functools.reduce(
        jnp.add, [ind.sum(axis=1) for _, ind, _ in bands])   # (TQ,)
    gmn = jnp.where(count > 0, gmh / jnp.maximum(count, 1.0), 0.0)

    dq = None
    for ds0, ind, k in bands:
        ds = ds0 + gmn[:, None] * ind
        dqt = jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=f32)
        dq = dqt if dq is None else dq + dqt

    dq_ref[0, 0] = dq.astype(dq_ref.dtype)
    gmn_ref[0, 0] = gmn.astype(gmn_ref.dtype)


def _sub_band_dkvw(qrows, gyrows, gdnrows, mrows, gmnrows, q0,
                   krows, vrows, wrows, k0, *, nr, ratio, lk):
    """One fine-query x coarse-key band of the sub dK/dV/dW pass."""
    f32 = jnp.float32
    nq_rows = qrows.shape[0]
    nk = krows.shape[0]
    qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (nq_rows, 1), 0)
    ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, nk), 1)
    a, ind = _recompute(qrows, krows, wrows, mrows, qi, ki, nr=nr,
                        mode=SUB_MODE, lk=lk, ratio=ratio, lq=lk * ratio)
    da = jax.lax.dot_general(gyrows, vrows, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    da = da + gdnrows[:, None] * wrows[None, :]
    ds = a * da + gmnrows[:, None] * ind
    dk_b = jax.lax.dot_general(ds, qrows, (((0,), (0,)), ((), ())),
                               preferred_element_type=f32)    # (nk, d)
    dv_b = jax.lax.dot_general(a, gyrows, (((0,), (0,)), ((), ())),
                               preferred_element_type=f32)    # (nk, dv)
    dw_b = jnp.sum(a * gdnrows[:, None], axis=0)              # (nk,)
    return dk_b, dv_b, dw_b


def _dkvw_sub_wide_kernel(*refs, nr: int, ratio: int, tq: int, lk: int):
    """sub dK/dV/dW, wide layout (nq <= tq): key-tile grid (B, NT, G)
    over coarse tiles of ``tqc = tq // ratio`` rows, aligned with the
    fine query tiles.  The queries reading coarse tile ``it`` are the
    fine window [it*tq + nq, (it+1)*tq + nq): the tail of the SELF fine
    tile plus the first ``nq`` rows of the NEXT fine tile (the exact
    transpose of the forward's prev-tail/self-head key window)."""
    (k_ref, v_ref, w_ref,
     qs_ref, qn_ref, gys_ref, gyn_ref, gdns_ref, gdnn_ref,
     ms_ref, mn_ref, gmns_ref, gmnn_ref,
     dk_ref, dv_ref, dw_ref) = refs

    it = pl.program_id(1)
    g = pl.program_id(2)
    f32 = jnp.float32
    nq = nr * ratio
    tqc = tq // ratio
    k = k_ref[0].astype(f32)                           # (tqc, d)
    v = v_ref[0].astype(f32)
    w = w_ref[0].astype(f32)

    # next-halo: first nq query rows of tile it+1 x this tile's last nr
    # keys (the query refs are exact nq-row blocks, see the wide specs)
    dk_h, dv_h, dw_h = _sub_band_dkvw(
        qn_ref[0, 0].astype(f32), gyn_ref[0, 0].astype(f32),
        gdnn_ref[0, 0].astype(f32), mn_ref[0, 0].astype(f32),
        gmnn_ref[0, 0].astype(f32), (it + 1) * tq,
        k[tqc - nr:], v[tqc - nr:], w[tqc - nr:], (it + 1) * tqc - nr,
        nr=nr, ratio=ratio, lk=lk)
    dk = jnp.pad(dk_h, ((tqc - nr, 0), (0, 0)))
    dvv = jnp.pad(dv_h, ((tqc - nr, 0), (0, 0)))
    dw = jnp.pad(dw_h, ((tqc - nr, 0),))

    if nq < tq:
        # self band: query rows [nq:] of tile it x this tile's head keys
        dk_s, dv_s, dw_s = _sub_band_dkvw(
            qs_ref[0, 0, nq:, :].astype(f32), gys_ref[0, 0, nq:, :].astype(f32),
            gdns_ref[0, 0, nq:].astype(f32), ms_ref[0, 0, nq:].astype(f32),
            gmns_ref[0, 0, nq:].astype(f32), it * tq + nq,
            k[:tqc - nr], v[:tqc - nr], w[:tqc - nr], it * tqc,
            nr=nr, ratio=ratio, lk=lk)
        dk = dk + jnp.pad(dk_s, ((0, nr), (0, 0)))
        dvv = dvv + jnp.pad(dv_s, ((0, nr), (0, 0)))
        dw = dw + jnp.pad(dw_s, ((0, nr),))

    @pl.when(g == 0)
    def _init():
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dvv.astype(dv_ref.dtype)
        dw_ref[0] = dw.astype(dw_ref.dtype)

    @pl.when(g > 0)
    def _acc():
        dk_ref[0] += dk.astype(dk_ref.dtype)
        dv_ref[0] += dvv.astype(dv_ref.dtype)
        dw_ref[0] += dw.astype(dw_ref.dtype)


def _dkvw_sub_deep_kernel(*refs, nr: int, ratio: int, tq: int, lk: int):
    """sub dK/dV/dW, deep layout (nq > tq): grid (B, NKB, S, G) -- one
    coarse key BLOCK per ``j`` step, its nq = S*tq reading query rows
    split over the S innermost-but-one grid steps.  The (1, nr, *)
    output blocks' index maps ignore (s, g), so the accumulation over
    query sub-tiles AND the GQA group happens in VMEM."""
    (k_ref, v_ref, w_ref, q_ref, gy_ref, gdn_ref, m_ref, gmn_ref,
     dk_ref, dv_ref, dw_ref) = refs

    jt = pl.program_id(1)
    s = pl.program_id(2)
    g = pl.program_id(3)
    f32 = jnp.float32
    s_blk = (nr * ratio) // tq
    q0 = ((jt + 1) * s_blk + s) * tq
    dk, dvv, dw = _sub_band_dkvw(
        q_ref[0, 0].astype(f32), gy_ref[0, 0].astype(f32),
        gdn_ref[0, 0].astype(f32), m_ref[0, 0].astype(f32),
        gmn_ref[0, 0].astype(f32), q0,
        k_ref[0].astype(f32), v_ref[0].astype(f32), w_ref[0].astype(f32),
        jt * nr, nr=nr, ratio=ratio, lk=lk)

    @pl.when((s == 0) & (g == 0))
    def _init():
        dk_ref[0] = dk.astype(dk_ref.dtype)
        dv_ref[0] = dvv.astype(dv_ref.dtype)
        dw_ref[0] = dw.astype(dw_ref.dtype)

    @pl.when((s > 0) | (g > 0))
    def _acc():
        dk_ref[0] += dk.astype(dk_ref.dtype)
        dv_ref[0] += dvv.astype(dv_ref.dtype)
        dw_ref[0] += dw.astype(dw_ref.dtype)


def band_attention_sub_bwd(q, k, v, w, y, dn, m, gy, gdn, gm, *,
                           nr: int, ratio: int, tq: int = 128,
                           interpret: bool = False):
    """Fused backward of the ``sub`` (fine-q causal) level.  Same
    recompute strategy as the symmetric modes: only ``(q, k, v, w)`` and
    the saved outputs ``(y, dn, m)`` are read; the banded scores are
    re-materialized per tile in VMEM.  Returns (dq, dk, dv, dw)."""
    B, G, Lq, d = q.shape
    Lk = k.shape[1]
    dv = v.shape[-1]
    nq = nr * ratio
    assert ratio >= 2 and Lq == Lk * ratio, (Lq, Lk, ratio)
    assert Lq % tq == 0 and tq % nr == 0, (Lq, tq, nr)
    assert (tq % nq == 0) or (nq % tq == 0), (tq, nq)
    nt = Lq // tq
    f32 = jnp.float32

    gy = gy.astype(f32)
    gdn = gdn.astype(f32)
    gm = gm.astype(f32)
    delta = jnp.sum(gy * y, axis=-1) + gdn * dn
    gmh = gm - delta                                    # (B, G, Lq)

    qtile_map = lambda b, g_, i: (b, g_, i, 0)
    rtile_map = lambda b, g_, i: (b, g_, i)

    # ---- pass 1: dQ (fine query-tile grid) + per-row max-grad scale -------
    in_specs = [pl.BlockSpec((1, 1, tq, d), qtile_map)]
    build, layout = sub_kv_specs(nr, ratio, tq)
    kv_specs, kv_inputs = build(k, v, w, d, dv)
    in_specs += kv_specs
    inputs = [q] + kv_inputs
    in_specs += [pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq, dv), qtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map)]
    inputs += [m, gy, gdn, gmh]

    dq, gmn = launch(
        functools.partial(_dq_sub_kernel, nr=nr, ratio=ratio, tq=tq, lk=Lk),
        family="sub_bwd", grid=(B, G, nt),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 1, tq, d), qtile_map),
                   pl.BlockSpec((1, 1, tq), rtile_map)),
        out_shape=(jax.ShapeDtypeStruct((B, G, Lq, d), f32),
                   jax.ShapeDtypeStruct((B, G, Lq), f32)),
        operands=inputs, interpret=interpret,
        in_names=(("q",) + SUB_KV_NAMES[layout]
                  + ("m", "gy", "gdn", "gmh")),
        out_names=("dq", "gmn"),
        meta=dict(mode=SUB_MODE, nr=nr, ratio=ratio, tq=tq, lk=Lk,
                  layout=layout, phase="dq"))

    # ---- pass 2: dK/dV/dW on the coarse key axis --------------------------
    if layout == "wide":
        tqc = tq // ratio
        # next-halo query operands are exact nq-row blocks: only the
        # first nq fine rows of tile it+1 read this coarse tile's keys
        nbq = Lq // nq
        tbq = tq // nq
        kv_self = lambda b, i, g_: (b, i, 0)
        w_self = lambda b, i, g_: (b, i)
        q_self = lambda b, i, g_: (b, g_, i, 0)
        q_next = lambda b, i, g_: (
            b, g_, jnp.minimum((i + 1) * tbq, nbq - 1), 0)
        r_self = lambda b, i, g_: (b, g_, i)
        r_next = lambda b, i, g_: (b, g_, jnp.minimum((i + 1) * tbq, nbq - 1))

        in_specs = [pl.BlockSpec((1, tqc, d), kv_self),
                    pl.BlockSpec((1, tqc, dv), kv_self),
                    pl.BlockSpec((1, tqc), w_self)]
        inputs = [k, v, w]
        for rows, mp in ((tq, q_self), (nq, q_next)):
            in_specs.append(pl.BlockSpec((1, 1, rows, d), mp))
            inputs.append(q)
        for rows, mp in ((tq, q_self), (nq, q_next)):
            in_specs.append(pl.BlockSpec((1, 1, rows, dv), mp))
            inputs.append(gy)
        for tensor in (gdn, m, gmn):
            for rows, mp in ((tq, r_self), (nq, r_next)):
                in_specs.append(pl.BlockSpec((1, 1, rows), mp))
                inputs.append(tensor)

        dk, dvv, dw = launch(
            functools.partial(_dkvw_sub_wide_kernel, nr=nr, ratio=ratio,
                              tq=tq, lk=Lk),
            family="sub_bwd", grid=(B, nt, G),
            in_specs=in_specs,
            out_specs=(pl.BlockSpec((1, tqc, d), kv_self),
                       pl.BlockSpec((1, tqc, dv), kv_self),
                       pl.BlockSpec((1, tqc), w_self)),
            out_shape=(jax.ShapeDtypeStruct((B, Lk, d), f32),
                       jax.ShapeDtypeStruct((B, Lk, dv), f32),
                       jax.ShapeDtypeStruct((B, Lk), f32)),
            operands=inputs, interpret=interpret,
            in_names=("k", "v", "w", "q_self", "q_next",
                      "gy_self", "gy_next", "gdn_self", "gdn_next",
                      "m_self", "m_next", "gmn_self", "gmn_next"),
            out_names=("dk", "dv", "dw"),
            meta=dict(mode=SUB_MODE, nr=nr, ratio=ratio, tq=tq, lk=Lk,
                      layout="wide", phase="dkvw"))
    else:
        s_blk = nq // tq
        nkb = Lk // nr
        kv_blk = lambda b, j, s, g_: (b, j, 0)
        w_blk = lambda b, j, s, g_: (b, j)
        q_map = lambda b, j, s, g_: (
            b, g_, jnp.minimum((j + 1) * s_blk + s, nt - 1), 0)
        r_map = lambda b, j, s, g_: (
            b, g_, jnp.minimum((j + 1) * s_blk + s, nt - 1))

        in_specs = [pl.BlockSpec((1, nr, d), kv_blk),
                    pl.BlockSpec((1, nr, dv), kv_blk),
                    pl.BlockSpec((1, nr), w_blk),
                    pl.BlockSpec((1, 1, tq, d), q_map),
                    pl.BlockSpec((1, 1, tq, dv), q_map),
                    pl.BlockSpec((1, 1, tq), r_map),
                    pl.BlockSpec((1, 1, tq), r_map),
                    pl.BlockSpec((1, 1, tq), r_map)]
        inputs = [k, v, w, q, gy, gdn, m, gmn]

        dk, dvv, dw = launch(
            functools.partial(_dkvw_sub_deep_kernel, nr=nr, ratio=ratio,
                              tq=tq, lk=Lk),
            family="sub_bwd", grid=(B, nkb, s_blk, G),
            in_specs=in_specs,
            out_specs=(pl.BlockSpec((1, nr, d), kv_blk),
                       pl.BlockSpec((1, nr, dv), kv_blk),
                       pl.BlockSpec((1, nr), w_blk)),
            out_shape=(jax.ShapeDtypeStruct((B, Lk, d), f32),
                       jax.ShapeDtypeStruct((B, Lk, dv), f32),
                       jax.ShapeDtypeStruct((B, Lk), f32)),
            operands=inputs, interpret=interpret,
            in_names=("k", "v", "w", "q", "gy", "gdn", "m", "gmn"),
            out_names=("dk", "dv", "dw"),
            meta=dict(mode=SUB_MODE, nr=nr, ratio=ratio, tq=tq, lk=Lk,
                      layout="deep", phase="dkvw"))

    return (dq.astype(q.dtype), dk.astype(k.dtype),
            dvv.astype(v.dtype), dw.astype(w.dtype))


def band_attention_bwd(
    q: jnp.ndarray,    # (B, G, L, d) -- pre-scaled queries (fwd input)
    k: jnp.ndarray,    # (B, L, d)
    v: jnp.ndarray,    # (B, L, dv)
    w: jnp.ndarray,    # (B, L)
    y: jnp.ndarray,    # (B, G, L, dv) f32 -- saved fwd outputs
    dn: jnp.ndarray,   # (B, G, L) f32
    m: jnp.ndarray,    # (B, G, L) f32
    gy: jnp.ndarray,   # cotangents of (y, dn, m)
    gdn: jnp.ndarray,
    gm: jnp.ndarray,
    *,
    nr: int,
    mode: str,
    tq: int = 128,
    ratio: int = 1,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused backward.  Returns (dq, dk, dv, dw) in the input dtypes."""
    if mode == SUB_MODE:
        return band_attention_sub_bwd(q, k, v, w, y, dn, m, gy, gdn, gm,
                                      nr=nr, ratio=ratio, tq=tq,
                                      interpret=interpret)
    assert mode in MODES, mode
    B, G, L, d = q.shape
    dv = v.shape[-1]
    assert L % tq == 0 and tq % nr == 0 and tq >= nr, (L, tq, nr)
    nt = L // tq
    causal = mode.endswith("causal")
    f32 = jnp.float32

    gy = gy.astype(f32)
    gdn = gdn.astype(f32)
    gm = gm.astype(f32)
    # delta_i = sum_j a_ij da_ij, from saved outputs alone.
    delta = jnp.sum(gy * y, axis=-1) + gdn * dn
    gmh = gm - delta                                    # (B, G, L)

    # self operands: full tiles; halo operands: exact nr-row blocks at
    # the neighbouring tile's edge (index maps count nr-row blocks)
    nb = L // nr
    tb = tq // nr
    self_map = lambda b, g_, i: (b, i, 0)
    prev_map = lambda b, g_, i: (b, jnp.maximum(i * tb - 1, 0), 0)
    next_map = lambda b, g_, i: (b, jnp.minimum((i + 1) * tb, nb - 1), 0)
    wself_map = lambda b, g_, i: (b, i)
    wprev_map = lambda b, g_, i: (b, jnp.maximum(i * tb - 1, 0))
    wnext_map = lambda b, g_, i: (b, jnp.minimum((i + 1) * tb, nb - 1))
    qtile_map = lambda b, g_, i: (b, g_, i, 0)
    rtile_map = lambda b, g_, i: (b, g_, i)

    # ---- pass 1: dQ (query-tile grid) + per-row max-grad scale ------------
    in_specs = [pl.BlockSpec((1, 1, tq, d), qtile_map)]
    inputs = [q]
    kmaps = [(tq, self_map), (nr, prev_map)] + (
        [] if causal else [(nr, next_map)])
    wmaps = [(tq, wself_map), (nr, wprev_map)] + (
        [] if causal else [(nr, wnext_map)])
    for rows, mp in kmaps:
        in_specs.append(pl.BlockSpec((1, rows, d), mp))
        inputs.append(k)
    for rows, mp in kmaps:
        in_specs.append(pl.BlockSpec((1, rows, dv), mp))
        inputs.append(v)
    for rows, mp in wmaps:
        in_specs.append(pl.BlockSpec((1, rows), mp))
        inputs.append(w)
    in_specs += [pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq, dv), qtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map),
                 pl.BlockSpec((1, 1, tq), rtile_map)]
    inputs += [m, gy, gdn, gmh]

    halo = ("self", "prev") if causal else ("self", "prev", "next")
    dq, gmn = launch(
        functools.partial(_dq_kernel, nr=nr, mode=mode, tq=tq, lk=L),
        family="band_bwd", grid=(B, G, nt),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 1, tq, d), qtile_map),
                   pl.BlockSpec((1, 1, tq), rtile_map)),
        out_shape=(jax.ShapeDtypeStruct((B, G, L, d), f32),
                   jax.ShapeDtypeStruct((B, G, L), f32)),
        operands=inputs, interpret=interpret,
        in_names=(("q",) + tuple(f"{a}_{h}" for a in "kvw" for h in halo)
                  + ("m", "gy", "gdn", "gmh")),
        out_names=("dq", "gmn"),
        meta=dict(mode=mode, nr=nr, tq=tq, lk=L, phase="dq"))

    # ---- pass 2: dK/dV/dW (key-tile grid, g innermost accumulates) --------
    # halo query operands (the nr edge rows of the neighbouring tile)
    # are fetched as exact nr-row blocks, mirroring pass 1.
    kv_self = lambda b, i, g_: (b, i, 0)
    w_self = lambda b, i, g_: (b, i)
    q_self = lambda b, i, g_: (b, g_, i, 0)
    q_next = lambda b, i, g_: (b, g_, jnp.minimum((i + 1) * tb, nb - 1), 0)
    q_prev = lambda b, i, g_: (b, g_, jnp.maximum(i * tb - 1, 0), 0)
    r_self = lambda b, i, g_: (b, g_, i)
    r_next = lambda b, i, g_: (b, g_, jnp.minimum((i + 1) * tb, nb - 1))
    r_prev = lambda b, i, g_: (b, g_, jnp.maximum(i * tb - 1, 0))

    qmaps = [(tq, q_self), (nr, q_next)] + ([] if causal else [(nr, q_prev)])
    rmaps = [(tq, r_self), (nr, r_next)] + ([] if causal else [(nr, r_prev)])

    in_specs = [pl.BlockSpec((1, tq, d), kv_self),
                pl.BlockSpec((1, tq, dv), kv_self),
                pl.BlockSpec((1, tq), w_self)]
    inputs = [k, v, w]
    for rows, mp in qmaps:
        in_specs.append(pl.BlockSpec((1, 1, rows, d), mp))
        inputs.append(q)
    for rows, mp in qmaps:
        in_specs.append(pl.BlockSpec((1, 1, rows, dv), mp))
        inputs.append(gy)
    for tensor in (gdn, m, gmn):
        for rows, mp in rmaps:
            in_specs.append(pl.BlockSpec((1, 1, rows), mp))
            inputs.append(tensor)

    qhalo = ("self", "next") if causal else ("self", "next", "prev")
    dk, dvv, dw = launch(
        functools.partial(_dkvw_kernel, nr=nr, mode=mode, tq=tq, lk=L),
        family="band_bwd", grid=(B, nt, G),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, tq, d), kv_self),
                   pl.BlockSpec((1, tq, dv), kv_self),
                   pl.BlockSpec((1, tq), w_self)),
        out_shape=(jax.ShapeDtypeStruct((B, L, d), f32),
                   jax.ShapeDtypeStruct((B, L, dv), f32),
                   jax.ShapeDtypeStruct((B, L), f32)),
        operands=inputs, interpret=interpret,
        in_names=(("k", "v", "w")
                  + tuple(f"q_{h}" for h in qhalo)
                  + tuple(f"gy_{h}" for h in qhalo)
                  + tuple(f"{a}_{h}" for a in ("gdn", "m", "gmn")
                          for h in qhalo)),
        out_names=("dk", "dv", "dw"),
        meta=dict(mode=mode, nr=nr, tq=tq, lk=L, phase="dkvw"))

    return (dq.astype(q.dtype), dk.astype(k.dtype),
            dvv.astype(v.dtype), dw.astype(w.dtype))
