"""Pure-jnp oracle for the banded block attention kernel.

Dense O(L * L) implementation of exactly the same semantics as
``h1d_block.band_attention_fwd`` -- used by kernel tests
(``assert_allclose`` sweeps) and as the differentiable body for the
custom-VJP backward pass in ``ops.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .h1d_block import band_mask, NEG_INF, _MIN_M


def band_attention_ref(q, k, v, w, *, nr: int, mode: str, ratio: int = 1):
    """q: (B, G, L, d) pre-scaled; k: (B, Lk, d); v: (B, Lk, dv); w: (B, Lk).
    Returns float32 (y, dn, m) identical to the Pallas kernel.

    For ``mode='sub'`` (fine-q causal coarse level) the key length is
    ``Lk = L / ratio``; all other modes have Lk == L (ratio ignored)."""
    B, G, L, d = q.shape
    Lk = k.shape[1]
    f32 = jnp.float32
    qi = jnp.arange(L)[:, None]
    ki = jnp.arange(Lk)[None, :]
    allow = band_mask(qi, ki, nr, mode, Lk, ratio)            # (L, Lk)
    s = jnp.einsum("bgqd,bkd->bgqk", q.astype(f32), k.astype(f32),
                   preferred_element_type=f32)
    allow = allow[None, None] & (w > 0)[:, None, None, :]
    s = jnp.where(allow, s, NEG_INF)
    m = jnp.maximum(s.max(-1), _MIN_M)                        # (B, G, L)
    a = jnp.exp(s - m[..., None])
    a = jnp.where(allow, a, 0.0)
    y = jnp.einsum("bgqk,bkv->bgqv", a, v.astype(f32),
                   preferred_element_type=f32)
    dn = jnp.einsum("bgqk,bk->bgq", a, w.astype(f32),
                    preferred_element_type=f32)
    return y, dn, m
