"""Pallas TPU kernels for the serving hot path: fused single-token
hierarchical-KV decode (DESIGN.md section 4).

Two kernels, both on a ``(R,)`` grid where ``R = slots * Hkv`` (batch
rows with kv-heads folded in, the ``core.h1d_decode`` cache layout):

* :func:`decode_attend_fused` -- ONE launch computes the whole
  O(nr log L) decode attention for every row: the per-row position ``t``
  is scalar-prefetched, so the BlockSpec index maps gather exactly the
  own/prev level-0 blocks plus the single ``(I_l - 1)`` coarse block per
  level straight from HBM (one ``nr``-row read per needed block), and
  the span/quadrant masks, per-level weights ``2^l`` and the weighted
  LSE combine all happen in VMEM.  The jnp path this replaces launches
  ~``2 (M+1)`` one-hot einsums that each stream the ENTIRE cache level
  through the MXU plus a concat/softmax epilogue (EXPERIMENTS.md P25).

* :func:`update_cache_fused` -- ONE launch appends a token: for each
  level ``l`` it reads the 2-row sibling pair containing the token's
  ancestor ``t >> l``, substitutes the freshly computed row (carried in
  VMEM from level ``l-1``), and writes the pair back --
  ``input_output_aliases`` makes it an in-place scatter, so the whole
  O(log L) ancestor chain costs 2 rows read + 2 rows written per level
  instead of M+1 vmap'd ``dynamic_update_slice`` launches.

Both kernels are bit-faithful to the ``impl='jnp'`` oracle in
``core.h1d_decode`` (same masks, same single-max softmax, same pairwise
mean/sum order); ``tests/test_decode_kernel.py`` sweeps the parity.

Two PAGED variants (:func:`decode_attend_paged` /
:func:`update_cache_paged`) serve the block-pool cache of
``serve/paged_cache.py``: same bodies, same single-launch structure, but
the BlockSpec index maps read physical page rows from one
scalar-prefetched indirection table per level (the host walks the page
tables; the kernels never see logical block indices).  Two SP variants
(``*_partial``) serve sequence-sharded caches (DESIGN.md section 7).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis.contracts import launch

_MIN_M = -1e30

# an unbounded "position" domain for the paged kernels: their index
# maps consume t only through masks / in-page arithmetic, so any
# non-negative int32 is legal (the page tables carry the geometry).
_T_MAX = (1 << 30) - 1


def _band_names(nbands: int):
    return ["own", "prev"] + [f"lvl{l}" for l in range(1, nbands - 1)]


def _band_levels(nbands: int):
    """Hierarchy level of each attend band (bands 0/1 are the own/prev
    fine blocks, band ``b >= 2`` is coarse level ``b - 1``).  Exposed in
    the attend contracts' meta so ``analysis/dist.py`` can align a
    contract's per-band index maps with the cache level they read."""
    return tuple([0, 0] + list(range(1, nbands - 1)))


def _hc():
    """Lazy ``core.hierarchy`` import (module-level would cycle through
    core/__init__ -> h1d_attention -> kernels/__init__), keeping one
    source of truth for num_levels / NEG_INF."""
    from repro.core import hierarchy as hc
    return hc


def _qz():
    """Lazy ``core.quantization`` import (same cycle as :func:`_hc`);
    the kernels inline its rounding rule but source QMAX/EPS here so the
    int8 wire format has one definition."""
    from repro.core import quantization as qz
    return qz


# ---------------------------------------------------------------------------
# fused decode attention
# ---------------------------------------------------------------------------

def _attend_kernel(t_ref, q_ref, *refs, nr: int, nbands: int, scale: float,
                   neg_inf: float, quant=()):
    """One grid step = one cache row: q (1, G, D) against ``nbands``
    nr-key bands (own, prev, coarse levels 1..M-1), weighted-LSE
    combined entirely in VMEM.

    ``quant`` (per-band bools, empty = all fp) marks int8 bands: their
    K/V blocks arrive as int8 pages and are dequantized in VMEM with the
    per-row scale blocks appended after the V refs (k-scales for the
    quantized bands in band order, then v-scales)."""
    nq = sum(quant)
    k_refs = refs[:nbands]
    v_refs = refs[nbands:2 * nbands]
    ksc_refs = refs[2 * nbands:2 * nbands + nq]
    vsc_refs = refs[2 * nbands + nq:2 * nbands + 2 * nq]
    o_ref = refs[2 * nbands + 2 * nq]
    r = pl.program_id(0)
    t = t_ref[r]
    f32 = jnp.float32

    q = q_ref[0].astype(f32) * scale                     # (G, D)
    ki = jax.lax.broadcasted_iota(jnp.int32, (1, nr), 1)  # key idx in band
    b0 = t // nr

    logits, values, weights = [], [], []
    si = 0
    for band in range(nbands):
        kb = k_refs[band][0].astype(f32)                 # (nr, D)
        vb = v_refs[band][0].astype(f32)                 # (nr, Dv)
        if quant and quant[band]:
            kb = kb * ksc_refs[si][0][:, None]
            vb = vb * vsc_refs[si][0][:, None]
            si += 1
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)   # (G, nr)
        if band == 0:          # own level-0 block, causal within the block
            pos = b0 * nr + ki
            mask = pos <= t
            wgt = jnp.full((1, nr), 1.0, f32)
        elif band == 1:        # previous level-0 block
            mask = jnp.broadcast_to(b0 >= 1, (1, nr))
            wgt = jnp.full((1, nr), 1.0, f32)
        else:                  # coarse level l: block I_l - 1, quadrant mask
            l = band - 1
            span = nr << l
            Il = t // span
            first_half_q = (t % span) < (span // 2)
            key_last_half = ki >= (nr // 2)
            mask = (Il >= 1) & ~(first_half_q & key_last_half)
            wgt = jnp.full((1, nr), float(1 << l), f32)
        logits.append(jnp.where(mask, s, neg_inf))
        values.append(vb)
        weights.append(jnp.where(mask, wgt, 0.0))

    s_all = jnp.concatenate(logits, axis=-1)             # (G, K)
    v_all = jnp.concatenate(values, axis=-2)             # (K, Dv)
    w_all = jnp.concatenate(weights, axis=-1)            # (1, K)
    m = jnp.maximum(s_all.max(axis=-1, keepdims=True), _MIN_M)
    a = jnp.exp(s_all - m)
    num = jax.lax.dot_general(a, v_all, (((1,), (0,)), ((), ())),
                              preferred_element_type=f32)     # (G, Dv)
    den = jnp.sum(a * w_all, axis=-1)                    # (G,)
    o_ref[0] = num / jnp.maximum(den, 1e-9)[:, None]


def decode_attend_fused(cache, q: jnp.ndarray, t: jnp.ndarray, *, nr: int,
                        softmax_scale=None,
                        interpret: bool = False) -> jnp.ndarray:
    """Fused batched single-token attention.  ``cache`` is an
    ``H1DCache``; ``q``: (R, G, D); ``t``: (R,) int32 per-row positions.
    Returns (R, G, Dv) in ``q.dtype`` -- same contract and numerics as
    ``core.h1d_decode.decode_attend(impl='jnp')``."""
    hc = _hc()
    R, G, D = q.shape
    Lmax = cache.k.shape[-2]
    Dv = cache.v.shape[-1]
    M = hc.num_levels(Lmax, nr)
    levels = len(cache.ck)
    assert levels == max(M - 1, 0), (levels, M)
    nbands = 2 + levels
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)

    nb0 = Lmax // nr
    own_map = lambda r, tref: (r, jnp.minimum(tref[r] // nr, nb0 - 1), 0)
    prev_map = lambda r, tref: (r, jnp.maximum(tref[r] // nr - 1, 0), 0)

    def lvl_map(l):
        nbl = (Lmax >> l) // nr
        return lambda r, tref: (
            r, jnp.clip(tref[r] // (nr << l) - 1, 0, nbl - 1), 0)

    maps = [own_map, prev_map] + [lvl_map(l) for l in range(1, M)]
    k_arrs = [cache.k, cache.k] + list(cache.ck)
    v_arrs = [cache.v, cache.v] + list(cache.cv)

    in_specs = [pl.BlockSpec((1, G, D), lambda r, tref: (r, 0, 0))]
    in_specs += [pl.BlockSpec((1, nr, D), mp) for mp in maps]
    in_specs += [pl.BlockSpec((1, nr, Dv), mp) for mp in maps]

    kernel = functools.partial(_attend_kernel, nr=nr, nbands=nbands,
                               scale=float(scale), neg_inf=hc.NEG_INF)
    bn = _band_names(nbands)
    out = launch(
        kernel, family="decode_attend", grid=(R,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, Dv), lambda r, tref: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, G, Dv), jnp.float32),
        operands=[q, *k_arrs, *v_arrs],
        scalars=(t.astype(jnp.int32),),
        scalar_bounds=((0, Lmax - 1),),
        scalar_names=("t",),
        in_names=(["q"] + [f"k_{b}" for b in bn] + [f"v_{b}" for b in bn]),
        out_names=("o",), interpret=interpret,
        meta=dict(nr=nr, Lmax=Lmax, levels=levels,
                  band_levels=_band_levels(nbands)))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# sequence-parallel partial attend (sharded index maps)
# ---------------------------------------------------------------------------

def _attend_partial_kernel(t_ref, bidx_ref, own_ref, q_ref, *refs, nr: int,
                           nbands: int, scale: float, neg_inf: float):
    """Per-shard variant of :func:`_attend_kernel` for the SP path: the
    BlockSpec index maps read shard-LOCAL block indices from the
    scalar-prefetched ``bidx`` array (``repro.parallel.sp_attention``
    computes them from the global position and the shard index), each
    band is additionally masked by its ownership bit, and the outputs
    are the *partial* ``(num, den, m)`` triple instead of the
    normalized result -- the cross-shard merge is one pmax + psum."""
    k_refs = refs[:nbands]
    v_refs = refs[nbands:2 * nbands]
    num_ref, den_ref, m_ref = refs[2 * nbands:2 * nbands + 3]
    r = pl.program_id(0)
    t = t_ref[r]
    f32 = jnp.float32

    q = q_ref[0].astype(f32) * scale                     # (G, D)
    ki = jax.lax.broadcasted_iota(jnp.int32, (1, nr), 1)
    b0 = t // nr

    logits, values, weights = [], [], []
    for band in range(nbands):
        kb = k_refs[band][0].astype(f32)                 # (nr, D)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)   # (G, nr)
        if band == 0:
            pos = b0 * nr + ki
            mask = pos <= t
            wgt = jnp.full((1, nr), 1.0, f32)
        elif band == 1:
            mask = jnp.broadcast_to(b0 >= 1, (1, nr))
            wgt = jnp.full((1, nr), 1.0, f32)
        else:
            l = band - 1
            span = nr << l
            Il = t // span
            first_half_q = (t % span) < (span // 2)
            key_last_half = ki >= (nr // 2)
            mask = (Il >= 1) & ~(first_half_q & key_last_half)
            wgt = jnp.full((1, nr), float(1 << l), f32)
        mask = mask & (own_ref[r, band] > 0)
        logits.append(jnp.where(mask, s, neg_inf))
        values.append(v_refs[band][0].astype(f32))
        weights.append(jnp.where(mask, wgt, 0.0))

    s_all = jnp.concatenate(logits, axis=-1)             # (G, K)
    v_all = jnp.concatenate(values, axis=-2)             # (K, Dv)
    w_all = jnp.concatenate(weights, axis=-1)            # (1, K)
    m = jnp.maximum(s_all.max(axis=-1), _MIN_M)          # (G,)
    a = jnp.exp(s_all - m[:, None])
    num_ref[0] = jax.lax.dot_general(a, v_all, (((1,), (0,)), ((), ())),
                                     preferred_element_type=f32)
    den_ref[0] = jnp.sum(a * w_all, axis=-1)
    m_ref[0] = m


def decode_attend_partial(cache, q: jnp.ndarray, t: jnp.ndarray,
                          bidx: jnp.ndarray, owned: jnp.ndarray, *,
                          nr: int, softmax_scale=None,
                          t_hi: int = None,
                          interpret: bool = False):
    """Partial fused decode attention on shard-LOCAL cache arrays.

    ``bidx`` (R, nbands) int32 holds the local block index of each band
    in this shard's cache slab (levels may have fewer local blocks than
    the global cache); ``owned`` (R, nbands) gates bands this shard
    does not own.  ``t`` stays GLOBAL (the in-kernel masks compare
    global positions); ``t_hi`` declares its domain -- the SP caller
    passes ``Lmax - 1``, the default covers a single-shard slab.
    Returns float32 ``(num (R,G,Dv), den (R,G),
    m (R,G))`` -- merge across shards with
    ``num * exp(m - pmax(m))`` psums (``sp_attention.sp_decode_attend``).
    """
    hc = _hc()
    R, G, D = q.shape
    Dv = cache.v.shape[-1]
    levels = len(cache.ck)
    nbands = 2 + levels
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)

    def band_map(band):
        return lambda r, tref, bref, oref: (r, bref[r, band], 0)

    maps = [band_map(b) for b in range(nbands)]
    k_arrs = [cache.k, cache.k] + list(cache.ck)
    v_arrs = [cache.v, cache.v] + list(cache.cv)

    in_specs = [pl.BlockSpec((1, G, D), lambda r, tref, bref, oref: (r, 0, 0))]
    in_specs += [pl.BlockSpec((1, nr, D), mp) for mp in maps]
    in_specs += [pl.BlockSpec((1, nr, Dv), mp) for mp in maps]

    kernel = functools.partial(_attend_partial_kernel, nr=nr, nbands=nbands,
                               scale=float(scale), neg_inf=hc.NEG_INF)
    f32 = jnp.float32
    # per-band bidx domain: local nr-row block count of that band's slab
    bidx_hi = np.array([a.shape[-2] // nr - 1 for a in k_arrs],
                       dtype=np.int32)
    Lloc = cache.k.shape[-2]
    bn = _band_names(nbands)
    return launch(
        kernel, family="decode_attend_partial", grid=(R,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, G, Dv), lambda r, tref, bref, oref: (r, 0, 0)),
            pl.BlockSpec((1, G), lambda r, tref, bref, oref: (r, 0)),
            pl.BlockSpec((1, G), lambda r, tref, bref, oref: (r, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((R, G, Dv), f32),
                   jax.ShapeDtypeStruct((R, G), f32),
                   jax.ShapeDtypeStruct((R, G), f32)),
        operands=[q, *k_arrs, *v_arrs],
        scalars=(t.astype(jnp.int32), bidx.astype(jnp.int32),
                 owned.astype(jnp.int32)),
        scalar_bounds=((0, Lloc - 1 if t_hi is None else t_hi),
                       (0, bidx_hi), (0, 1)),
        scalar_names=("t", "bidx", "owned"),
        in_names=(["q"] + [f"k_{b}" for b in bn] + [f"v_{b}" for b in bn]),
        out_names=("num", "den", "m"), interpret=interpret,
        meta=dict(nr=nr, Lloc=Lloc, levels=levels,
                  band_levels=_band_levels(nbands)))


# ---------------------------------------------------------------------------
# fused ancestor update
# ---------------------------------------------------------------------------

def _update_kernel(t_ref, knew_ref, vnew_ref, *refs, nlev: int):
    """One grid step = one cache row: substitute the new fine row into
    its level-0 sibling pair, then walk the ancestor chain upward -- the
    level-l row is the pairwise mean/sum of the level-(l-1) pair, which
    is already updated in VMEM."""
    in_refs = refs[:2 * nlev]
    out_refs = refs[2 * nlev:]
    r = pl.program_id(0)
    t = t_ref[r]
    f32 = jnp.float32
    sel_row = jax.lax.broadcasted_iota(jnp.int32, (2, 1), 0)

    new_k = knew_ref[...].astype(f32)                    # (1, D)
    new_v = vnew_ref[...].astype(f32)                    # (1, Dv)
    for l in range(nlev):
        sel = sel_row == ((t >> l) & 1)
        pk = jnp.where(sel, new_k, in_refs[2 * l][0].astype(f32))
        pv = jnp.where(sel, new_v, in_refs[2 * l + 1][0].astype(f32))
        out_refs[2 * l][0] = pk.astype(out_refs[2 * l].dtype)
        out_refs[2 * l + 1][0] = pv.astype(out_refs[2 * l + 1].dtype)
        if l + 1 < nlev:
            new_k = pk.mean(axis=0, keepdims=True)       # Eq. 25/26
            new_v = pv.sum(axis=0, keepdims=True)        # Eq. 27


def update_cache_fused(cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       t: jnp.ndarray, *, interpret: bool = False):
    """Fused batched cache append.  ``k_new``: (R, D), ``v_new``:
    (R, Dv), ``t``: (R,).  Returns an updated ``H1DCache`` -- same
    contract as ``core.h1d_decode.update_cache(impl='jnp')``.

    Every level array is aliased input->output, so rows outside the
    written sibling pairs are untouched in HBM (in-place scatter)."""
    R, D = k_new.shape
    Dv = v_new.shape[-1]
    Lmax = cache.k.shape[-2]
    nlev = 1 + len(cache.ck)        # fine + coarse levels

    arrs, in_specs, out_specs, out_shape = [], [], [], []
    lvls = [(cache.k, cache.v)] + list(zip(cache.ck, cache.cv))
    for l, (ka, va) in enumerate(lvls):
        npairs = ka.shape[-2] // 2

        def pair_map(r, tref, l=l, npairs=npairs):
            return (r, jnp.minimum(tref[r] >> (l + 1), npairs - 1), 0)

        for a, d_ in ((ka, D), (va, Dv)):
            arrs.append(a)
            in_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_shape.append(jax.ShapeDtypeStruct(a.shape, a.dtype))

    # alias each cache operand to its output (operand-indexed; launch()
    # translates to pallas call-arg indices past the scalar args)
    aliases = {2 + i: i for i in range(2 * nlev)}
    kernel = functools.partial(_update_kernel, nlev=nlev)
    lvl_names = [f"{a}_l{l}" for l in range(nlev) for a in ("k", "v")]
    outs = launch(
        kernel, family="decode_update", grid=(R,),
        in_specs=[pl.BlockSpec((1, D), lambda r, tref: (r, 0)),
                  pl.BlockSpec((1, Dv), lambda r, tref: (r, 0))] + in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        operands=[k_new, v_new, *arrs],
        scalars=(t.astype(jnp.int32),),
        scalar_bounds=((0, Lmax - 1),),
        scalar_names=("t",),
        in_names=["k_new", "v_new"] + lvl_names,
        out_names=lvl_names, aliases=aliases, interpret=interpret,
        meta=dict(Lmax=Lmax, nlev=nlev))
    ck = tuple(outs[2 + 2 * i] for i in range(nlev - 1))
    cv = tuple(outs[3 + 2 * i] for i in range(nlev - 1))
    return type(cache)(k=outs[0], v=outs[1], ck=ck, cv=cv)


# ---------------------------------------------------------------------------
# paged decode attention (scalar-prefetched page-table indirection)
# ---------------------------------------------------------------------------

def _attend_paged_kernel(t_ref, bidx_ref, *rest, **kw):
    """Paged variant of :func:`_attend_kernel`: the body is IDENTICAL --
    masks and the weighted-LSE combine depend only on the global
    position ``t`` -- the page indirection lives entirely in the
    BlockSpec index maps, which read physical page rows from the
    scalar-prefetched ``bidx`` table instead of computing block indices
    from ``t``."""
    return _attend_kernel(t_ref, *rest, **kw)


def decode_attend_paged(pool, q: jnp.ndarray, t: jnp.ndarray,
                        bidx: jnp.ndarray, *, nr: int, softmax_scale=None,
                        interpret: bool = False) -> jnp.ndarray:
    """Fused single-token attention over a PAGED hierarchical KV pool.

    ``pool`` is a ``core.h1d_decode.PagedH1DCache``: per level a pool of
    ``nr``-row pages, fine ``k``/``v`` (NP0, nr, D/Dv) and coarse
    ``ck[l-1]``/``cv[l-1]`` (NP_l, nr, ...).  ``q``: (R, G, D); ``t``:
    (R,) global positions; ``bidx``: (R, 2 + levels) int32 physical page
    rows -- column 0 the own level-0 page, column 1 the previous level-0
    page, column 1+l the level-l page ``I_l - 1`` (host-side page-table
    walk; invalid bands carry any in-range page, the in-kernel masks
    zero them exactly like the dense kernel).  ONE launch on the (R,)
    grid, one ``nr``-row HBM read per band -- the dense cache's
    ``decode_attend_fused`` contract, with the block-index maps
    generalized to one scalar-prefetched indirection table per level.
    """
    hc = _hc()
    R, G, D = q.shape
    Dv = pool.v.shape[-1]
    levels = len(pool.ck)
    nbands = 2 + levels
    assert bidx.shape == (R, nbands), (bidx.shape, R, nbands)
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)

    def band_map(band):
        return lambda r, tref, bref: (bref[r, band], 0, 0)

    maps = [band_map(b) for b in range(nbands)]
    k_arrs = [pool.k, pool.k] + list(pool.ck)
    v_arrs = [pool.v, pool.v] + list(pool.cv)

    in_specs = [pl.BlockSpec((1, G, D), lambda r, tref, bref: (r, 0, 0))]
    in_specs += [pl.BlockSpec((1, nr, D), mp) for mp in maps]
    in_specs += [pl.BlockSpec((1, nr, Dv), mp) for mp in maps]

    kernel = functools.partial(_attend_paged_kernel, nr=nr, nbands=nbands,
                               scale=float(scale), neg_inf=hc.NEG_INF)
    # per-band page domain: that band's pool page count
    bidx_hi = np.array([a.shape[0] - 1 for a in k_arrs], dtype=np.int32)
    bn = _band_names(nbands)
    out = launch(
        kernel, family="decode_attend_paged", grid=(R,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, Dv), lambda r, tref, bref: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, G, Dv), jnp.float32),
        operands=[q, *k_arrs, *v_arrs],
        scalars=(t.astype(jnp.int32), bidx.astype(jnp.int32)),
        scalar_bounds=((0, _T_MAX), (0, bidx_hi)),
        scalar_names=("t", "bidx"),
        in_names=(["q"] + [f"k_{b}" for b in bn] + [f"v_{b}" for b in bn]),
        out_names=("o",), interpret=interpret,
        meta=dict(nr=nr, levels=levels))
    return out.astype(q.dtype)


def decode_attend_paged_quant(pool, q: jnp.ndarray, t: jnp.ndarray,
                              bidx: jnp.ndarray, *, nr: int,
                              softmax_scale=None,
                              interpret: bool = False) -> jnp.ndarray:
    """Quantized-pool variant of :func:`decode_attend_paged`.

    ``pool`` is a ``core.h1d_decode.QuantPagedH1DCache``: int8 pages for
    any subset of levels, with per-row f32 scales ``(NP_l, nr)``.  The
    scales ride the SAME scalar-prefetched ``bidx`` indirection as the
    pages -- one extra ``(1, nr)`` scale block per quantized band, whose
    index map reads the identical table column -- and the dequantize
    (one multiply per gathered row) happens in VMEM right before the
    QK^T dot.  Still one launch on the (R,) grid; fp32 levels of a
    mixed-precision pool skip the scale operands entirely (which levels
    are quantized is static in the array dtypes)."""
    hc = _hc()
    R, G, D = q.shape
    Dv = pool.v.shape[-1]
    levels = len(pool.ck)
    nbands = 2 + levels
    assert bidx.shape == (R, nbands), (bidx.shape, R, nbands)
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)

    lvl_quant = tuple(bool(a.dtype == jnp.int8) for a in (pool.k, *pool.ck))
    band_lvl = [0, 0] + list(range(1, 1 + levels))
    quant = tuple(lvl_quant[band_lvl[b]] for b in range(nbands))

    def band_map(band):
        return lambda r, tref, bref: (bref[r, band], 0, 0)

    def band_map_sc(band):
        return lambda r, tref, bref: (bref[r, band], 0)

    maps = [band_map(b) for b in range(nbands)]
    k_arrs = [pool.k, pool.k] + list(pool.ck)
    v_arrs = [pool.v, pool.v] + list(pool.cv)
    ksc_all = [pool.ksc, pool.ksc] + list(pool.cksc)
    vsc_all = [pool.vsc, pool.vsc] + list(pool.cvsc)
    sc_arrs, sc_specs = [], []
    for scs in (ksc_all, vsc_all):         # k-scales first, then v-scales
        for b in range(nbands):
            if quant[b]:
                sc_arrs.append(scs[b])
                sc_specs.append(pl.BlockSpec((1, nr), band_map_sc(b)))

    in_specs = [pl.BlockSpec((1, G, D), lambda r, tref, bref: (r, 0, 0))]
    in_specs += [pl.BlockSpec((1, nr, D), mp) for mp in maps]
    in_specs += [pl.BlockSpec((1, nr, Dv), mp) for mp in maps]
    in_specs += sc_specs

    kernel = functools.partial(_attend_paged_kernel, nr=nr, nbands=nbands,
                               scale=float(scale), neg_inf=hc.NEG_INF,
                               quant=quant)
    bidx_hi = np.array([a.shape[0] - 1 for a in k_arrs], dtype=np.int32)
    bn = _band_names(nbands)
    sc_names = [f"{a}sc_{bn[b]}" for a in "kv" for b in range(nbands)
                if quant[b]]
    out = launch(
        kernel, family="decode_attend_paged_quant", grid=(R,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, Dv), lambda r, tref, bref: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, G, Dv), jnp.float32),
        operands=[q, *k_arrs, *v_arrs, *sc_arrs],
        scalars=(t.astype(jnp.int32), bidx.astype(jnp.int32)),
        scalar_bounds=((0, _T_MAX), (0, bidx_hi)),
        scalar_names=("t", "bidx"),
        in_names=(["q"] + [f"k_{b}" for b in bn] + [f"v_{b}" for b in bn]
                  + sc_names),
        out_names=("o",), interpret=interpret,
        meta=dict(nr=nr, levels=levels, quant=quant))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# paged ancestor update
# ---------------------------------------------------------------------------

def _update_paged_kernel(t_ref, utab_ref, *rest, **kw):
    """Paged variant of :func:`_update_kernel`: identical body (the
    within-pair row select and the carried mean/sum use only ``t``);
    the sibling-pair location comes from the prefetched ``utab`` page
    table via the BlockSpec index maps."""
    return _update_kernel(t_ref, *rest, **kw)


def update_cache_paged(pool, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       t: jnp.ndarray, utab: jnp.ndarray, *,
                       interpret: bool = False):
    """Fused batched append into a PAGED hierarchical KV pool.

    ``k_new``: (R, D), ``v_new``: (R, Dv), ``t``: (R,) global positions,
    ``utab``: (R, 1 + levels) int32 physical page rows -- column ``l``
    is the page holding the token's level-l ancestor row ``t >> l``
    (the engine COWs / allocates these pages before the tick, and points
    inactive rows at a per-level trash page so their writes are inert).
    Within the page the sibling pair sits at local pair index
    ``(t >> (l+1)) mod (nr/2)``.  Every pool operand is aliased
    input->output (in-place scatter), same as ``update_cache_fused``."""
    R, D = k_new.shape
    Dv = v_new.shape[-1]
    nr = pool.k.shape[-2]
    nlev = 1 + len(pool.ck)
    assert utab.shape == (R, nlev), (utab.shape, R, nlev)

    arrs, in_specs, out_specs, out_shape = [], [], [], []
    lvls = [(pool.k, pool.v)] + list(zip(pool.ck, pool.cv))
    for l, (ka, va) in enumerate(lvls):

        def pair_map(r, tref, uref, l=l):
            return (uref[r, l], (tref[r] >> (l + 1)) & (nr // 2 - 1), 0)

        for a, d_ in ((ka, D), (va, Dv)):
            arrs.append(a)
            in_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_shape.append(jax.ShapeDtypeStruct(a.shape, a.dtype))

    row_map = lambda r, tref, uref: (r, 0)
    # aliases are operand-indexed ((k_new, v_new, *arrs): pool operands
    # start at 2); launch() shifts past the scalar args.
    aliases = {2 + i: i for i in range(2 * nlev)}
    kernel = functools.partial(_update_paged_kernel, nlev=nlev)
    # per-level utab domain: that level's pool page count (k page count
    # == v page count per level, lvls order == utab column order)
    utab_hi = np.array([ka.shape[0] - 1 for ka, _ in lvls], dtype=np.int32)
    lvl_names = [f"{a}_l{l}" for l in range(nlev) for a in ("k", "v")]
    outs = launch(
        kernel, family="decode_update_paged", grid=(R,),
        in_specs=[pl.BlockSpec((1, D), row_map),
                  pl.BlockSpec((1, Dv), row_map)] + in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        operands=[k_new, v_new, *arrs],
        scalars=(t.astype(jnp.int32), utab.astype(jnp.int32)),
        scalar_bounds=((0, _T_MAX), (0, utab_hi)),
        scalar_names=("t", "utab"),
        in_names=["k_new", "v_new"] + lvl_names,
        out_names=lvl_names, aliases=aliases, interpret=interpret,
        meta=dict(nr=nr, nlev=nlev))
    ck = tuple(outs[2 + 2 * i] for i in range(nlev - 1))
    cv = tuple(outs[3 + 2 * i] for i in range(nlev - 1))
    return type(pool)(k=outs[0], v=outs[1], ck=ck, cv=cv)


def _update_paged_quant_kernel(t_ref, utab_ref, knew_ref, vnew_ref, *refs,
                               nlev: int, quant, qmax: float, recip: float,
                               eps: float):
    """Quantized variant of :func:`_update_kernel`: at each int8 level
    the 2-row sibling pair is dequantized with its per-row scales, the
    new row substituted, and the pair REquantized in place (fresh absmax
    scales -- the same rounding as ``core.quantization.quantize_int8``,
    inlined so it runs on the VMEM-resident pair).  The ancestor carry
    is the PRE-quantization f32 pair mean/sum, so quantization error
    does not compound up the hierarchy within a tick."""
    nq = sum(quant)
    in_data = refs[:2 * nlev]
    in_sc = refs[2 * nlev:2 * nlev + 2 * nq]
    out_data = refs[2 * nlev + 2 * nq:4 * nlev + 2 * nq]
    out_sc = refs[4 * nlev + 2 * nq:]
    r = pl.program_id(0)
    t = t_ref[r]
    f32 = jnp.float32
    sel_row = jax.lax.broadcasted_iota(jnp.int32, (2, 1), 0)

    new_k = knew_ref[...].astype(f32)                    # (1, D)
    new_v = vnew_ref[...].astype(f32)                    # (1, Dv)
    si = 0
    for l in range(nlev):
        sel = sel_row == ((t >> l) & 1)
        kd = in_data[2 * l][0].astype(f32)               # (2, D)
        vd = in_data[2 * l + 1][0].astype(f32)
        if quant[l]:
            kd = kd * in_sc[2 * si][0][:, None]
            vd = vd * in_sc[2 * si + 1][0][:, None]
        pk = jnp.where(sel, new_k, kd)
        pv = jnp.where(sel, new_v, vd)
        if quant[l]:
            ksc = jnp.maximum(jnp.max(jnp.abs(pk), axis=1, keepdims=True),
                              eps) * recip
            vsc = jnp.maximum(jnp.max(jnp.abs(pv), axis=1, keepdims=True),
                              eps) * recip
            out_data[2 * l][0] = jnp.clip(jnp.round(pk / ksc),
                                          -qmax, qmax).astype(jnp.int8)
            out_data[2 * l + 1][0] = jnp.clip(jnp.round(pv / vsc),
                                              -qmax, qmax).astype(jnp.int8)
            out_sc[2 * si][0] = ksc[:, 0]
            out_sc[2 * si + 1][0] = vsc[:, 0]
            si += 1
        else:
            out_data[2 * l][0] = pk.astype(out_data[2 * l].dtype)
            out_data[2 * l + 1][0] = pv.astype(out_data[2 * l + 1].dtype)
        if l + 1 < nlev:
            new_k = pk.mean(axis=0, keepdims=True)       # Eq. 25/26
            new_v = pv.sum(axis=0, keepdims=True)        # Eq. 27


def update_cache_paged_quant(pool, k_new: jnp.ndarray, v_new: jnp.ndarray,
                             t: jnp.ndarray, utab: jnp.ndarray, *,
                             interpret: bool = False):
    """Fused batched append into a QUANTIZED paged pool
    (``core.h1d_decode.QuantPagedH1DCache``).

    Same single-launch in-place scatter as :func:`update_cache_paged`;
    each quantized level additionally carries a ``(1, 2)`` per-row scale
    block whose index map reads the SAME ``utab`` column / pair index as
    its data block, aliased input->output so both the int8 pair and its
    two scales rewrite in place.  fp32 levels of a mixed pool pass their
    scale arrays through untouched (never kernel operands)."""
    qz = _qz()
    R, D = k_new.shape
    Dv = v_new.shape[-1]
    nr = pool.k.shape[-2]
    nlev = 1 + len(pool.ck)
    assert utab.shape == (R, nlev), (utab.shape, R, nlev)
    quant = tuple(bool(a.dtype == jnp.int8) for a in (pool.k, *pool.ck))

    data_arrs, data_in, data_out, data_shape = [], [], [], []
    sc_arrs, sc_in, sc_out, sc_shape = [], [], [], []
    lvls = ([(pool.k, pool.v, pool.ksc, pool.vsc)]
            + list(zip(pool.ck, pool.cv, pool.cksc, pool.cvsc)))
    for l, (ka, va, ksa, vsa) in enumerate(lvls):

        def pair_map(r, tref, uref, l=l):
            return (uref[r, l], (tref[r] >> (l + 1)) & (nr // 2 - 1), 0)

        def pair_map_sc(r, tref, uref, l=l):
            return (uref[r, l], (tref[r] >> (l + 1)) & (nr // 2 - 1))

        for a, d_ in ((ka, D), (va, Dv)):
            data_arrs.append(a)
            data_in.append(pl.BlockSpec((1, 2, d_), pair_map))
            data_out.append(pl.BlockSpec((1, 2, d_), pair_map))
            data_shape.append(jax.ShapeDtypeStruct(a.shape, a.dtype))
        if quant[l]:
            for a in (ksa, vsa):
                sc_arrs.append(a)
                sc_in.append(pl.BlockSpec((1, 2), pair_map_sc))
                sc_out.append(pl.BlockSpec((1, 2), pair_map_sc))
                sc_shape.append(jax.ShapeDtypeStruct(a.shape, a.dtype))

    row_map = lambda r, tref, uref: (r, 0)
    # (k_new, v_new, *data_arrs, *sc_arrs): every pool operand (payload
    # AND scale blocks) aliases its mirror output; operand-indexed.
    nio = 2 * nlev + 2 * sum(quant)
    aliases = {2 + i: i for i in range(nio)}
    kernel = functools.partial(_update_paged_quant_kernel, nlev=nlev,
                               quant=quant, qmax=qz.QMAX,
                               recip=qz.RECIP_QMAX, eps=qz.EPS)
    utab_hi = np.array([ka.shape[0] - 1 for ka, _, _, _ in lvls],
                       dtype=np.int32)
    lvl_names = [f"{a}_l{l}" for l in range(nlev) for a in ("k", "v")]
    sc_names = [f"{a}sc_l{l}" for l in range(nlev) for a in ("k", "v")
                if quant[l]]
    outs = launch(
        kernel, family="decode_update_paged_quant", grid=(R,),
        in_specs=[pl.BlockSpec((1, D), row_map),
                  pl.BlockSpec((1, Dv), row_map)] + data_in + sc_in,
        out_specs=tuple(data_out + sc_out),
        out_shape=tuple(data_shape + sc_shape),
        operands=[k_new, v_new, *data_arrs, *sc_arrs],
        scalars=(t.astype(jnp.int32), utab.astype(jnp.int32)),
        scalar_bounds=((0, _T_MAX), (0, utab_hi)),
        scalar_names=("t", "utab"),
        in_names=["k_new", "v_new"] + lvl_names + sc_names,
        out_names=lvl_names + sc_names, aliases=aliases,
        interpret=interpret,
        meta=dict(nr=nr, nlev=nlev, quant=quant))
    data = outs[:2 * nlev]
    scs = outs[2 * nlev:]
    ksc_out, vsc_out = [], []
    all_ks = [pool.ksc] + list(pool.cksc)
    all_vs = [pool.vsc] + list(pool.cvsc)
    si = 0
    for l in range(nlev):
        if quant[l]:
            ksc_out.append(scs[2 * si])
            vsc_out.append(scs[2 * si + 1])
            si += 1
        else:
            ksc_out.append(all_ks[l])
            vsc_out.append(all_vs[l])
    return type(pool)(
        k=data[0], v=data[1],
        ck=tuple(data[2 + 2 * i] for i in range(nlev - 1)),
        cv=tuple(data[3 + 2 * i] for i in range(nlev - 1)),
        ksc=ksc_out[0], vsc=vsc_out[0],
        cksc=tuple(ksc_out[1:]), cvsc=tuple(vsc_out[1:]))


# ---------------------------------------------------------------------------
# sequence-parallel partial update (owned rows only + carried ancestor)
# ---------------------------------------------------------------------------

def _update_partial_kernel(t_ref, own_ref, knew_ref, vnew_ref, *refs,
                           nlev: int):
    """SP variant of :func:`_update_kernel`: ``t`` is shard-LOCAL, the
    substitution is gated per row by the ownership bit (non-owners
    write their clamped pair back unchanged -- a no-op scatter), and
    the pair mean/sum carried past the LAST level is emitted so the
    caller can broadcast it to the replicated deep levels."""
    in_refs = refs[:2 * nlev]
    out_refs = refs[2 * nlev:4 * nlev]
    ck_ref, cv_ref = refs[4 * nlev:4 * nlev + 2]
    r = pl.program_id(0)
    t = t_ref[r]
    owned = own_ref[r] > 0
    f32 = jnp.float32
    sel_row = jax.lax.broadcasted_iota(jnp.int32, (2, 1), 0)

    new_k = knew_ref[...].astype(f32)                    # (1, D)
    new_v = vnew_ref[...].astype(f32)                    # (1, Dv)
    for l in range(nlev):
        sel = (sel_row == ((t >> l) & 1)) & owned
        pk = jnp.where(sel, new_k, in_refs[2 * l][0].astype(f32))
        pv = jnp.where(sel, new_v, in_refs[2 * l + 1][0].astype(f32))
        out_refs[2 * l][0] = pk.astype(out_refs[2 * l].dtype)
        out_refs[2 * l + 1][0] = pv.astype(out_refs[2 * l + 1].dtype)
        new_k = pk.mean(axis=0, keepdims=True)
        new_v = pv.sum(axis=0, keepdims=True)
    # carried row for the first level ABOVE this sharded chain; garbage
    # on non-owner rows (the caller masks it with `owned` before psum)
    ck_ref[...] = new_k.astype(ck_ref.dtype)
    cv_ref[...] = new_v.astype(cv_ref.dtype)


def update_cache_partial(cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                         t_loc: jnp.ndarray, owned: jnp.ndarray, *,
                         t_hi: int = None, interpret: bool = False):
    """Fused ancestor update on shard-LOCAL cache arrays.

    ``cache`` holds only the SHARDED levels of the hierarchy (this
    shard's slab); ``t_loc`` (R,) is the shard-local position (low-
    clamped only, so a non-owner left of the owning shard sees values up
    to the GLOBAL length -- ``t_hi`` declares that real domain, the
    default covers a single-shard slab) and ``owned`` (R,) marks the
    rows whose token lives on this shard.  Returns ``(updated_cache,
    carry_k (R, D), carry_v (R, Dv))`` where the carry is the freshly
    computed row for the first level above the sharded chain (valid on
    owner rows)."""
    R, D = k_new.shape
    Dv = v_new.shape[-1]
    nlev = 1 + len(cache.ck)

    arrs, in_specs, out_specs, out_shape = [], [], [], []
    lvls = [(cache.k, cache.v)] + list(zip(cache.ck, cache.cv))
    for l, (ka, va) in enumerate(lvls):
        npairs = ka.shape[-2] // 2

        def pair_map(r, tref, oref, l=l, npairs=npairs):
            return (r, jnp.minimum(tref[r] >> (l + 1), npairs - 1), 0)

        for a, d_ in ((ka, D), (va, Dv)):
            arrs.append(a)
            in_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_specs.append(pl.BlockSpec((1, 2, d_), pair_map))
            out_shape.append(jax.ShapeDtypeStruct(a.shape, a.dtype))

    row_map = lambda r, tref, oref: (r, 0)
    out_specs += [pl.BlockSpec((1, D), row_map),
                  pl.BlockSpec((1, Dv), row_map)]
    out_shape += [jax.ShapeDtypeStruct((R, D), cache.k.dtype),
                  jax.ShapeDtypeStruct((R, Dv), cache.v.dtype)]

    # (k_new, v_new, *arrs): cache operands start at operand index 2;
    # the two carry outputs at the end are not aliased.
    aliases = {2 + i: i for i in range(2 * nlev)}
    kernel = functools.partial(_update_partial_kernel, nlev=nlev)
    Lloc = cache.k.shape[-2]
    lvl_names = [f"{a}_l{l}" for l in range(nlev) for a in ("k", "v")]
    outs = launch(
        kernel, family="decode_update_partial", grid=(R,),
        in_specs=[pl.BlockSpec((1, D), row_map),
                  pl.BlockSpec((1, Dv), row_map)] + in_specs,
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        operands=[k_new, v_new, *arrs],
        scalars=(t_loc.astype(jnp.int32), owned.astype(jnp.int32)),
        scalar_bounds=((0, Lloc - 1 if t_hi is None else t_hi), (0, 1)),
        scalar_names=("t_loc", "owned"),
        in_names=["k_new", "v_new"] + lvl_names,
        out_names=lvl_names + ["carry_k", "carry_v"],
        aliases=aliases, interpret=interpret,
        meta=dict(Lloc=Lloc, nlev=nlev))
    ck = tuple(outs[2 + 2 * i] for i in range(nlev - 1))
    cv = tuple(outs[3 + 2 * i] for i in range(nlev - 1))
    upd = type(cache)(k=outs[0], v=outs[1], ck=ck, cv=cv)
    return upd, outs[2 * nlev], outs[2 * nlev + 1]
