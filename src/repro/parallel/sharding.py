"""Sharding rules: logical-to-mesh mapping for params, batches, and caches.

Mesh axes: ``("pod", "data", "model")`` (multi-pod) or
``("data", "model")`` (single pod).

* params     -- specs come from the model init (divisibility-aware TP,
                EP for experts); anything else replicated.
* train batch-- leading batch dim over ("pod", "data")  (DP).
* decode     -- cache leading dim over DP axes when the batch is large;
                for batch=1 long-context decode the *sequence* axis of
                the KV cache shards over "data" (SP) and kv-heads over
                "model" when divisible.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_type_kwargs(n: int) -> dict:
    """Version-compat mesh kwargs: jax >= 0.5 wants explicit
    ``axis_types=(AxisType.Auto,) * n``; 0.4.x predates the kwarg
    entirely (Auto is the only behaviour).  Single source of truth for
    the AxisType probe -- also used by ``launch/mesh.py``."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def abstract_mesh(shape, axes):
    """Version-compat ``jax.sharding.AbstractMesh`` constructor: jax >=
    0.5 takes ``(shape, axes, axis_types=...)``; 0.4.x takes name/size
    pairs."""
    kw = axis_type_kwargs(len(axes))
    if kw:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes), **kw)
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def param_shardings(mesh: Mesh, specs: Any):
    """Model init specs -> NamedSharding tree (axes absent from the mesh
    dropped)."""
    names = set(mesh.axis_names)

    def fix(spec: P) -> NamedSharding:
        clean = []
        for ax in spec:
            if ax is None:
                clean.append(None)
            elif isinstance(ax, str):
                clean.append(ax if ax in names else None)
            else:
                sub = tuple(a for a in ax if a in names)
                clean.append(sub if sub else None)
        return NamedSharding(mesh, P(*clean))

    return jax.tree.map(fix, specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh: Mesh, batch_tree: Any):
    """Leading dim of every batch leaf over the DP axes."""
    bd = dp_axes(mesh)

    def one(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(bd, *([None] * (nd - 1))))

    return jax.tree.map(one, batch_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def cache_shardings(mesh: Mesh, cache_tree: Any, *, batch: int,
                    kv_heads: int, long_context: bool,
                    num_layers: int = 0):
    """Decode-cache shardings (see module docstring).

    Heuristic per leaf: batch-major leaves shard dim0 over DP (and over
    "model" too when it divides); in long-context (batch==1) mode the
    longest axis shards over "data" (sequence parallelism) and dim0 over
    "model" when the kv-head count divides.
    """
    bd = dp_axes(mesh)
    dsz = dp_size(mesh)
    tsz = tp_size(mesh)

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return replicated(mesh)
        # scanned models stack caches with a leading LAYER dim -- never
        # shard that; the batch dim is dim1 there
        off = 1 if (num_layers and nd >= 2 and shape[0] == num_layers) else 0
        if not long_context:
            # dim0 over the DP axes ONLY: the decode compute (q from the
            # batch-sharded tokens) lives on DP, and a dp x model cache
            # sharding forces a full cache all-to-all every step
            ax0 = shape[off]
            spec = [None] * nd
            if tsz > 1 and ax0 % (dsz * tsz) == 0:
                spec[off] = bd + ("model",)
                return NamedSharding(mesh, P(*spec))
            if ax0 % dsz == 0:
                spec[off] = bd
                return NamedSharding(mesh, P(*spec))
            return replicated(mesh)
        # long-context: SP over the sequence axis
        spec = [None] * nd
        if shape[off] % tsz == 0 and tsz > 1:
            spec[off] = "model"
        if nd >= off + 2:
            seq_ax = int(np.argmax(shape[off + 1:])) + off + 1
            if shape[seq_ax] % mesh.shape.get("data", 1) == 0:
                spec[seq_ax] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_tree)
