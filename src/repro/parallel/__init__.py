"""Distribution: sharding rules, pipeline parallelism."""
from .sharding import (param_shardings, batch_shardings, cache_shardings,
                       replicated, dp_axes, dp_size, tp_axis, tp_size,
                       abstract_mesh, axis_type_kwargs)
from .pipeline import pipeline_apply
