"""Distribution: sharding rules, pipeline parallelism, sequence-parallel
kernel execution (shard_map halo exchange around the fused Pallas
kernels)."""
from .sharding import (param_shardings, batch_shardings, cache_shardings,
                       replicated, dp_axes, dp_size, tp_axis, tp_size,
                       abstract_mesh, axis_type_kwargs)
from .pipeline import pipeline_apply
from .sp_attention import (sp_scope, sp_ctx, sp_band_attention,
                           sp_h1d_attention, sp_decode_attend,
                           sp_update_cache, sp_cache_specs,
                           sp_sharded_levels)
