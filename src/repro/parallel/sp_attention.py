"""Sequence-parallel (SP) execution layer for the fused Pallas kernels.

The fused band/decode kernels were single-chip until this layer: a
sequence-sharded operand handed to ``pallas_call`` is gathered whole,
so every caller with an ``L``-sharded cache or activation fell back to
``impl='jnp'`` (EXPERIMENTS.md P21/P22 measured why).  This module wraps
the *unmodified* kernels in ``shard_map`` over the ``data`` mesh axis
and makes the cross-shard structure explicit:

* each shard runs the Pallas band kernels on its local ``L/d`` rows --
  the banded structure is translation-invariant by multiples of the
  query-block size, so a local launch computes every contribution
  except the ones that cross the left/right shard boundary;
* the boundary needs exactly one ``nr``-row block per level per
  direction (level 0: the neighbouring fine block; level ``l``: the
  single coarse block ``I-1`` owned by the left shard).  All levels'
  halo rows are packed into ONE buffer and exchanged with one
  ``ppermute`` per direction (causal modes need only the left->right
  direction);
* the cross-level streaming LSE combine (``_stream_combine``, PR 2)
  gains a cross-shard epilogue: the halo contributions are merged into
  the affected edge rows with the same log-sum-exp shift.  Each fine
  query row is owned by exactly one shard, so the epilogue is
  psum-free;
* levels too deep to keep an ``nr``-row block per shard (local coarse
  length < ``nr``) are computed from one ``all_gather`` of the tiny
  transition-level coarse KV (<= ``d * nr / 2`` rows total -- see
  DESIGN.md section 7 for the communication accounting);
* the decode kernels run per shard with *sharded index maps*: block
  indices are translated to shard-local coordinates outside the kernel
  and scalar-prefetched together with a per-band ownership bit, so a
  token's ancestor pair is read/updated on its owning shard only; the
  per-shard partial ``(num, den, m)`` triples merge with one
  ``pmax`` + ``psum`` pair.

Entry points
------------
``sp_band_attention``   -- one banded level under SP (all five modes).
``sp_h1d_attention``    -- the full hierarchical operator under SP.
``sp_decode_attend`` / ``sp_update_cache`` -- fused decode tick under a
sequence-sharded ``H1DCache``.
``sp_scope`` / ``sp_ctx`` -- trace-time context: callers enter
``sp_scope(mesh)`` around tracing and the kernel dispatchers in
``kernels/ops.py`` / ``core/h1d_attention.py`` / ``core/h1d_decode.py``
route through this module automatically.
``sp_cache_specs``      -- PartitionSpec tree for an ``H1DCache`` under
SP (deep levels replicated; loud fallback when the kv-head dim does not
divide the ``model`` axis).
"""
from __future__ import annotations

import math
import threading
import warnings
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - newer jax moved it to the top level
    from jax import shard_map

from repro import obs
from repro.core import hierarchy as hc
from repro.kernels import h1d_block

NEG_INF = h1d_block.NEG_INF
_MIN_M = -1e30


def _note_dispatch(op: str, shards: int) -> None:
    """Trace-time SP dispatch counter (one per traced shard_map shape,
    like the kernel-launch accounting)."""
    obs.counter("sp.dispatches", op=op, shards=shards).inc()


# ---------------------------------------------------------------------------
# trace-time SP context
# ---------------------------------------------------------------------------

_state = threading.local()


@contextmanager
def sp_scope(mesh: Optional[Mesh], axis: str = "data"):
    """Enable SP dispatch while tracing.  ``h1d_attention`` /
    ``band_attention`` / the decode entry points check :func:`sp_ctx`
    and route through this module when a mesh with ``mesh.shape[axis] >
    1`` is active.  A ``None`` mesh (or a trivial axis) is a no-op, so
    callers can wrap unconditionally."""
    prev = getattr(_state, "ctx", None)
    active = mesh is not None and dict(mesh.shape).get(axis, 1) > 1
    _state.ctx = (mesh, axis) if active else None
    try:
        yield
    finally:
        _state.ctx = prev


def sp_ctx() -> Optional[Tuple[Mesh, str]]:
    """The active (mesh, axis) SP context, or None."""
    return getattr(_state, "ctx", None)


@contextmanager
def _local_region():
    """Suppress SP re-dispatch while tracing a shard_map body: the
    kernels called inside already see shard-local arrays."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = None
    try:
        yield
    finally:
        _state.ctx = prev


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map (check_rep was renamed check_vma)."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - newer jax
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _dim0_spec(mesh: Mesh, n: int, what: str):
    """Shard the folded ``batch * kv_heads`` dim over ``model`` when it
    divides; otherwise fall back LOUDLY (a silent wrong-shape shard
    would wrong-answer GQA head counts not divisible by the axis)."""
    msz = dict(mesh.shape).get("model", 1)
    if msz <= 1:
        return None
    if n % msz == 0:
        return "model"
    warnings.warn(
        f"SP {what}: dim0={n} (batch*kv_heads) does not divide the "
        f"'model' axis ({msz}); replicating heads instead of sharding "
        f"them (correct but slower)", stacklevel=3)
    return None


# ---------------------------------------------------------------------------
# halo pack / edge-correction helpers
# ---------------------------------------------------------------------------

def _pack_kvw(k, v, w):
    """(B, R, Dk) + (B, R, Dv) + (B, R) -> one (B, R, Dk+Dv+1) buffer so
    the whole exchange is ONE ppermute per direction."""
    return jnp.concatenate([k, v, w[..., None]], axis=-1)


def _unpack_kvw(buf, dk, dv):
    return buf[..., :dk], buf[..., dk:dk + dv], buf[..., dk + dv]


def _ppermute_right(x, axis, d):
    """Shard s -> s+1 (receives the LEFT neighbour's buffer; shard 0
    receives zeros, which the global masks / w>0 kill anyway)."""
    return jax.lax.ppermute(x, axis, [(i, i + 1) for i in range(d - 1)])


def _ppermute_left(x, axis, d):
    return jax.lax.ppermute(x, axis, [(i + 1, i) for i in range(d - 1)])


def sp_n_shallow(M: int, Lloc: int, nr: int) -> int:
    """Number of hierarchy levels (fine level 0 included) the
    training/prefill path runs LOCALLY per shard: level ``l`` keeps at
    least one whole ``nr``-row coarse block per shard iff
    ``Lloc >> l >= nr``.  Levels at or above the returned count go
    through the gathered deep path.  One definition shared by
    :func:`sp_h1d_attention` and ``analysis/dist.py``."""
    return min(M, int(math.log2(Lloc // nr)) + 1)


def sp_halo_pack(kc_l, vc_l, wc_l, n_shallow: int, nr: int, side: str):
    """Pack the shard-boundary ``nr``-row block of every shallow level
    into ONE ``(B, n_shallow * nr, Dk + Dv + 1)`` buffer -- the whole
    multi-level halo then costs a single ppermute per direction.
    ``side='prev'`` takes each level's LAST block (sent rightward),
    ``side='next'`` the FIRST (sent leftward)."""
    sl = slice(-nr, None) if side == "prev" else slice(None, nr)
    return jnp.concatenate(
        [_pack_kvw(kc_l[l][:, sl], vc_l[l][:, sl], wc_l[l][:, sl])
         for l in range(n_shallow)], axis=1)


def _edge_term(qe, ke, ve, we, mask):
    """Partial banded softmax of an edge query slab against one halo
    key block.  qe: (B, G, nq, D); ke/ve: (B, nk, *); we: (B, nk);
    mask: broadcastable (.., nq, nk) allowed-mask.  Returns float32
    (y, dn, m) like one band kernel launch."""
    f32 = jnp.float32
    s = jnp.einsum("bgqd,bkd->bgqk", qe.astype(f32), ke.astype(f32),
                   preferred_element_type=f32)
    allow = jnp.logical_and(mask, (we > 0)[:, None, None, :])
    s = jnp.where(allow, s, NEG_INF)
    m = jnp.maximum(s.max(-1), _MIN_M)
    a = jnp.exp(s - m[..., None])
    y = jnp.einsum("bgqk,bkv->bgqv", a, ve.astype(f32),
                   preferred_element_type=f32)
    dn = jnp.einsum("bgqk,bk->bgq", a, we.astype(f32),
                    preferred_element_type=f32)
    return y, dn, m


def _merge_rows(acc, corr, start):
    """LSE-merge a correction triple into rows [start, start+n) of a
    (y, dn, m) accumulator (the cross-shard epilogue of
    ``_stream_combine``)."""
    y, dn, m = acc
    yl, dl, ml = corr
    n = yl.shape[-2]
    y0 = jax.lax.dynamic_slice_in_dim(y, start, n, axis=-2)
    d0 = jax.lax.dynamic_slice_in_dim(dn, start, n, axis=-1)
    m0 = jax.lax.dynamic_slice_in_dim(m, start, n, axis=-1)
    mn = jnp.maximum(m0, ml)
    e0 = jnp.exp(m0 - mn)
    el = jnp.exp(ml - mn)
    y = jax.lax.dynamic_update_slice_in_dim(
        y, y0 * e0[..., None] + yl * el[..., None], start, axis=-2)
    dn = jax.lax.dynamic_update_slice_in_dim(
        dn, d0 * e0 + dl * el, start, axis=-1)
    m = jax.lax.dynamic_update_slice_in_dim(m, mn, start, axis=-1)
    return y, dn, m


def _halo_mask(mode, nr, ratio, lkg, q0, k0, nq_rows, nk_rows):
    """Allowed-mask of an edge correction from GLOBAL indices (q0/k0 may
    be traced: they depend on the shard index)."""
    qi = q0 + jnp.arange(nq_rows)[:, None]
    ki = k0 + jnp.arange(nk_rows)[None, :]
    return h1d_block.band_mask(qi, ki, nr, mode, lkg, ratio)[None, None]


# ---------------------------------------------------------------------------
# single banded level under SP
# ---------------------------------------------------------------------------

def _validate_sp_shape(L, d, nr, what):
    if L % d:
        raise ValueError(f"{what}: L={L} not divisible by the data axis "
                         f"size {d}")
    Lloc = L // d
    if Lloc % nr or Lloc < nr:
        raise ValueError(
            f"{what}: local length L/d={Lloc} must be a multiple of "
            f"nr={nr} and >= nr; use fewer shards for this sequence")
    return Lloc


def sp_band_attention(q, k, v, w, *, nr: int, mode: str, ratio: int = 1,
                      impl: str = "pallas", tq: Optional[int] = None,
                      mesh: Mesh, axis: str = "data"):
    """One banded level under sequence parallelism.

    Same contract as ``kernels.ops.band_attention`` (returns the float32
    ``(y, dn, m)`` triple at fine/query resolution), but the query and
    key sequence axes are sharded over ``mesh[axis]``: each shard runs
    the unmodified Pallas kernel on its rows and the boundary blocks are
    fixed up from one packed halo exchange per direction.

    ``mode='sub'`` requires the local query slab to hold at least one
    whole ``nr * ratio``-row query block (deeper levels are the
    gathered path of :func:`sp_h1d_attention`).
    """
    from repro.kernels.ops import band_attention

    d = dict(mesh.shape)[axis]
    if d == 1:
        with _local_region():
            return band_attention(q, k, v, w, nr=nr, mode=mode, ratio=ratio,
                                  impl=impl, tq=tq)
    _note_dispatch("band_attention", d)
    B, G, Lq, dk = q.shape
    dv = v.shape[-1]
    Lk = k.shape[1]
    causal = mode.endswith("causal") or mode == h1d_block.SUB_MODE
    Lq_loc = _validate_sp_shape(Lq, d, nr, "sp_band_attention")
    if mode == h1d_block.SUB_MODE:
        nq = nr * ratio
        if nq > Lq_loc:
            raise ValueError(
                f"sp_band_attention(mode='sub'): query block nq={nq} "
                f"exceeds the local slab L/d={Lq_loc}; deep levels go "
                f"through sp_h1d_attention's gathered path")
    else:
        nq = nr
    spec0 = _dim0_spec(mesh, B, "band_attention")

    def body(q, k, v, w):
        with _local_region():
            s = jax.lax.axis_index(axis)
            lloc = q.shape[2]
            kloc = k.shape[1]
            acc = band_attention(q, k, v, w, nr=nr, mode=mode, ratio=ratio,
                                 impl=impl, tq=tq)
            # one packed halo buffer per direction
            halo = _ppermute_right(
                _pack_kvw(k[:, -nr:], v[:, -nr:], w[:, -nr:]), axis, d)
            kh, vh, wh = _unpack_kvw(halo, dk, dv)
            # left boundary: the first query block attends the left
            # neighbour's last key block (masked out by the local call)
            q0 = s * lloc if mode == h1d_block.SUB_MODE else s * kloc
            corr = _edge_term(
                q[:, :, :nq], kh, vh, wh,
                _halo_mask(mode, nr, ratio, Lk, q0, s * kloc - nr, nq, nr))
            acc = _merge_rows(acc, corr, 0)
            if not causal:
                nhalo = _ppermute_left(
                    _pack_kvw(k[:, :nr], v[:, :nr], w[:, :nr]), axis, d)
                kn, vn, wn = _unpack_kvw(nhalo, dk, dv)
                corr = _edge_term(
                    q[:, :, -nr:], kn, vn, wn,
                    _halo_mask(mode, nr, ratio, Lk, s * kloc + kloc - nr,
                               (s + 1) * kloc, nr, nr))
                acc = _merge_rows(acc, corr, lloc - nr)
            return acc

    fn = _shard_map(
        body, mesh,
        in_specs=(P(spec0, None, axis, None), P(spec0, axis, None),
                  P(spec0, axis, None), P(spec0, axis)),
        out_specs=(P(spec0, None, axis, None), P(spec0, None, axis),
                   P(spec0, None, axis)))
    return fn(q, k, v, w)


# ---------------------------------------------------------------------------
# full hierarchical operator under SP
# ---------------------------------------------------------------------------

def sp_h1d_attention(q, k, v, *, mesh: Mesh, axis: str = "data",
                     nr: int = 16, causal: bool = False,
                     causal_mode: str = "fine-q", kv_weight=None,
                     softmax_scale: Optional[float] = None,
                     impl: str = "pallas", tq: Optional[int] = None):
    """``core.h1d_attention`` semantics with the L axis sharded over
    ``mesh[axis]``.  Every level that keeps an ``nr``-row block per
    shard runs the unmodified fused kernel locally (+ halo epilogue);
    deeper levels are computed from ONE ``all_gather`` of the
    transition-level coarse KV (<= ``d*nr/2`` rows in total).  The
    output stays sequence-sharded: no psum touches the fine rows."""
    from repro.core.h1d_attention import _stream_combine
    from repro.kernels.ops import band_attention

    d = dict(mesh.shape)[axis]
    B, G, L, D = q.shape
    if k.ndim == 4:
        raise ValueError("sp_h1d_attention: per-head 4-D KV is the "
                         "GSPMD jnp layout; SP is the kernel path")
    Dk = k.shape[-1]
    Dv = v.shape[-1]
    if d == 1:
        from repro.core.h1d_attention import h1d_attention
        with _local_region():
            return h1d_attention(q, k, v, nr=nr, causal=causal,
                                 causal_mode=causal_mode,
                                 kv_weight=kv_weight,
                                 softmax_scale=softmax_scale,
                                 impl=impl, tq=tq)
    _note_dispatch("h1d_attention", d)
    Lloc = _validate_sp_shape(L, d, nr, "sp_h1d_attention")
    M = hc.num_levels(L, nr)
    fine_q = causal and causal_mode == "fine-q"
    # levels 0..n_shallow-1 keep >= one nr-row coarse block per shard
    n_shallow = sp_n_shallow(M, Lloc, nr)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    out_dtype = v.dtype
    spec0 = _dim0_spec(mesh, B, "h1d_attention")
    l0_mode = "l0_causal" if causal else "l0_bidir"
    coarse_mode = "coarse_causal" if causal else "coarse_bidir"
    f32 = jnp.float32

    w_in = (jnp.ones((B, L), f32) if kv_weight is None
            else jnp.broadcast_to(kv_weight.astype(f32), (B, L)))

    def body(q, k, v, w):
      with _local_region():
        s = jax.lax.axis_index(axis)
        q = q.astype(f32) * scale
        k = k.astype(f32)
        v = v.astype(f32) * w[..., None]

        # ---- local coarse pyramid (pairwise ops never cross shards) --
        # levels 1..n_shallow-1 run the fused kernel; the extra level
        # n_shallow (if any) only exists to seed the deep-level gather.
        n_pyr = min(M - 1, n_shallow)
        kc_l, vc_l, wc_l = [k], [v], [w]
        qc_l, wq_l = [q], [w]
        for l in range(1, n_pyr + 1):
            kcl, _ = hc.coarsen_weighted_mean(kc_l[-1], wc_l[-1])
            kc_l.append(kcl)
            vc_l.append(hc.coarsen_sum(vc_l[-1], axis=-2))
            wc_l.append(hc.coarsen_sum(wc_l[-1], axis=-1))
            if causal and not fine_q or not causal:
                qcl, _ = hc.coarsen_weighted_mean(qc_l[-1], wq_l[-1])
                qc_l.append(qcl)
                wq_l.append(hc.coarsen_sum(wq_l[-1], axis=-1))

        # ---- one packed halo exchange per direction ------------------
        prev_halo = _ppermute_right(
            sp_halo_pack(kc_l, vc_l, wc_l, n_shallow, nr, "prev"), axis, d)
        if not causal:
            next_halo = _ppermute_left(
                sp_halo_pack(kc_l, vc_l, wc_l, n_shallow, nr, "next"),
                axis, d)

        def halo(buf, l):
            return _unpack_kvw(buf[:, l * nr:(l + 1) * nr], Dk, Dv)

        # ---- level 0 seeds the streaming accumulator -----------------
        acc = band_attention(q, k, v, w, nr=nr, mode=l0_mode, impl=impl,
                             tq=tq)
        kh, vh, wh = halo(prev_halo, 0)
        acc = _merge_rows(acc, _edge_term(
            q[:, :, :nr], kh, vh, wh,
            _halo_mask(l0_mode, nr, 1, L, s * Lloc, s * Lloc - nr, nr, nr)),
            0)
        if not causal:
            kh, vh, wh = halo(next_halo, 0)
            acc = _merge_rows(acc, _edge_term(
                q[:, :, -nr:], kh, vh, wh,
                _halo_mask(l0_mode, nr, 1, L, (s + 1) * Lloc - nr,
                           (s + 1) * Lloc, nr, nr)), Lloc - nr)

        # ---- shallow coarse levels: local kernel + halo epilogue -----
        for l in range(1, n_shallow):
            kc, vc, wc = kc_l[l], vc_l[l], wc_l[l]
            cl = Lloc >> l                     # local coarse length
            lkg = L >> l                       # global coarse length
            kh, vh, wh = halo(prev_halo, l)
            if fine_q:
                ratio = 1 << l
                yl, dl, ml = band_attention(q, kc, vc, wc, nr=nr, mode="sub",
                                            ratio=ratio, impl=impl, tq=tq)
                nq = nr * ratio
                corr = _edge_term(
                    q[:, :, :nq], kh, vh, wh,
                    _halo_mask("sub", nr, ratio, lkg, s * Lloc,
                               s * cl - nr, nq, nr))
                yl, dl, ml = _merge_rows((yl, dl, ml), corr, 0)
            else:
                qc = qc_l[l]
                yl, dl, ml = band_attention(qc, kc, vc, wc, nr=nr,
                                            mode=coarse_mode, impl=impl,
                                            tq=tq)
                corr = _edge_term(
                    qc[:, :, :nr], kh, vh, wh,
                    _halo_mask(coarse_mode, nr, 1, lkg, s * cl,
                               s * cl - nr, nr, nr))
                yl, dl, ml = _merge_rows((yl, dl, ml), corr, 0)
                if not causal:
                    kh, vh, wh = halo(next_halo, l)
                    corr = _edge_term(
                        qc[:, :, -nr:], kh, vh, wh,
                        _halo_mask(coarse_mode, nr, 1, lkg,
                                   (s + 1) * cl - nr, (s + 1) * cl, nr, nr))
                    yl, dl, ml = _merge_rows((yl, dl, ml), corr, cl - nr)
                rep = 1 << l
                yl = hc.interp_repeat(yl, rep, axis=-2)
                dl = hc.interp_repeat(dl, rep, axis=-1)
                ml = hc.interp_repeat(ml, rep, axis=-1)
            acc = _stream_combine(acc, yl, dl, ml)

        # ---- deep levels: gathered tiny coarse KV --------------------
        if n_shallow < M:
            lt = n_shallow
            kg = jax.lax.all_gather(kc_l[lt], axis, axis=1, tiled=True)
            vg = jax.lax.all_gather(vc_l[lt], axis, axis=1, tiled=True)
            wg = jax.lax.all_gather(wc_l[lt], axis, axis=1, tiled=True)
            if not fine_q:
                qg = jax.lax.all_gather(qc_l[lt], axis, axis=2, tiled=True)
                wqg = jax.lax.all_gather(wq_l[lt], axis, axis=1, tiled=True)
            fidx = s * Lloc + jnp.arange(Lloc)
            for l in range(lt, M):
                lkg = L >> l
                if fine_q:
                    qi = fidx[:, None]
                    ki = jnp.arange(lkg)[None, :]
                    mask = h1d_block.band_mask(qi, ki, nr, "sub", lkg,
                                               1 << l)[None, None]
                    yl, dl, ml = _edge_term(q, kg, vg, wg, mask)
                else:
                    qi = jnp.arange(lkg)[:, None]
                    ki = jnp.arange(lkg)[None, :]
                    mask = h1d_block.band_mask(qi, ki, nr, coarse_mode,
                                               lkg)[None, None]
                    yc, dc, mc = _edge_term(qg, kg, vg, wg, mask)
                    cidx = fidx >> l
                    yl = jnp.take(yc, cidx, axis=-2)
                    dl = jnp.take(dc, cidx, axis=-1)
                    ml = jnp.take(mc, cidx, axis=-1)
                acc = _stream_combine(acc, yl, dl, ml)
                if l + 1 < M:
                    kg, _ = hc.coarsen_weighted_mean(kg, wg)
                    vg = hc.coarsen_sum(vg, axis=-2)
                    wg = hc.coarsen_sum(wg, axis=-1)
                    if not fine_q:
                        qg, _ = hc.coarsen_weighted_mean(qg, wqg)
                        wqg = hc.coarsen_sum(wqg, axis=-1)

        y, dn, _ = acc
        z = y / jnp.maximum(dn, 1e-9)[..., None]
        return z.astype(out_dtype)

    fn = _shard_map(
        body, mesh,
        in_specs=(P(spec0, None, axis, None), P(spec0, axis, None),
                  P(spec0, axis, None), P(spec0, axis)),
        out_specs=P(spec0, None, axis, None))
    return fn(q, k, v, w_in)


# ---------------------------------------------------------------------------
# sequence-sharded fused decode
# ---------------------------------------------------------------------------

def sp_sharded_levels(Lmax: int, nr: int, d: int) -> int:
    """Number of cache levels (fine level 0 included) whose sequence
    axis shards over a ``d``-way data axis: level ``l`` keeps a whole
    ``nr``-row block per shard iff ``Lmax >> l >= d * nr``.  Deeper
    levels replicate (they are tiny)."""
    n = 0
    while (Lmax >> n) >= d * nr and (Lmax >> n) % (d * nr) == 0:
        n += 1
    return n


def sp_update_owner(t, Lloc: int, d: int):
    """Owning shard of a decode-update row at global position ``t``.
    Out-of-range ``t`` (defensive: the engine freezes slots before this
    can happen) is owned by the LAST shard, whose kernel then clamps the
    pair index exactly like the single-chip launch -- without the clip
    no shard owns the row and the masked-psum carry would write ZEROS
    into the deep levels."""
    return jnp.clip(t // Lloc, 0, d - 1)


def sp_update_local_t(t, s, Lloc: int):
    """Shard-local position handed to ``update_cache_partial``.  Keeps
    the raw low bits (no upper clip): the kernel's pair_map min()-clamps
    the index, and the sibling parity ``(t >> l) & 1`` must match the
    unclamped single-chip value."""
    return jnp.maximum(t - s * Lloc, 0)


def sp_cache_specs(cache, mesh: Mesh, *, nr: int, axis: str = "data"):
    """PartitionSpec tree for an ``H1DCache`` under SP: fine + shallow
    coarse levels shard their sequence axis over ``axis``; deep levels
    replicate.  Dim0 (batch*kv_heads) shards over ``model`` when it
    divides -- the fallback when it does not is loud (a warning), never
    a silent wrong answer."""
    d = dict(mesh.shape)[axis]
    Lmax = cache.k.shape[-2]
    spec0 = _dim0_spec(mesh, cache.k.shape[0], "decode cache")
    nsh = sp_sharded_levels(Lmax, nr, d)
    if nsh < 1:
        raise ValueError(
            f"SP decode: Lmax={Lmax} < data_axis*nr = {d * nr}; the fine "
            f"level cannot keep an nr-row block per shard -- use fewer "
            f"shards")
    ck = tuple(P(spec0, axis if l + 1 < nsh else None, None)
               for l in range(len(cache.ck)))
    return type(cache)(k=P(spec0, axis, None), v=P(spec0, axis, None),
                       ck=ck, cv=ck)


def _band_geometry(t, s, nr, Lmax, d, nsh, nlevels):
    """Per-row (local block index, owned) for every decode band.

    t: (R,) global positions; s: traced shard index.  Band 0/1 are the
    own/prev fine blocks; band ``l+1`` is coarse level ``l``'s single
    ``I_l - 1`` block.  Sharded levels translate the global block index
    to shard-local coordinates and set ``owned`` on the owning shard
    only; replicated levels are owned by shard 0 (any single shard --
    the merge is a psum)."""
    idx, own = [], []
    for band in range(2 + nlevels):
        if band == 0:
            l, gb = 0, t // nr
        elif band == 1:
            l, gb = 0, jnp.maximum(t // nr - 1, 0)
        else:
            l = band - 1
            gb = t // (nr << l) - 1
        nbl = (Lmax >> l) // nr
        gb = jnp.clip(gb, 0, nbl - 1)
        if l < nsh:
            nbl_loc = nbl // d
            owner = gb // nbl_loc
            idx.append(jnp.clip(gb - s * nbl_loc, 0, nbl_loc - 1))
            own.append((owner == s).astype(jnp.int32))
        else:
            idx.append(gb)
            own.append((s == 0).astype(jnp.int32)
                       * jnp.ones_like(gb, jnp.int32))
    return (jnp.stack(idx, axis=-1).astype(jnp.int32),
            jnp.stack(own, axis=-1).astype(jnp.int32))


def sp_decode_attend(cache, q, t, *, nr: int, softmax_scale=None,
                     impl: str = "pallas", mesh: Mesh, axis: str = "data"):
    """Fused decode attention over a sequence-sharded ``H1DCache``.

    Same contract as ``core.h1d_decode.decode_attend``: ``q`` (R, G, D),
    ``t`` (R,) -> (R, G, Dv).  Each shard launches the partial-output
    variant of the fused kernel over the bands it owns (shard-local
    block indices + ownership bits scalar-prefetched), then the partial
    ``(num, den, m)`` triples merge with one ``pmax`` + ``psum``."""
    from repro.kernels import h1d_decode_kernel as dk
    from repro.kernels.tuning import get_policy

    d = dict(mesh.shape)[axis]
    impl = get_policy().resolve_impl(impl, "decode_attend")
    interpret = impl == "pallas_interpret"
    if d == 1:
        return dk.decode_attend_fused(cache, q, t, nr=nr,
                                      softmax_scale=softmax_scale,
                                      interpret=interpret)
    _note_dispatch("decode_attend", d)
    R, G, D = q.shape
    Lmax = cache.k.shape[-2]
    M = hc.num_levels(Lmax, nr)
    nsh = sp_sharded_levels(Lmax, nr, d)
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    cache_specs = sp_cache_specs(cache, mesh, nr=nr, axis=axis)
    spec0 = cache_specs.k[0]

    def body(cache, q, t):
        with _local_region():
            s = jax.lax.axis_index(axis)
            bidx, owned = _band_geometry(t, s, nr, Lmax, d, nsh, M - 1)
            # t stays GLOBAL inside the partial kernel (the band masks
            # compare global positions), so its declared domain is the
            # full sequence, not the local slab
            num, den, m = dk.decode_attend_partial(
                cache, q, t, bidx, owned, nr=nr, softmax_scale=scale,
                t_hi=Lmax - 1, interpret=interpret)
            mg = jax.lax.pmax(m, axis)
            e = jnp.exp(m - mg)
            num = jax.lax.psum(num * e[..., None], axis)
            den = jax.lax.psum(den * e, axis)
            return (num / jnp.maximum(den, 1e-9)[..., None]).astype(q.dtype)

    fn = _shard_map(
        body, mesh,
        in_specs=(cache_specs, P(spec0, None, None), P(spec0)),
        out_specs=P(spec0, None, None))
    return fn(cache, q, t)


def sp_update_cache(cache, k_new, v_new, t, *, impl: str = "pallas",
                    mesh: Mesh, axis: str = "data"):
    """Fused ancestor update over a sequence-sharded ``H1DCache``.

    All of a token's sharded-level ancestors live on ONE shard (the
    hierarchy is a binary tree over a contiguous shard span), so the
    owning shard runs the fused in-place kernel with shard-local pair
    indices while the others write their pairs back unchanged.  The
    carried pair mean/sum at the top of the sharded chain is broadcast
    with one masked ``psum`` and the (tiny, replicated) deep levels are
    updated identically everywhere by the unmodified kernel."""
    from repro.kernels import h1d_decode_kernel as dk
    from repro.kernels.tuning import get_policy

    d = dict(mesh.shape)[axis]
    impl = get_policy().resolve_impl(impl, "decode_update")
    interpret = impl == "pallas_interpret"
    if d == 1:
        return dk.update_cache_fused(cache, k_new, v_new, t,
                                     interpret=interpret)
    if not cache.ck:
        # a coarse-less cache (M <= 1) is ambiguous for the nr recovery
        # below AND too small to shard usefully: single-launch kernel
        return dk.update_cache_fused(cache, k_new, v_new, t,
                                     interpret=interpret)
    _note_dispatch("update_cache", d)
    Lmax = cache.k.shape[-2]
    Lloc = Lmax // d
    # the update signature has no nr, but a cache with >= 1 coarse level
    # fixes it: init_cache builds M = num_levels(Lmax, nr) - 1 coarse
    # levels, so Lmax = nr << (len(ck) + 1) -- recover nr to keep the
    # sharded-level rule identical between attend and update (ONE cache
    # layout).
    nr = Lmax >> (len(cache.ck) + 1)
    cache_specs = sp_cache_specs(cache, mesh, nr=nr, axis=axis)
    nsh = sp_sharded_levels(Lmax, nr, d)
    spec0 = cache_specs.k[0]
    nlev = 1 + len(cache.ck)

    def body(cache, k_new, v_new, t):
        with _local_region():
            s = jax.lax.axis_index(axis)
            owner = sp_update_owner(t, Lloc, d)
            owned = (owner == s).astype(jnp.int32)
            t_loc = sp_update_local_t(t, s, Lloc)
            sharded = type(cache)(k=cache.k, v=cache.v,
                                  ck=cache.ck[:nsh - 1],
                                  cv=cache.cv[:nsh - 1])
            # t_hi: non-owner rows keep t_loc = t - s*Lloc up to Lmax
            # (shard 0 under a last-shard row); the contract must
            # declare the real domain, not the local slab's
            upd, carry_k, carry_v = dk.update_cache_partial(
                sharded, k_new, v_new, t_loc, owned, t_hi=Lmax,
                interpret=interpret)
            ck = list(upd.ck) + list(cache.ck[nsh - 1:])
            cv = list(upd.cv) + list(cache.cv[nsh - 1:])
            if nsh <= nlev - 1:
                # broadcast the carried ancestor row from its owner and
                # walk the replicated deep levels with the stock kernel
                carry_k = jax.lax.psum(
                    carry_k * owned[:, None].astype(carry_k.dtype), axis)
                carry_v = jax.lax.psum(
                    carry_v * owned[:, None].astype(carry_v.dtype), axis)
                deep = type(cache)(k=cache.ck[nsh - 1],
                                   v=cache.cv[nsh - 1],
                                   ck=cache.ck[nsh:], cv=cache.cv[nsh:])
                dout = dk.update_cache_fused(deep, carry_k, carry_v,
                                             t >> nsh, interpret=interpret)
                ck[nsh - 1:] = [dout.k] + list(dout.ck)
                cv[nsh - 1:] = [dout.v] + list(dout.cv)
            return type(cache)(k=upd.k, v=upd.v, ck=tuple(ck), cv=tuple(cv))

    fn = _shard_map(
        body, mesh,
        in_specs=(cache_specs, P(spec0, None), P(spec0, None), P(spec0)),
        out_specs=cache_specs)
    return fn(cache, k_new, v_new, t)
