"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For depth beyond what DP x TP covers (1000+ nodes), the ``pod`` axis can
be repurposed as a ``stage`` axis: layers are split into S contiguous
stages; M microbatches flow through; each tick every stage applies its
layers and ppermutes its activation to the next stage.  Bubble fraction
is (S-1)/(M+S-1) as usual.

``pipeline_apply`` is deliberately model-agnostic: it takes stacked
per-stage params (leading dim S, sharded over the stage axis) and a
per-stage apply ``fn(stage_params, x) -> x``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(fn: Callable, stage_params: Any, x: jnp.ndarray, *,
                   mesh: Mesh, axis: str = "stage") -> jnp.ndarray:
    """x: (M, B_m, ...) microbatched input (M >= num_stages is sensible).
    stage_params leaves have leading dim = num_stages.
    Returns (M, B_m, ...) outputs of the final stage, in order."""
    S = mesh.shape[axis]
    M = x.shape[0]

    pspec = jax.tree.map(lambda _: P(axis), stage_params)

    @partial(shard_map, mesh=mesh,
             in_specs=(pspec, P(axis)), out_specs=P(axis),
             check_rep=False)
    def run(params, xs):
        # params leaves: (1, ...) local stage slice; xs: (M/S, Bm, ...)
        # We want every stage to see ALL microbatches in sequence, so we
        # first all-gather the microbatch stream along the stage axis.
        params = jax.tree.map(lambda p: p[0], params)
        xs = jax.lax.all_gather(xs, axis, axis=0, tiled=True)  # (M, Bm, ...)
        idx = jax.lax.axis_index(axis)

        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        nticks = M + S - 1

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (if any)
            take = xs[jnp.minimum(t, M - 1)]
            state = jnp.where(idx == 0,
                              jnp.where(t < M, take, state), state)
            state = fn(params, state)
            # last stage emits microbatch t-(S-1)
            emit = t - (S - 1)
            outs = jax.lax.cond(
                emit >= 0,
                lambda o: o.at[jnp.maximum(emit, 0)].set(
                    jnp.where(idx == S - 1, state, o[jnp.maximum(emit, 0)])),
                lambda o: o, outs)
            # shift all states one stage forward
            perm = [(i, (i + 1) % S) for i in range(S)]
            state = jax.lax.ppermute(state, axis, perm)
            return state, outs

        state, outs = jax.lax.fori_loop(0, nticks, tick, (state, outs))
        # every device now holds the outputs of the LAST stage only on
        # device S-1; psum the (zero-elsewhere) buffers to broadcast.
        outs = jnp.where(idx == S - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        # shard_map splits the output along the stage axis again
        return outs.reshape((S, M // S) + outs.shape[1:])[idx]

    assert M % S == 0, (M, S)
    return run(stage_params, x)
