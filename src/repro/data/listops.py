"""Synthetic ListOps generator (LRA Table-1 proxy, offline-compatible).

ListOps (Nangia & Bowman 2018) is the LRA task where H-Transformer-1D
gains the most (+12.3 over the best prior xformer): nested prefix
expressions over MIN/MAX/MED/SM (sum mod 10) whose answer requires
hierarchical reasoning over long contexts -- exactly the inductive bias
the paper claims.  The generator below reproduces the task distribution
(random trees, depth/length-controlled); since it is synthetic by
construction, the offline container can train on the *same* task as the
paper's benchmark.

Vocabulary: 0-9 digits, 4 operators, '(' ')' (ignored by LRA models),
PAD=0 ... encoded as: PAD=0, digits 1..10, ops 11..14, close 15.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

PAD = 0
DIGIT0 = 1           # digit d -> DIGIT0 + d
OPS = {"MIN": 11, "MAX": 12, "MED": 13, "SM": 14}
CLOSE = 15
VOCAB = 16
NUM_CLASSES = 10


def _sample_tree(r: np.random.Generator, depth: int, breadth: int):
    """Returns (tokens, value)."""
    if depth == 0 or r.random() < 0.3:
        d = int(r.integers(0, 10))
        return [DIGIT0 + d], d
    op_name = ("MIN", "MAX", "MED", "SM")[int(r.integers(0, 4))]
    n = int(r.integers(2, breadth + 1))
    toks: List[int] = [OPS[op_name]]
    vals = []
    for _ in range(n):
        t, v = _sample_tree(r, depth - 1, breadth)
        toks.extend(t)
        vals.append(v)
    toks.append(CLOSE)
    if op_name == "MIN":
        val = min(vals)
    elif op_name == "MAX":
        val = max(vals)
    elif op_name == "MED":
        val = int(np.median(vals))
    else:
        val = sum(vals) % 10
    return toks, val


@dataclasses.dataclass
class ListOps:
    seq_len: int = 512
    batch_per_host: int = 32
    seed: int = 0
    host_id: int = 0
    max_depth: int = 6
    breadth: int = 4

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        r = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S = self.batch_per_host, self.seq_len
        toks = np.zeros((B, S), np.int32)
        labels = np.zeros((B,), np.int32)
        mask = np.zeros((B, S), np.float32)
        for b in range(B):
            while True:
                t, v = _sample_tree(r, self.max_depth, self.breadth)
                if len(t) <= S:
                    break
            toks[b, :len(t)] = t
            mask[b, :len(t)] = 1.0
            labels[b] = v
        return {"tokens": toks, "label": labels, "mask": mask}
