"""Deterministic shardable data pipelines + synthetic task generators."""
from .pipeline import ZipfLM, HierarchicalLM, file_corpus, Prefetcher
from .listops import ListOps, VOCAB as LISTOPS_VOCAB, NUM_CLASSES
