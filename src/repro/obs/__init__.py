"""Process-wide telemetry: metrics, spans, kernel-launch accounting.

Quickstart::

    from repro import obs
    obs.enable()
    ... run serve / train / bench ...
    obs.export.write_trace("trace.json")       # load in ui.perfetto.dev
    print(obs.export.prometheus_text())
    snap = obs.export.snapshot()

Telemetry is OFF by default and costs one branch per instrumentation
site when off (:mod:`repro.obs.metrics` returns shared no-op stubs).
:func:`enable` flips the registry live and registers the kernel-launch
hook on :mod:`repro.analysis.contracts`, so every ``pallas_call``
traced while enabled is accounted (family, grid, analytic HBM bytes
and FLOPs -- see :mod:`repro.obs.traffic`).  CLIs expose this as
``--telemetry`` / ``--trace-out`` / ``--prom-out``.
"""
from __future__ import annotations

from . import export, metrics, tracing, traffic
from .metrics import (NULL_COUNTER, NULL_GAUGE, NULL_HISTOGRAM, NULL_SPAN,
                      Histogram, counter, enabled, gauge, histogram,
                      registry)
from .tracing import (TRACK_BENCH, TRACK_KERNELS, TRACK_SERVE, TRACK_TRAIN,
                      instant, span)

__all__ = [
    "enable", "disable", "enabled", "reset",
    "counter", "gauge", "histogram", "span", "instant",
    "registry", "Histogram",
    "metrics", "tracing", "traffic", "export",
    "NULL_COUNTER", "NULL_GAUGE", "NULL_HISTOGRAM", "NULL_SPAN",
    "TRACK_SERVE", "TRACK_TRAIN", "TRACK_BENCH", "TRACK_KERNELS",
]

_HOOKED = False


def enable() -> None:
    """Turn telemetry on and hook kernel-launch accounting."""
    global _HOOKED
    metrics._set_enabled(True)
    if not _HOOKED:
        from repro.analysis import contracts
        contracts.add_launch_hook(traffic.on_launch)
        _HOOKED = True


def disable() -> None:
    """Turn telemetry off (hot paths revert to the one-branch no-op).
    Collected metrics/trace events are kept until :func:`reset`."""
    global _HOOKED
    metrics._set_enabled(False)
    if _HOOKED:
        from repro.analysis import contracts
        contracts.remove_launch_hook(traffic.on_launch)
        _HOOKED = False


def reset() -> None:
    """Clear all collected metrics and trace events (enabled state is
    unchanged)."""
    metrics.registry().reset()
    tracing.buffer().reset()
