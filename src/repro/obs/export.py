"""Export surfaces: snapshot dict, Prometheus text, JSONL, trace files.

Four ways out of the in-process registry/trace buffer:

* :func:`snapshot` -- one JSON-able dict: metrics (counters / gauges /
  histogram summaries), the kernel tuning state (backend, digest,
  aggregated decision-log counts), and trace-buffer stats.
* :func:`prometheus_text` -- Prometheus text exposition (0.0.4):
  ``repro_``-prefixed names with dots flattened to underscores,
  histograms as cumulative ``_bucket{le=...}`` series.
* :class:`JsonlEmitter` -- appends a snapshot line to a file at most
  once per ``period_s`` (drive it from any loop; ``emit()`` forces).
* :func:`write_trace` -- Chrome trace-event JSON via the tracing
  buffer, with a metadata header carrying backend + XLA_FLAGS +
  tuning_digest so every trace pins the environment it was captured in.

The ``validate_*`` functions are the *pinned schemas*: tests and the CI
telemetry smoke (``scripts/check_telemetry.py``) call the same code, so
the exporters cannot drift from what CI checks.
"""
from __future__ import annotations

import collections
import json
import math
import os
import re
import time
from typing import Any, Dict, List, Optional

from . import metrics as _m
from . import tracing as _t


def tuning_snapshot() -> Dict[str, Any]:
    """Backend + digest + the decision log aggregated to
    {family: {source: count}} (satellite: tuning observability)."""
    from repro.kernels.tuning import get_policy
    p = get_policy()
    agg: Dict[str, Dict[str, int]] = collections.defaultdict(
        lambda: collections.defaultdict(int))
    for d in p.decisions:
        agg[d["family"]][d["source"]] += 1
    return {
        "backend": p.backend,
        "tuning_digest": p.tuning_digest(),
        "decisions": {f: dict(s) for f, s in sorted(agg.items())},
        "decision_log_len": len(p.decisions),
    }


def trace_metadata() -> Dict[str, Any]:
    """The header every trace/snapshot carries: enough to know what
    environment produced it."""
    ts = tuning_snapshot()
    return {
        "backend": ts["backend"],
        "tuning_digest": ts["tuning_digest"],
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
    }


def snapshot() -> Dict[str, Any]:
    return {
        "schema": "repro.obs.snapshot/1",
        "enabled": _m.enabled(),
        "metrics": _m.registry().snapshot(),
        "tuning": tuning_snapshot(),
        "trace": {"events": len(_t.buffer()),
                  "dropped": _t.buffer().dropped},
    }


# -- Prometheus text exposition ----------------------------------------------

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_OK.sub("_", name)


def _prom_labels(labels: Dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


def _prom_float(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text() -> str:
    """Prometheus text-format exposition of the whole registry."""
    by_name: Dict[str, List[Any]] = collections.defaultdict(list)
    for (name, _lk), m in _m.registry():
        by_name[name].append(m)
    lines: List[str] = []
    for name in sorted(by_name):
        ms = by_name[name]
        pname = _prom_name(name)
        kind = type(ms[0]).__name__
        if kind == "Counter":
            lines.append(f"# TYPE {pname} counter")
            for m in ms:
                lines.append(
                    f"{pname}_total{_prom_labels(m.labels)} {m.value}")
        elif kind == "Gauge":
            lines.append(f"# TYPE {pname} gauge")
            for m in ms:
                lines.append(
                    f"{pname}{_prom_labels(m.labels)} "
                    f"{_prom_float(m.value)}")
        else:
            lines.append(f"# TYPE {pname} histogram")
            for m in ms:
                base = dict(m.labels)
                for edge, cum in m.cumulative():
                    lab = _prom_labels(dict(base, le=_prom_float(edge)))
                    lines.append(f"{pname}_bucket{lab} {cum}")
                lines.append(f"{pname}_sum{_prom_labels(base)} "
                             f"{_prom_float(m.sum)}")
                lines.append(f"{pname}_count{_prom_labels(base)} "
                             f"{m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text())


def write_trace(path: str,
                extra_metadata: Optional[Dict[str, Any]] = None) -> None:
    md = trace_metadata()
    if extra_metadata:
        md.update(extra_metadata)
    _t.buffer().write(path, metadata=md)


def write_snapshot(path: str) -> None:
    with open(path, "w") as f:
        json.dump(snapshot(), f, indent=1, sort_keys=True)


class JsonlEmitter:
    """Appends one snapshot JSON line to ``path`` at most every
    ``period_s`` seconds of wall clock.  Call :meth:`maybe_emit` from
    any loop; :meth:`emit` writes unconditionally (use it once at
    shutdown so short runs still produce a line)."""

    def __init__(self, path: str, period_s: float = 10.0):
        self.path = path
        self.period_s = float(period_s)
        self._last = 0.0
        self.emitted = 0

    def maybe_emit(self) -> bool:
        now = time.monotonic()
        if now - self._last < self.period_s:
            return False
        self._last = now
        self.emit()
        return True

    def emit(self) -> None:
        line = dict(snapshot(), unix_time=time.time())
        with open(self.path, "a") as f:
            f.write(json.dumps(line, sort_keys=True) + "\n")
        self.emitted += 1


# -- pinned schemas (shared by tests and the CI telemetry smoke) -------------

def validate_snapshot(doc: Dict[str, Any]) -> List[str]:
    """Schema errors for a snapshot dict ([] when valid)."""
    errs: List[str] = []
    if doc.get("schema") != "repro.obs.snapshot/1":
        errs.append(f"bad schema tag: {doc.get('schema')!r}")
    m = doc.get("metrics")
    if not isinstance(m, dict):
        errs.append("metrics: not a dict")
    else:
        for sec in ("counters", "gauges", "histograms"):
            if not isinstance(m.get(sec), dict):
                errs.append(f"metrics.{sec}: not a dict")
        for k, h in (m.get("histograms") or {}).items():
            for field in ("count", "sum", "buckets"):
                if field not in h:
                    errs.append(f"histogram {k}: missing {field!r}")
    t = doc.get("tuning")
    if not isinstance(t, dict):
        errs.append("tuning: not a dict")
    else:
        for field in ("backend", "tuning_digest", "decisions"):
            if field not in t:
                errs.append(f"tuning: missing {field!r}")
        dig = t.get("tuning_digest", "")
        if not re.fullmatch(r"[0-9a-f]{12}", str(dig)):
            errs.append(f"tuning_digest not 12-hex: {dig!r}")
    return errs


def validate_chrome_trace(doc: Dict[str, Any],
                          require_kernel_traffic: bool = False,
                          ) -> List[str]:
    """Schema errors for a Chrome trace-event document ([] when valid).

    Pins the Perfetto-loadable shape: a ``traceEvents`` array whose
    entries carry ``ph``; ``X`` events need name/ts/dur/pid/tid; the
    metadata header must carry backend + tuning_digest (12-hex) +
    xla_flags.  With ``require_kernel_traffic``, at least one
    ``kernel.launch`` instant event must carry the analytic
    ``hbm_read_bytes``/``hbm_write_bytes``/``flops`` args.
    """
    errs: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents: missing or empty"]
    md = doc.get("metadata")
    if not isinstance(md, dict):
        errs.append("metadata: not a dict")
    else:
        for field in ("backend", "tuning_digest", "xla_flags"):
            if field not in md:
                errs.append(f"metadata: missing {field!r}")
        if not re.fullmatch(r"[0-9a-f]{12}",
                            str(md.get("tuning_digest", ""))):
            errs.append("metadata.tuning_digest not 12-hex")
    saw_traffic = False
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "B", "E", "C"):
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if ph == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in ev:
                    errs.append(f"event {i} ({ev.get('name')}): "
                                f"X missing {field!r}")
            if ev.get("dur", 0) < 0:
                errs.append(f"event {i}: negative dur")
        if ph == "i" and ev.get("name") == "kernel.launch":
            args = ev.get("args", {})
            need = ("family", "hbm_read_bytes", "hbm_write_bytes",
                    "flops")
            if all(k in args for k in need):
                saw_traffic = True
            else:
                errs.append(f"event {i}: kernel.launch missing "
                            f"traffic args {need}")
    if require_kernel_traffic and not saw_traffic:
        errs.append("no kernel.launch event with analytic traffic args")
    return errs


_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? "
    r"[-+]?([0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?|Inf|NaN)$")


def validate_prometheus_text(text: str,
                             require_metrics: tuple = (),
                             ) -> List[str]:
    """Schema errors for a Prometheus exposition ([] when valid)."""
    errs: List[str] = []
    seen: set = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line or line.startswith("#"):
            continue
        if not _PROM_LINE.match(line):
            errs.append(f"line {ln}: not prometheus text format: "
                        f"{line!r}")
            continue
        seen.add(line.split("{")[0].split(" ")[0])
    for name in require_metrics:
        if name not in seen:
            errs.append(f"required metric missing: {name}")
    return errs
