"""Process-wide metrics registry: counters, gauges, histograms.

Telemetry is OFF by default.  Every accessor (:func:`counter`,
:func:`gauge`, :func:`histogram`) returns a process-wide NO-OP stub
when telemetry is disabled -- the same singleton object every time, so
the disabled hot path pays one branch and one no-op method call, never
a dict lookup or an allocation (``tests/test_obs.py`` pins the object
identity and bounds the per-tick overhead).

Naming convention (DESIGN.md section 13): dotted lower-case
``subsystem.noun[_unit]`` names (``serve.ttft_s``, ``pool.prefix_hits``,
``kernel.hbm_read_bytes``); dimensions ride as labels
(``counter("kernel.launches", family="decode_attend")``), never baked
into the name.  Units are explicit suffixes: ``_s`` seconds, ``_bytes``
bytes, ``_ticks`` engine ticks; unsuffixed metrics are plain event or
object counts.

Histograms have FIXED bucket boundaries chosen at construction (first
call wins) so merging/exposition never re-buckets.  They additionally
retain up to ``keep_samples`` raw observations: quantiles are EXACT
while every observation is retained (the benchmark harnesses rely on
this -- ``benchmarks/common.py``), and fall back to linear
interpolation inside the fixed buckets once the reservoir overflows.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

# default histogram boundaries: exponential, ~microseconds..minutes when
# observing seconds, also serviceable for counts
DEFAULT_BUCKETS = tuple(
    float(f"{m}e{e}") for e in range(-6, 3) for m in (1, 2.5, 5))
DEFAULT_KEEP_SAMPLES = 1024

_ENABLED = False
_LOCK = threading.Lock()


def _labels_key(labels: Dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return "{" + inner + "}"


class Counter:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Dict[str, Any]):
        self.name = name
        self.labels = dict(labels)
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Dict[str, Any]):
        self.name = name
        self.labels = dict(labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-boundary histogram with a small exact-sample reservoir.

    ``boundaries`` are the inclusive upper edges of the finite buckets
    (ascending); observations above the last edge land in the implicit
    +Inf bucket.  Usable standalone (the benchmark harnesses construct
    private instances) or through the registry.
    """

    def __init__(self, name: str = "", labels: Optional[Dict] = None,
                 boundaries: Sequence[float] = DEFAULT_BUCKETS,
                 keep_samples: int = DEFAULT_KEEP_SAMPLES):
        bs = [float(b) for b in boundaries]
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(
                f"histogram boundaries must be non-empty ascending: {bs}")
        self.name = name
        self.labels = dict(labels or {})
        self.boundaries = bs
        self.counts = [0] * (len(bs) + 1)     # last = +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._keep = int(keep_samples)
        self._samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.boundaries, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._samples) < self._keep:
            self._samples.append(v)

    @property
    def exact(self) -> bool:
        """True while every observation is still in the reservoir (all
        quantiles exact)."""
        return self.count == len(self._samples)

    def quantile(self, q: float) -> float:
        """q in [0, 1].  Exact (linear-interpolated order statistic)
        while the reservoir holds every observation; bucket-interpolated
        after overflow.  NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        if self.exact:
            xs = sorted(self._samples)
            pos = q * (len(xs) - 1)
            lo = int(math.floor(pos))
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
        # bucket interpolation: find the bucket holding the q-th obs
        target = q * self.count
        acc = 0.0
        for i, c in enumerate(self.counts):
            if acc + c >= target and c > 0:
                lo = (self.min if i == 0
                      else self.boundaries[i - 1])
                hi = (self.max if i == len(self.boundaries)
                      else self.boundaries[i])
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                frac = (target - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
        return self.max

    def cumulative(self) -> List[Tuple[float, int]]:
        """Prometheus-style cumulative (upper_edge, count) pairs ending
        with the +Inf bucket."""
        out = []
        acc = 0
        for b, c in zip(self.boundaries, self.counts):
            acc += c
            out.append((b, acc))
        out.append((math.inf, acc + self.counts[-1]))
        return out


# -- no-op stubs -------------------------------------------------------------

class _NullCounter:
    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()
NULL_SPAN = _NullSpan()


# -- registry ----------------------------------------------------------------

class MetricsRegistry:
    def __init__(self):
        self._metrics: Dict[Tuple[str, str], Any] = {}

    def _get(self, cls, name: str, labels: Dict[str, Any], **kw):
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with _LOCK:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, labels, **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r}{_labels_key(labels)} already registered "
                f"as {type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, labels: Dict[str, Any]) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, labels: Dict[str, Any]) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, labels: Dict[str, Any],
                  boundaries: Sequence[float] = DEFAULT_BUCKETS,
                  ) -> Histogram:
        return self._get(Histogram, name, labels, boundaries=boundaries)

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: {"counters": {...}, "gauges": {...},
        "histograms": {...}} keyed by ``name{label=value,...}``."""
        counters: Dict[str, int] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Any] = {}
        for (name, lk), m in sorted(self._metrics.items()):
            key = name + lk
            if isinstance(m, Counter):
                counters[key] = m.value
            elif isinstance(m, Gauge):
                gauges[key] = m.value
            elif isinstance(m, Histogram):
                hists[key] = {
                    "count": m.count,
                    "sum": m.sum,
                    "min": None if m.count == 0 else m.min,
                    "max": None if m.count == 0 else m.max,
                    "p50": None if m.count == 0 else m.quantile(0.5),
                    "p99": None if m.count == 0 else m.quantile(0.99),
                    "buckets": [[b, c] for b, c in m.cumulative()],
                }
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def reset(self) -> None:
        self._metrics.clear()

    def __iter__(self):
        return iter(sorted(self._metrics.items()))


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def enabled() -> bool:
    return _ENABLED


def _set_enabled(on: bool) -> None:
    global _ENABLED
    _ENABLED = bool(on)


def counter(name: str, **labels):
    """A live :class:`Counter` when telemetry is enabled, else the
    process-wide no-op stub (one branch on the disabled path)."""
    if not _ENABLED:
        return NULL_COUNTER
    return _REGISTRY.counter(name, labels)


def gauge(name: str, **labels):
    if not _ENABLED:
        return NULL_GAUGE
    return _REGISTRY.gauge(name, labels)


def histogram(name: str, boundaries: Sequence[float] = DEFAULT_BUCKETS,
              **labels):
    if not _ENABLED:
        return NULL_HISTOGRAM
    return _REGISTRY.histogram(name, labels, boundaries=boundaries)
