"""Span tracing with Chrome trace-event JSON export.

Spans are wall-clock intervals recorded into a bounded in-process
buffer and exported as Chrome trace-event JSON (the ``[{"ph": "X",
...}]`` array format), loadable in Perfetto (ui.perfetto.dev) or
``chrome://tracing``.

Track model (DESIGN.md section 13): one process (``pid`` 0), one
thread-track per subsystem -- ``serve`` (tid 1) carries ``serve.tick``
spans with nested admit/decode phases, ``train`` (tid 2) carries
``train.step``, ``bench`` (tid 3) harness sections.  Kernel launches
are *instant* events (``ph: "i"``) on the ``kernels`` track (tid 10):
a LaunchContract is recorded once per traced shape at trace time, not
per device execution, so it has no meaningful duration -- its payload
(family, grid, analytic HBM bytes / FLOPs) rides in ``args``.

Like metrics, the disabled path is a no-op: :func:`span` returns the
shared null context manager and :func:`instant` returns immediately.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, Optional

from . import metrics as _m

TRACK_SERVE = 1
TRACK_TRAIN = 2
TRACK_BENCH = 3
TRACK_KERNELS = 10

_TRACK_NAMES = {
    TRACK_SERVE: "serve",
    TRACK_TRAIN: "train",
    TRACK_BENCH: "bench",
    TRACK_KERNELS: "kernels",
}

_MAX_EVENTS = 65536


class TraceBuffer:
    """Bounded buffer of Chrome trace events (oldest dropped first)."""

    def __init__(self, maxlen: int = _MAX_EVENTS):
        self._events: Deque[Dict[str, Any]] = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.dropped = 0

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def add(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def complete(self, name: str, ts_us: float, dur_us: float,
                 tid: int, args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "ph": "X", "ts": ts_us, "dur": dur_us,
              "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.add(ev)

    def instant(self, name: str, tid: int,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "ph": "i", "ts": self.now_us(), "s": "t",
              "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.add(ev)

    def chrome_trace(self, metadata: Optional[Dict[str, Any]] = None,
                     ) -> Dict[str, Any]:
        """Full trace document: ``{"traceEvents": [...], "metadata":
        {...}}`` with thread-name metadata events prepended."""
        meta_events = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "repro"}},
        ]
        for tid, tname in sorted(_TRACK_NAMES.items()):
            meta_events.append(
                {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                 "args": {"name": tname}})
        with self._lock:
            events = meta_events + list(self._events)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "metadata": dict(metadata or {})}
        if self.dropped:
            doc["metadata"]["dropped_events"] = self.dropped
        return doc

    def write(self, path: str, metadata: Optional[Dict[str, Any]] = None,
              ) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(metadata), f)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._t0 = time.perf_counter()

    def __len__(self) -> int:
        return len(self._events)


_BUFFER = TraceBuffer()


def buffer() -> TraceBuffer:
    return _BUFFER


class _Span:
    """Context manager recording one complete ("X") event on exit and
    feeding the matching ``<name>_s`` histogram."""

    __slots__ = ("name", "tid", "args", "_start")

    def __init__(self, name: str, tid: int, args: Optional[Dict] = None):
        self.name = name
        self.tid = tid
        self.args = args
        self._start = 0.0

    def __enter__(self):
        self._start = _BUFFER.now_us()
        return self

    def __exit__(self, *exc):
        end = _BUFFER.now_us()
        _BUFFER.complete(self.name, self._start, end - self._start,
                         self.tid, self.args)
        return False


def span(name: str, tid: int = TRACK_SERVE,
         args: Optional[Dict[str, Any]] = None):
    """``with span("serve.tick", args={...}):`` -- no-op when disabled."""
    if not _m.enabled():
        return _m.NULL_SPAN
    return _Span(name, tid, args)


def instant(name: str, tid: int = TRACK_KERNELS,
            args: Optional[Dict[str, Any]] = None) -> None:
    if not _m.enabled():
        return
    _BUFFER.instant(name, tid, args)
