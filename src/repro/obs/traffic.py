"""Analytic HBM-traffic and FLOP model for traced LaunchContracts.

This turns the EXPERIMENTS.md hand accounting (P25 fused-decode DMA
ledger, P27 fixed-HBM concurrency) into executable code: given a
:class:`~repro.analysis.contracts.LaunchContract`, compute how many
bytes each operand moves between HBM and VMEM over the whole grid, and
an estimate of the arithmetic the kernel performs.

HBM model.  Pallas fetches one block per operand per grid step, but
ELIDES the fetch when the block index is unchanged from the previous
step (the revisit-contiguity rule the static checker enforces makes
this the only legal revisit shape).  So per operand:

    bytes = n_fetches * prod(block) * itemsize
    n_fetches = 1 + (# of consecutive block-index changes over the
                     row-major grid walk)

evaluated with the same index-map machinery as the checker.  Outputs
are written with the same elision rule.  Scalar-prefetch tables live
in SMEM and are excluded (they are KBs against MBs, same stance as the
VMEM estimator).  Scalar-dependent index maps are evaluated under a
deterministic "spread" sample -- distinct in-domain values -- so
table-driven operands (page gathers) count one fetch per distinct
entry rather than collapsing onto a corner value.

FLOP model.  Attention families (``*_fwd``, ``*_bwd``,
``decode_attend*``) are scored with the standard form

    2 * Q * K * (d + dv) + C_softmax * Q * K        per grid step

where Q/K are the block row counts of the ``q`` and ``k_*`` operands
and ``C_softmax = 8`` covers exp/max/sum/scale; backward passes cost
~2.5x forward.  Everything else (``decode_update*``, packers) is
scored as elementwise traffic: ``4`` ops per output element.  These
are *analytic estimates* for roofline ratios and regression tracking,
not hardware counters.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.contracts import LaunchContract, Operand

from . import metrics as _m
from . import tracing as _t

# beyond this many grid steps, skip index-map evaluation and use the
# conservative one-fetch-per-step closed form
_MAX_EVAL_STEPS = 1 << 20

_SOFTMAX_OPS_PER_SCORE = 8
_ELEMENTWISE_OPS = 4
_BWD_FACTOR = 2.5


def _itemsize(dtype: str) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        # bfloat16 & friends when ml_dtypes is not registered with numpy
        import jax.numpy as jnp
        return jnp.dtype(dtype).itemsize


def _grid_arrays(grid: Tuple[int, ...]) -> List[np.ndarray]:
    axes = [np.arange(g, dtype=np.int64) for g in grid]
    if not axes:
        return []
    return [m.ravel() for m in np.meshgrid(*axes, indexing="ij")]


def _spread_scalars(contract: LaunchContract) -> Tuple[np.ndarray, ...]:
    """Deterministic in-domain scalar tables with distinct consecutive
    values: ``lo + arange(size) % span`` reshaped to the table shape."""
    tabs = []
    for s in contract.scalars:
        lo = np.broadcast_to(np.asarray(s.lo, dtype=np.int64), s.shape)
        hi = np.broadcast_to(np.asarray(s.hi, dtype=np.int64), s.shape)
        span = np.maximum(hi - lo + 1, 1)
        n = int(np.prod(s.shape)) if s.shape else 1
        walk = np.arange(n, dtype=np.int64).reshape(s.shape)
        tabs.append(lo + walk % span)
    return tuple(tabs)


def _n_fetches(op: Operand, grid: Tuple[int, ...],
               gargs: List[np.ndarray],
               stabs: Tuple[np.ndarray, ...]) -> int:
    """Number of HBM block fetches for one operand over the grid walk
    (consecutive identical block indices fetch once)."""
    n = int(np.prod(grid)) if grid else 1
    if not grid:
        return 1
    idx = op.index_map(*gargs, *stabs)
    if not isinstance(idx, tuple):
        idx = (idx,)
    cols = [np.broadcast_to(np.asarray(c, dtype=np.int64), (n,))
            for c in idx]
    bidx = np.stack(cols, axis=-1)
    changed = (bidx[1:] != bidx[:-1]).any(axis=1)
    return 1 + int(changed.sum())


def _block_bytes(op: Operand) -> int:
    return int(np.prod(op.block)) * _itemsize(op.dtype)


def contract_hbm_bytes(contract: LaunchContract) -> Dict[str, Any]:
    """Analytic HBM traffic for one launch.

    Returns ``{"read_bytes", "write_bytes", "by_operand": {name:
    {"fetches", "block_bytes", "bytes", "dir"}}}``.  Aliased in/out
    pairs are counted on both sides (the update kernels genuinely read
    then write the aliased page).
    """
    grid = contract.grid
    n_steps = int(np.prod(grid)) if grid else 1
    use_eval = n_steps <= _MAX_EVAL_STEPS
    gargs = _grid_arrays(grid) if use_eval else []
    stabs = _spread_scalars(contract) if use_eval else ()

    by_op: Dict[str, Any] = {}
    totals = {"in": 0, "out": 0}
    # the hook fires while the enclosing jit/eval_shape trace is still
    # active; force the index-map jnp ops eager so concrete numpy grid
    # walks stay concrete instead of being staged into the trace
    with jax.ensure_compile_time_eval():
        for direction, ops in (("in", contract.inputs),
                               ("out", contract.outputs)):
            for op in ops:
                if use_eval:
                    fetches = _n_fetches(op, grid, gargs, stabs)
                else:
                    fetches = n_steps
                bb = _block_bytes(op)
                by_op[op.name] = {"fetches": fetches, "block_bytes": bb,
                                  "bytes": fetches * bb, "dir": direction}
                totals[direction] += fetches * bb
    return {"read_bytes": totals["in"], "write_bytes": totals["out"],
            "by_operand": by_op}


def _rows(op: Operand, d: int) -> int:
    n = int(np.prod(op.block))
    return n // d if d > 0 else n


def contract_flops(contract: LaunchContract) -> int:
    """Analytic FLOPs for one launch (whole grid)."""
    n_steps = int(np.prod(contract.grid)) if contract.grid else 1
    fam = contract.family
    q = next((o for o in contract.inputs if o.name == "q"), None)
    ks = [o for o in contract.inputs if o.name.startswith("k")]
    if q is not None and ks:
        d = int(q.block[-1])
        dv = next((int(o.block[-1]) for o in contract.inputs
                   if o.name.startswith("v")), d)
        q_rows = _rows(q, d)
        k_rows = sum(_rows(o, d) for o in ks)
        per_step = (2 * q_rows * k_rows * (d + dv)
                    + _SOFTMAX_OPS_PER_SCORE * q_rows * k_rows)
        if "bwd" in fam:
            per_step = int(per_step * _BWD_FACTOR)
        return per_step * n_steps
    out_elems = sum(int(np.prod(o.block)) for o in contract.outputs)
    return _ELEMENTWISE_OPS * out_elems * n_steps


def on_launch(contract: LaunchContract) -> None:
    """Launch hook (registered by ``obs.enable()``): account one traced
    ``pallas_call`` into counters and the kernel trace track."""
    traffic = contract_hbm_bytes(contract)
    flops = contract_flops(contract)
    fam = contract.family
    _m.counter("kernel.launches", family=fam).inc()
    _m.counter("kernel.hbm_read_bytes", family=fam).inc(
        traffic["read_bytes"])
    _m.counter("kernel.hbm_write_bytes", family=fam).inc(
        traffic["write_bytes"])
    _m.counter("kernel.flops", family=fam).inc(flops)
    args = {
        "family": fam,
        "grid": list(contract.grid),
        "hbm_read_bytes": traffic["read_bytes"],
        "hbm_write_bytes": traffic["write_bytes"],
        "flops": flops,
    }
    for k in ("impl", "tq", "mode", "nr", "Lmax", "levels"):
        if k in contract.meta:
            args[k] = contract.meta[k]
    _t.instant("kernel.launch", tid=_t.TRACK_KERNELS, args=args)
