"""Zamba2-1.2B: Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242].  The shared block's attention is causal H1D -- the
arch's long-context bottleneck."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
        num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192,
        vocab_size=32000, attention="h1d", nr=16,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        hybrid_attn_every=6, tie_embeddings=True, dtype="bfloat16",
        remat=True)


def smoke():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        attention="h1d", nr=8, ssm_state=16, ssm_head_dim=16, ssm_expand=2,
        ssm_chunk=16, hybrid_attn_every=3, tie_embeddings=True)
