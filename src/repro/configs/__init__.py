"""Architecture configs: the 10 assigned archs + the paper's own models.

``get_config(name)`` -> full config; ``get_smoke_config(name)`` -> reduced
same-family config for CPU smoke tests.  ``ARCH_IDS`` lists the assigned
pool; ``SHAPES`` defines the per-arch input-shape set.
"""
import importlib

ARCH_IDS = [
    "yi-6b", "qwen2.5-14b", "llama3.2-1b", "gemma3-4b",
    "seamless-m4t-medium", "qwen2-moe-a2.7b", "arctic-480b",
    "llava-next-34b", "mamba2-1.3b", "zamba2-1.2b",
]
PAPER_IDS = ["h1d-lm-53m", "h1d-lm-144m", "h1d-lra-encoder"]

_MODULES = {
    "yi-6b": "yi_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma3-4b": "gemma3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "arctic-480b": "arctic_480b",
    "llava-next-34b": "llava_next_34b",
    "mamba2-1.3b": "mamba2_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "h1d-lm-53m": "h1d_lm",
    "h1d-lm-144m": "h1d_lm",
    "h1d-lra-encoder": "h1d_lm",
}

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if hasattr(mod, "CONFIGS"):
        return mod.CONFIGS[name]()
    return mod.config()


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if hasattr(mod, "SMOKES"):
        return mod.SMOKES[name]()
    return mod.smoke()
