"""Yi-6B: llama-arch dense GQA [arXiv:2403.04652]."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="yi-6b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=4, head_dim=128, d_ff=11008,
        vocab_size=64000, attention="h1d", nr=16, rope_theta=5_000_000.0,
        dtype="bfloat16", remat=True,
        seq_parallel_residual=False)


def smoke():
    return ModelConfig(
        name="yi-6b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, head_dim=8, d_ff=128, vocab_size=512,
        attention="h1d", nr=8)
