"""Snowflake Arctic-480B: dense-MoE hybrid, 128 experts top-2 with a dense
FFN residual branch [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=4864,
        vocab_size=32000, attention="h1d", nr=16,
        moe_experts=128, moe_top_k=2, moe_d_ff=4864,
        moe_dense_residual=True, dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="arctic-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512,
        attention="h1d", nr=8, moe_experts=8, moe_top_k=2, moe_d_ff=64,
        moe_dense_residual=True)
