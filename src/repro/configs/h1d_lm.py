"""The paper's own models (section 8.2): 53M / 144M decoder LMs with
N_r=16 hierarchical attention, plus the LRA-style encoder (section 8.1:
6L / 8H / 512 / FFN 2048)."""
from repro.models.common import ModelConfig


def _lm(name, d_model, d_ff):
    return ModelConfig(
        name=name, family="dense", num_layers=6, d_model=d_model,
        num_heads=8, num_kv_heads=8, head_dim=d_model // 8, d_ff=d_ff,
        vocab_size=32768, attention="h1d", nr=16, causal_mode="fine-q",
        tie_embeddings=True)


CONFIGS = {
    "h1d-lm-53m": lambda: _lm("h1d-lm-53m", 512, 2048),
    "h1d-lm-144m": lambda: _lm("h1d-lm-144m", 1024, 4096),
    "h1d-lra-encoder": lambda: ModelConfig(
        name="h1d-lra-encoder", family="dense", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=8, head_dim=64, d_ff=2048,
        vocab_size=256, attention="h1d", nr=16, tie_embeddings=True),
}

SMOKES = {
    k: (lambda: ModelConfig(
        name=f"{k}-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        attention="h1d", nr=8, tie_embeddings=True))
    for k in CONFIGS
}
