"""Qwen2.5-14B: dense GQA with QKV bias [hf:Qwen/Qwen2.5]."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=13824,
        vocab_size=152064, qkv_bias=True, attention="h1d", nr=16,
        rope_theta=1_000_000.0, dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="qwen2.5-14b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        qkv_bias=True, attention="h1d", nr=8)
