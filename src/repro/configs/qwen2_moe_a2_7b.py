"""Qwen2-MoE-A2.7B: 60 routed experts top-4 + shared expert
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128, d_ff=5632,
        vocab_size=151936, attention="h1d", nr=16,
        moe_experts=60, moe_top_k=4, moe_d_ff=1408, moe_shared_d_ff=5632,
        qkv_bias=True, dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        attention="h1d", nr=8, moe_experts=8, moe_top_k=2, moe_d_ff=32,
        moe_shared_d_ff=64, qkv_bias=True)
