"""Mamba2-1.3B: attention-free SSD [arXiv:2405.21060].  H1D attention is
inapplicable (DESIGN.md section 5); long_500k runs natively."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
        num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        tie_embeddings=True, dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
        num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=512,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
        tie_embeddings=True)
