"""Gemma3-4B: 5:1 local:global attention, 128k ctx [hf:google/gemma-3].

Local layers: block-local sliding window (1024).  Global layers: H1D --
exactly where the quadratic cost lived; this is the arch that benefits
most from the paper's technique at long context.
"""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="gemma3-4b", family="dense", num_layers=34, d_model=2560,
        num_heads=8, num_kv_heads=4, head_dim=256, d_ff=10240,
        vocab_size=262144, attention="h1d", nr=16, sliding_window=1024,
        global_every=6, qk_norm=True, mlp_activation="geglu",
        tie_embeddings=True, rope_theta=1_000_000.0, dtype="bfloat16",
        remat=True)


def smoke():
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense", num_layers=6, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        attention="h1d", nr=8, sliding_window=16, global_every=3,
        qk_norm=True, mlp_activation="geglu", tie_embeddings=True)
