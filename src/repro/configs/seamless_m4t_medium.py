"""SeamlessM4T-medium backbone: enc-dec; audio frontend stubbed
[arXiv:2308.11596].  seq_len applies to the (long) audio frame axis; the
decoder runs a fixed modest target length (DESIGN.md section 5)."""
from repro.models.common import ModelConfig

DECODER_LEN = 1024  # teacher-forced / prefill target length


def config():
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec", num_layers=12,
        encoder_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=4096, vocab_size=256206, attention="h1d", nr=16,
        dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="seamless-smoke", family="encdec", num_layers=2,
        encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, attention="h1d", nr=8)
