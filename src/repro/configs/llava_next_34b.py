"""LLaVA-NeXT-34B backbone (Yi-34B-ish decoder); anyres vision frontend
stubbed -- input_specs provides precomputed patch embeddings
[hf:llava-hf/llava-v1.6].  Patches are a 1D prefix (the paper defers 2D
attention to future work)."""
from repro.models.common import ModelConfig

PATCHES = 576  # one image of stubbed anyres patch embeddings


def config():
    return ModelConfig(
        name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
        vocab_size=64000, attention="h1d", nr=16, prefix_len=PATCHES,
        rope_theta=5_000_000.0, dtype="bfloat16", remat=True)


def smoke():
    return ModelConfig(
        name="llava-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        attention="h1d", nr=8, prefix_len=16)
