"""Llama-3.2-1B: small llama3, tied embeddings [hf:meta-llama/Llama-3.2-1B]."""
from repro.models.common import ModelConfig


def config():
    return ModelConfig(
        name="llama3.2-1b", family="dense", num_layers=16, d_model=2048,
        num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192,
        vocab_size=128256, tie_embeddings=True, attention="h1d", nr=16,
        rope_theta=500_000.0, dtype="bfloat16", remat=True,
        seq_parallel_residual=False)


def smoke():
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        tie_embeddings=True, attention="h1d", nr=8)
