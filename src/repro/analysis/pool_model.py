"""Bounded exhaustive model checker for the paged KV pool.

``serve/paged_cache.py``'s :class:`PagePool` is a pure host-side state
machine (free lists, refcounts, page tables, prefix registry, LRU), so
its whole reachable state space on a SMALL geometry can be enumerated:
this module drives the REAL allocator -- not a re-implementation --
through every interleaving of the serving engine's mutating operations
{admit, decode-write (prepare_tick), COW, evict, preempt-snapshot,
restore, finish} from a small prompt set, asserting after every
transition (DESIGN.md section 12):

* **refcount conservation** -- ``refcount[l][p]`` equals the number of
  page-table references, reserved pages stay at zero, and every
  refcount-0 page is on exactly one of the free / evictable lists
  (kind ``refcount-leak``);
* **no use-after-free** -- no duplicate or referenced page on a free
  list, no table entry outside the pool (kind ``use-after-free``);
* **no aliasing outside the registry** -- a page mapped by more than
  one (slot, block) must be advertised in the prefix registry, and the
  decode write-set page after ``prepare_tick`` is exclusively owned
  (kind ``shared-alias``);
* **ZERO/TRASH immutability** -- reserved pages never appear in a slot
  table and never land in a tick's write set (kinds ``shared-alias`` /
  ``use-after-free``);
* **transactional-admit rollback identity** -- a failed admit leaves
  the pool fingerprint bit-identical (registry divergence is
  ``zombie-registry``, anything else ``refcount-leak``);
* **registry liveness** -- ``registry``/``key_of`` stay a bijection
  onto live registered pages (kind ``zombie-registry``).

Every counterexample is a replayable :class:`Op` schedule, greedily
minimized (delta-debugging over a lenient replayer that skips
inapplicable ops) and JSON-serializable -- the regression suite feeds
minimized schedules through the real :class:`PagePool` via
:func:`replay_schedule`.  ``REPRO_POOL_CHECK=1`` makes the pool itself
call :func:`check_pool_invariants` after every mutating op, so fuzzing
(``tests/test_paged.py``) and this checker share ONE invariant
definition.
"""
from __future__ import annotations

import copy
import dataclasses
from collections import Counter, OrderedDict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .checker import Violation

POOL_KINDS = ("refcount-leak", "use-after-free", "shared-alias",
              "zombie-registry")

#: default model geometry: 2 slots over a deliberately tight pool so
#: COW, eviction and exhaustion are all reachable within a few ops
DEFAULT_GEOMETRY = dict(slots=2, max_len=16, nr=4, pool_pages=4)


def default_pool():
    from repro.serve.paged_cache import PagePool
    return PagePool(**DEFAULT_GEOMETRY)


def default_prompts() -> Tuple[np.ndarray, ...]:
    """Three prompts: two sharing an 8-token prefix (registry hits +
    COW on divergence), one short enough to leave a partial frontier
    page (COW on the first decode write)."""
    return (np.arange(8, dtype=np.int32),
            np.concatenate([np.arange(8, dtype=np.int32),
                            np.arange(100, 104, dtype=np.int32)]),
            np.arange(50, 56, dtype=np.int32))


# ---------------------------------------------------------------------------
# invariants (shared with PagePool's REPRO_POOL_CHECK hook)
# ---------------------------------------------------------------------------

def check_pool_invariants(pool, family: str = "pool") -> List[Violation]:
    """Structural invariants of a :class:`PagePool`.  Pure reads; safe
    to call from inside the pool's own mutating ops."""
    from repro.serve.paged_cache import TRASH, ZERO
    out: List[Violation] = []
    for l in range(pool.M):
        n = pool.num_pages[l]
        lv = f"L{l}"
        free = pool.free[l]
        fs = set(free)
        if len(fs) != len(free):
            dup = [p for p in fs if free.count(p) > 1]
            out.append(Violation(family, lv, "use-after-free",
                                 f"page {dup[0]} on the free list "
                                 f"{free.count(dup[0])} times"))
        for p in sorted(fs):
            if p < 2 or p >= n:
                out.append(Violation(family, lv, "use-after-free",
                                     f"free list holds invalid page {p} "
                                     f"(pool has pages 2..{n - 1})"))
        tab = pool.table[l]
        vals = tab[tab >= 0]
        if vals.size and int(vals.max()) >= n:
            out.append(Violation(family, lv, "use-after-free",
                                 f"slot table maps nonexistent page "
                                 f"{int(vals.max())}"))
            vals = vals[vals < n]
        if np.isin(vals, (ZERO, TRASH)).any():
            out.append(Violation(family, lv, "shared-alias",
                                 "slot table maps a reserved ZERO/TRASH "
                                 "page -- a tick would mutate it"))
        counts = np.bincount(vals, minlength=n)
        rc = pool.refcount[l]
        if int(rc[ZERO]) or int(rc[TRASH]):
            out.append(Violation(family, lv, "refcount-leak",
                                 f"reserved pages carry refcounts "
                                 f"(ZERO={int(rc[ZERO])}, "
                                 f"TRASH={int(rc[TRASH])})"))
        evs = {p for (ll, p) in pool.evictable if ll == l}
        for p in range(2, n):
            r, c = int(rc[p]), int(counts[p])
            reg = (l, p) in pool.key_of
            inf, ine = p in fs, p in evs
            if r != c:
                out.append(Violation(
                    family, f"{lv} p{p}", "refcount-leak",
                    f"refcount {r} != {c} page-table references"))
            if inf and r > 0:
                out.append(Violation(
                    family, f"{lv} p{p}", "use-after-free",
                    f"page on the free list while still referenced "
                    f"(rc={r}) -- the next alloc would hand out live "
                    f"KV"))
            if ine and r > 0:
                out.append(Violation(
                    family, f"{lv} p{p}", "refcount-leak",
                    f"page parked on the evictable LRU while still "
                    f"referenced (rc={r})"))
            if inf and ine:
                out.append(Violation(
                    family, f"{lv} p{p}", "use-after-free",
                    "page on BOTH the free list and the evictable LRU "
                    "-- it can be handed out twice"))
            if r == 0 and not inf and not ine:
                out.append(Violation(
                    family, f"{lv} p{p}", "refcount-leak",
                    "page leaked: refcount 0 but on neither the free "
                    "list nor the evictable LRU"))
            if inf and reg:
                out.append(Violation(
                    family, f"{lv} p{p}", "zombie-registry",
                    "prefix registry still advertises a FREED page -- "
                    "the next registry hit would serve recycled KV"))
            if ine and not reg:
                out.append(Violation(
                    family, f"{lv} p{p}", "zombie-registry",
                    "unregistered page parked on the evictable LRU -- "
                    "nothing can ever reclaim or re-hit it"))
            if r > 1 and not reg:
                out.append(Violation(
                    family, f"{lv} p{p}", "shared-alias",
                    f"page mapped by {r} (slot, block) references "
                    f"outside the sharing registry"))
    for key, (l, p) in pool.registry.items():
        if key[0] != l:
            out.append(Violation(family, f"L{l} p{p}", "zombie-registry",
                                 f"registry key level {key[0]} != "
                                 f"mapped level {l}"))
        elif p < 2 or p >= pool.num_pages[l]:
            out.append(Violation(family, f"L{l} p{p}", "zombie-registry",
                                 "registry entry points at an invalid "
                                 "page"))
        elif pool.key_of.get((l, p)) != key:
            out.append(Violation(family, f"L{l} p{p}", "zombie-registry",
                                 "registry -> key_of is not a bijection "
                                 "(stale forward entry)"))
    for (l, p), key in pool.key_of.items():
        if pool.registry.get(key) != (l, p):
            out.append(Violation(family, f"L{l} p{p}", "zombie-registry",
                                 "key_of -> registry is not a bijection "
                                 "(stale reverse entry)"))
    return out


def check_tick_postconditions(pool, slot: int, t: int,
                              family: str = "pool") -> List[Violation]:
    """After ``prepare_tick(slot, t)`` succeeds, position ``t``'s
    write-set page at every level must be present, private, and
    unadvertised -- the decode kernel mutates it in place."""
    from repro.serve.paged_cache import TRASH, ZERO
    out: List[Violation] = []
    for l in range(pool.M):
        blk = t // (pool.nr << l)
        p = int(pool.table[l][slot, blk])
        lv = f"L{l} t{t}"
        if p < 0:
            out.append(Violation(family, lv, "use-after-free",
                                 "write-set page unmapped after "
                                 "prepare_tick -- the kernel would "
                                 "write nowhere"))
            continue
        if p in (ZERO, TRASH):
            out.append(Violation(family, lv, "shared-alias",
                                 f"tick would write reserved page {p} "
                                 f"(ZERO/TRASH immutability)"))
            continue
        if int(pool.refcount[l][p]) > 1:
            out.append(Violation(
                family, lv, "shared-alias",
                f"tick writes page {p} still shared by "
                f"{int(pool.refcount[l][p])} references (missing "
                f"copy-on-write)"))
        if (l, p) in pool.key_of:
            out.append(Violation(
                family, lv, "zombie-registry",
                f"tick writes page {p} still advertised in the prefix "
                f"registry -- future hits would read post-divergence "
                f"content"))
    return out


# ---------------------------------------------------------------------------
# pool cloning + canonical fingerprints
# ---------------------------------------------------------------------------

def clone_pool(pool):
    """Cheap deep-enough copy of a :class:`PagePool` (or a mutated test
    subclass -- ``copy.copy`` preserves the class)."""
    new = copy.copy(pool)
    new.free = [list(f) for f in pool.free]
    new.refcount = [r.copy() for r in pool.refcount]
    new.table = [t.copy() for t in pool.table]
    new.registry = dict(pool.registry)
    new.key_of = dict(pool.key_of)
    new.evictable = OrderedDict(pool.evictable)
    new.stats = dataclasses.replace(pool.stats)
    return new


def pool_fingerprint(pool) -> tuple:
    """Canonical hashable pool state.  Free lists are SORTED (page
    allocation order is not behaviour the invariants care about);
    evictable keeps its order (LRU order IS behaviour)."""
    return (
        tuple(tuple(sorted(f)) for f in pool.free),
        tuple(tuple(int(x) for x in r) for r in pool.refcount),
        tuple(tuple(int(x) for x in t.ravel()) for t in pool.table),
        tuple(sorted(pool.registry.items())),
        tuple(pool.evictable.keys()),
    )


def _check_rollback(fp0: tuple, fp1: tuple, where: str) -> List[Violation]:
    """Transactional-admit identity, modulo the two things a failed
    admit is ALLOWED to change:

    * the evictable LRU *recency* of parked pages its registry hits
      touched (eviction order is a heuristic, not a safety property);
    * registry entries dropped by evictions it performed before running
      out -- an evicted page may have been reused by an earlier level
      of the same admit, so re-registering the old key would advertise
      garbage; the entry is gone and its page moves evictable -> free.

    Everything else -- tables, refcounts, no new/changed registry
    entries, free/evictable membership beyond the evicted set -- must
    be bit-identical."""
    out: List[Violation] = []
    if fp1[1] != fp0[1] or fp1[2] != fp0[2]:
        out.append(Violation(
            "pool", where, "refcount-leak",
            "failed admit left refcounts/page-tables changed "
            "(transactional-admit identity)"))
        return out
    reg0, reg1 = dict(fp0[3]), dict(fp1[3])
    added = set(reg1) - set(reg0)
    moved = {k for k in set(reg0) & set(reg1) if reg0[k] != reg1[k]}
    if added or moved:
        out.append(Violation(
            "pool", where, "zombie-registry",
            "failed admit left registrations behind -- a stale key "
            "would serve garbage to the next prompt hashing to it"))
        return out
    evicted = {reg0[k] for k in set(reg0) - set(reg1)}
    free0 = {(l, p) for l, f in enumerate(fp0[0]) for p in f}
    free1 = {(l, p) for l, f in enumerate(fp1[0]) for p in f}
    ev0, ev1 = set(fp0[4]), set(fp1[4])
    if (free1 - free0 != evicted or not free0 <= free1
            or ev0 - ev1 != evicted or not ev1 <= ev0):
        out.append(Violation(
            "pool", where, "refcount-leak",
            "failed admit changed free/evictable membership beyond "
            "the entries its evictions legally dropped"))
    return out


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Op:
    """One engine-level operation: ``admit`` (arg = prompt index),
    ``tick`` (one decode write at the slot's current position),
    ``finish`` (release), ``snapshot`` (preempt: record blocks +
    release), ``restore`` (arg = parked-snapshot index)."""
    op: str
    slot: int = 0
    arg: int = 0


def schedule_to_json(schedule: Sequence[Op]) -> List[dict]:
    return [dataclasses.asdict(op) for op in schedule]


def schedule_from_json(data: Sequence[dict]) -> List[Op]:
    return [Op(**d) for d in data]


class _Model:
    """The explorer's state: a real pool + the engine-side bookkeeping
    (which slots are live at which position, parked snapshots)."""

    def __init__(self, pool, prompts, snap_cap: int = 1):
        self.pool = pool
        self.prompts = prompts
        self.snap_cap = snap_cap
        self.live: Dict[int, List[int]] = {}     # slot -> [prompt, pos]
        self.snaps: List[Tuple[int, int, Dict[int, List[int]]]] = []
        self.path: Tuple[Op, ...] = ()

    def clone(self) -> "_Model":
        m = _Model(clone_pool(self.pool), self.prompts, self.snap_cap)
        m.live = {s: list(v) for s, v in self.live.items()}
        m.snaps = [(p, t, {l: list(b) for l, b in blocks.items()})
                   for p, t, blocks in self.snaps]
        m.path = self.path
        return m

    def fingerprint(self) -> tuple:
        return (pool_fingerprint(self.pool),
                tuple(sorted((s, tuple(v)) for s, v in self.live.items())),
                tuple((p, t, tuple((l, tuple(b))
                                   for l, b in sorted(blocks.items())))
                      for p, t, blocks in self.snaps))

    def successors(self) -> List[Op]:
        ops = []
        for s in range(self.pool.slots):
            if s in self.live:
                if self.live[s][1] < self.pool.Lp:
                    ops.append(Op("tick", s))
                ops.append(Op("finish", s))
                if len(self.snaps) < self.snap_cap:
                    ops.append(Op("snapshot", s))
            else:
                for i in range(len(self.prompts)):
                    ops.append(Op("admit", s, i))
                for j in range(len(self.snaps)):
                    ops.append(Op("restore", s, j))
        return ops

    def apply(self, op: Op) -> Tuple[bool, List[Violation]]:
        """Apply one op to the REAL pool.  Returns ``(applied,
        violations)``; inapplicable ops (lenient replay) return
        ``(False, [])`` without touching state."""
        from repro.serve.paged_cache import PoolExhausted
        pool = self.pool
        vs: List[Violation] = []
        where = f"{op.op} slot{op.slot}"
        if op.op == "admit":
            if op.slot in self.live or not (0 <= op.slot < pool.slots) \
                    or not (0 <= op.arg < len(self.prompts)):
                return False, []
            fp0 = pool_fingerprint(pool)
            try:
                pool.admit(op.slot, self.prompts[op.arg])
                self.live[op.slot] = [op.arg,
                                      len(self.prompts[op.arg])]
            except PoolExhausted:
                vs.extend(_check_rollback(fp0, pool_fingerprint(pool),
                                          where))
        elif op.op == "tick":
            st = self.live.get(op.slot)
            if st is None or st[1] >= pool.Lp:
                return False, []
            t = st[1]
            try:
                pool.prepare_tick(op.slot, t, {})
                st[1] += 1
                vs.extend(check_tick_postconditions(pool, op.slot, t))
            except PoolExhausted:
                pass           # legal partial state: the engine retries
        elif op.op == "finish":
            if op.slot not in self.live:
                return False, []
            pool.release_slot(op.slot)
            del self.live[op.slot]
        elif op.op == "snapshot":
            st = self.live.get(op.slot)
            if st is None or len(self.snaps) >= self.snap_cap:
                return False, []
            blocks = {
                l: [int(b) for b in
                    np.nonzero(pool.table[l][op.slot] >= 0)[0]]
                for l in range(pool.M)}
            pool.release_slot(op.slot)
            self.snaps.append((st[0], st[1], blocks))
            del self.live[op.slot]
        elif op.op == "restore":
            if op.slot in self.live or not (0 <= op.slot < pool.slots) \
                    or not (0 <= op.arg < len(self.snaps)):
                return False, []
            p, t, blocks = self.snaps[op.arg]
            try:
                pool.admit_snapshot(op.slot, blocks)
                self.live[op.slot] = [p, t]
                self.snaps.pop(op.arg)
            except PoolExhausted:
                pool.release_slot(op.slot)   # documented caller unwind
        else:
            return False, []
        vs.extend(check_pool_invariants(pool))
        return True, vs


# ---------------------------------------------------------------------------
# exploration, replay, minimization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolCheckResult:
    states: int
    transitions: int
    coverage: Dict[str, int]
    violations: List[Violation]
    counterexample: Optional[List[Op]] = None


def explore(*, pool_factory: Callable = default_pool,
            prompts: Optional[Sequence[np.ndarray]] = None,
            max_states: int = 12000, snap_cap: int = 1,
            ) -> PoolCheckResult:
    """Breadth-first enumeration of the pool's reachable states up to
    ``max_states`` distinct canonical fingerprints.  Stops at the FIRST
    invariant violation and returns its schedule (already minimized by
    :func:`minimize_schedule` when one is found)."""
    prompts = tuple(prompts) if prompts is not None else default_prompts()
    root = _Model(pool_factory(), prompts, snap_cap)
    seen = {root.fingerprint()}
    queue = deque([root])
    cov: Counter = Counter()
    states, transitions = 1, 0
    while queue and states < max_states:
        m = queue.popleft()
        for op in m.successors():
            m2 = m.clone()
            s0 = dataclasses.replace(m2.pool.stats)
            applied, vs = m2.apply(op)
            if not applied:
                continue
            transitions += 1
            cov[op.op] += 1
            s1 = m2.pool.stats
            cov["cow_copies"] += s1.cow_copies - s0.cow_copies
            cov["evictions"] += s1.evictions - s0.evictions
            cov["shared_maps"] += s1.shared_maps - s0.shared_maps
            cov["fresh_pages"] += s1.fresh_pages - s0.fresh_pages
            if vs:
                ce = list(m.path) + [op]
                ce = minimize_schedule(ce, pool_factory=pool_factory,
                                       prompts=prompts,
                                       kinds={v.kind for v in vs},
                                       snap_cap=snap_cap)
                return PoolCheckResult(states, transitions, dict(cov),
                                       vs, ce)
            fp = m2.fingerprint()
            if fp in seen:
                continue
            seen.add(fp)
            states += 1
            m2.path = m.path + (op,)
            queue.append(m2)
    return PoolCheckResult(states, transitions, dict(cov), [])


def replay_schedule(schedule: Sequence[Op], *,
                    pool_factory: Callable = default_pool,
                    prompts: Optional[Sequence[np.ndarray]] = None,
                    snap_cap: int = 1,
                    ) -> Tuple[List[Violation], "object"]:
    """Feed a schedule through the REAL pool, leniently (inapplicable
    ops are skipped -- this is what makes delta-debugging sound).
    Returns ``(violations, pool)``; stops at the first violating op."""
    prompts = tuple(prompts) if prompts is not None else default_prompts()
    m = _Model(pool_factory(), prompts, snap_cap)
    for op in schedule:
        _, vs = m.apply(op)
        if vs:
            return vs, m.pool
    return [], m.pool


def minimize_schedule(schedule: Sequence[Op], *,
                      pool_factory: Callable = default_pool,
                      prompts: Optional[Sequence[np.ndarray]] = None,
                      kinds: Optional[set] = None,
                      snap_cap: int = 1) -> List[Op]:
    """Greedy delta-debugging: repeatedly drop ops (latest first) while
    the replay still produces a violation of one of ``kinds`` (any kind
    if None).  The result replays through :func:`replay_schedule`."""
    def fails(sched):
        vs, _ = replay_schedule(sched, pool_factory=pool_factory,
                                prompts=prompts, snap_cap=snap_cap)
        return any(kinds is None or v.kind in kinds for v in vs)

    cur = list(schedule)
    if not fails(cur):
        return cur            # non-deterministic repro: keep as-is
    changed = True
    while changed:
        changed = False
        for i in reversed(range(len(cur))):
            cand = cur[:i] + cur[i + 1:]
            if fails(cand):
                cur = cand
                changed = True
    return cur


def run_pool(*, max_states: int = 12000,
             ) -> Tuple[Dict[str, object], List[Violation]]:
    """CLI driver: explore the default geometry with the real pool.
    Returns ``(stats, violations)`` shaped like ``dist.run_dist``."""
    res = explore(max_states=max_states)
    stats: Dict[str, object] = {
        "states": res.states, "transitions": res.transitions,
        "coverage": res.coverage,
    }
    if res.counterexample is not None:
        stats["counterexample"] = schedule_to_json(res.counterexample)
    return stats, res.violations
