"""Static checker for :class:`~repro.analysis.contracts.LaunchContract`.

Abstract evaluation of every BlockSpec index map over the FULL grid:
the maps are elementwise functions of the grid indices (and the
scalar-prefetch tables), so one vectorized call with numpy meshgrid
index arrays evaluates all grid points at once -- jnp ops inside the
maps execute eagerly on numpy inputs, and scalar-table reads like
``tref[r]`` / ``bref[r, band]`` become numpy fancy indexing.

Checks, per contract:

* **in-bounds** -- every block's element offset range ``[idx*bs,
  idx*bs + bs)`` lies inside the operand array, at every grid point
  (this is what catches a bad halo/prev-block clamp at the grid edge).
* **output coverage** -- a non-aliased output's blocks form an exact
  partition of the array, each written exactly once; revisits are legal
  only if contiguous in the row-major grid iteration order (the
  VMEM-accumulation pattern of the dKVW kernels -- a non-contiguous
  revisit means a block is flushed and re-fetched, i.e. a double
  write).  Aliased outputs are in-place scatters by design (trash-page
  collisions, partial pair writes), so they get only the in-bounds
  check.
* **alias agreement** -- an aliased input/output pair must agree on
  array shape, dtype, block shape AND index map (evaluated pointwise
  over the grid), or the in-place write lands somewhere else than the
  read.
* **scalar domains** -- the maps are evaluated at the lo/hi corners of
  every scalar table's declared domain plus seeded random tables; a
  violation that needs a scalar sample to manifest is tagged
  ``scalar-oob`` (an out-of-range prefetch index under the *declared*
  geometry).

Everything here is pure numpy + eager jnp -- no tracing, no
compilation; checking a contract is microseconds per map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .contracts import LaunchContract, Operand

DEFAULT_SAMPLES = 3   # random scalar tables per contract (plus lo+hi)


@dataclasses.dataclass(frozen=True)
class Violation:
    family: str
    operand: str
    kind: str     # oob | scalar-oob | coverage-gap | double-write |
                  # alias-mismatch | bad-spec
    detail: str

    def __str__(self) -> str:
        return f"[{self.family}] {self.operand}: {self.kind}: {self.detail}"


def _grid_arrays(grid: Tuple[int, ...]) -> List[np.ndarray]:
    """Flattened row-major meshgrid index arrays, one per grid axis.

    Row-major (``indexing='ij'`` + ravel) makes position in the
    flattened arrays == Pallas grid iteration order (last axis
    fastest), which the revisit-contiguity rule relies on."""
    axes = [np.arange(g, dtype=np.int64) for g in grid]
    if not axes:
        return []
    return [m.ravel() for m in np.meshgrid(*axes, indexing="ij")]


def _bounds_arrays(spec, which: str) -> np.ndarray:
    b = getattr(spec, which)
    return np.broadcast_to(np.asarray(b, dtype=np.int64), spec.shape)


def _scalar_samples(contract: LaunchContract, samples: int,
                    seed: int) -> List[Tuple[str, Tuple[np.ndarray, ...]]]:
    """Scalar-table value samples: the lo corner, the hi corner, then
    ``samples`` seeded-random tables, all within the declared domains."""
    if not contract.scalars:
        return [("none", ())]
    los = [_bounds_arrays(s, "lo") for s in contract.scalars]
    his = [_bounds_arrays(s, "hi") for s in contract.scalars]
    out = [("lo", tuple(lo.copy() for lo in los)),
           ("hi", tuple(hi.copy() for hi in his))]
    rng = np.random.default_rng(seed)
    for i in range(samples):
        tabs = tuple(
            lo + (rng.random(lo.shape) * (hi - lo + 1)).astype(np.int64)
                 .clip(0, hi - lo)
            for lo, hi in zip(los, his))
        out.append((f"rand{i}", tabs))
    return out


def _eval_map(op: Operand, gargs: List[np.ndarray],
              stabs: Tuple[np.ndarray, ...], n: int) -> np.ndarray:
    """Evaluate one index map over the whole grid -> (n, ndim) int64
    block indices.  Map components that are constant in the grid
    indices come back as scalars and are broadcast."""
    idx = op.index_map(*gargs, *stabs)
    if not isinstance(idx, tuple):
        idx = (idx,)
    if len(idx) != len(op.block):
        raise ValueError(
            f"{op.name}: index map returned {len(idx)} components for a "
            f"{len(op.block)}-d block {op.block}")
    cols = [np.broadcast_to(np.asarray(c, dtype=np.int64), (n,))
            for c in idx]
    return np.stack(cols, axis=-1)


def _check_bounds(contract: LaunchContract, op: Operand,
                  bidx: np.ndarray, gargs: List[np.ndarray],
                  sample: str) -> Optional[Violation]:
    """In-bounds check for one operand under one scalar sample."""
    shape = np.asarray(op.shape, dtype=np.int64)
    block = np.asarray(op.block, dtype=np.int64)
    off = bidx * block
    bad = (off < 0) | (off + block > shape)
    if not bad.any():
        return None
    pt = int(np.argwhere(bad.any(axis=1))[0][0])
    gp = tuple(int(a[pt]) for a in gargs)
    kind = "scalar-oob" if contract.scalars and sample != "lo" else "oob"
    return Violation(
        contract.family, op.name, kind,
        f"block index {tuple(bidx[pt])} (element offset "
        f"{tuple(off[pt])}, block {op.block}) escapes array "
        f"{op.shape} at grid point {gp} [scalar sample: {sample}]")


def _check_coverage(contract: LaunchContract, op: Operand,
                    bidx: np.ndarray, sample: str) -> List[Violation]:
    """Exactly-once coverage (+ contiguous-revisit) for one output."""
    out: List[Violation] = []
    shape = np.asarray(op.shape, dtype=np.int64)
    block = np.asarray(op.block, dtype=np.int64)
    if (shape % block).any():
        return [Violation(
            contract.family, op.name, "bad-spec",
            f"block {op.block} does not divide array {op.shape}; "
            f"coverage undefined")]
    uniq, inverse = np.unique(bidx, axis=0, return_inverse=True)
    expect = int(np.prod(shape // block))
    if len(uniq) != expect:
        missing = expect - len(uniq)
        out.append(Violation(
            contract.family, op.name, "coverage-gap",
            f"{len(uniq)} distinct blocks written, array has {expect} "
            f"({missing} never written) [scalar sample: {sample}]"))
    # revisits must be contiguous in grid order: the block stays
    # resident in VMEM across consecutive steps (accumulation); a gap
    # means it was flushed and later re-written -> double write.
    order = np.arange(len(inverse))
    for u in range(len(uniq)):
        pos = order[inverse == u]
        if len(pos) and int(pos[-1] - pos[0]) != len(pos) - 1:
            out.append(Violation(
                contract.family, op.name, "double-write",
                f"block {tuple(uniq[u])} written at non-contiguous grid "
                f"steps {pos[0]}..{pos[-1]} ({len(pos)} visits) "
                f"[scalar sample: {sample}]"))
            break
    return out


def _check_alias(contract: LaunchContract, i: int, o: int,
                 gargs: List[np.ndarray],
                 samples: List[Tuple[str, Tuple[np.ndarray, ...]]],
                 n: int) -> List[Violation]:
    """Aliased pair: identical array geometry, dtype, block and map."""
    inp = contract.inputs[i]
    outp = contract.outputs[o]
    name = f"{inp.name}~{outp.name}"
    out: List[Violation] = []
    if inp.shape != outp.shape or inp.dtype != outp.dtype:
        out.append(Violation(
            contract.family, name, "alias-mismatch",
            f"aliased operand {inp.shape}/{inp.dtype} vs output "
            f"{outp.shape}/{outp.dtype}"))
        return out
    if inp.block != outp.block:
        out.append(Violation(
            contract.family, name, "alias-mismatch",
            f"aliased block shapes differ: {inp.block} vs {outp.block}"))
        return out
    for sample, stabs in samples:
        bi = _eval_map(inp, gargs, stabs, n)
        bo = _eval_map(outp, gargs, stabs, n)
        if not np.array_equal(bi, bo):
            pt = int(np.argwhere((bi != bo).any(axis=1))[0][0])
            out.append(Violation(
                contract.family, name, "alias-mismatch",
                f"aliased index maps disagree at flat grid step {pt}: "
                f"read {tuple(bi[pt])} vs write {tuple(bo[pt])} "
                f"[scalar sample: {sample}]"))
            return out
    return out


def check_contract(contract: LaunchContract, *,
                   samples: int = DEFAULT_SAMPLES,
                   seed: int = 0) -> List[Violation]:
    """All violations in one contract (empty list == clean)."""
    violations: List[Violation] = []
    gargs = _grid_arrays(contract.grid)
    n = int(np.prod(contract.grid)) if contract.grid else 1
    stab_samples = _scalar_samples(contract, samples, seed)
    aliased_outputs = {o for _, o in contract.aliases}

    for s in contract.scalars:
        lo = _bounds_arrays(s, "lo")
        hi = _bounds_arrays(s, "hi")
        if (lo > hi).any() or (lo < 0).any():
            violations.append(Violation(
                contract.family, s.name, "bad-spec",
                f"scalar domain lo={s.lo} hi={s.hi} is empty or "
                f"negative"))

    for kind, ops in (("in", contract.inputs), ("out", contract.outputs)):
        for j, op in enumerate(ops):
            per_op: List[Violation] = []
            for sample, stabs in stab_samples:
                try:
                    bidx = _eval_map(op, gargs, stabs, n)
                except Exception as e:  # map itself is malformed
                    per_op.append(Violation(
                        contract.family, op.name, "bad-spec",
                        f"index map failed: {type(e).__name__}: {e}"))
                    break
                v = _check_bounds(contract, op, bidx, gargs, sample)
                if v is not None:
                    per_op.append(v)
                    break     # one bounds report per operand is enough
                if kind == "out" and j not in aliased_outputs:
                    cov = _check_coverage(contract, op, bidx, sample)
                    if cov:
                        per_op.extend(cov)
                        break
            violations.extend(per_op)

    for i, o in contract.aliases:
        violations.extend(
            _check_alias(contract, i, o, gargs, stab_samples, n))
    return violations


def check_contracts(contracts, *, samples: int = DEFAULT_SAMPLES,
                    seed: int = 0) -> List[Violation]:
    out: List[Violation] = []
    for c in contracts:
        out.extend(check_contract(c, samples=samples, seed=seed))
    return out


def summarize(violations: List[Violation]) -> Dict[str, Any]:
    by_kind: Dict[str, int] = {}
    for v in violations:
        by_kind[v.kind] = by_kind.get(v.kind, 0) + 1
    return {"total": len(violations), "by_kind": by_kind}
