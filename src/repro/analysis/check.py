"""CI gate: statically verify launch contracts, SP ownership, and the
paged-pool state machine.

``python -m repro.analysis.check`` (no flags, or ``--kernels``) traces
every Pallas entry point -- band/sub forward+backward over the FULL
``tuning.py`` candidate space (every legal ``tq`` per mode x shape
bucket), and every decode family (dense, SP-partial, paged,
quantized-paged) over representative pool geometries -- under
``jax.eval_shape`` (nothing compiles or runs), then checks each
captured :class:`~repro.analysis.contracts.LaunchContract`: in-bounds
blocks at every grid point, exactly-once output coverage, alias
agreement, and scalar-prefetch domains.

``--dist`` runs :mod:`repro.analysis.dist` (cross-shard ownership,
halo protocol, comm volume over mesh sizes 1/2/4/8, zero devices);
``--pool`` runs :mod:`repro.analysis.pool_model` (bounded exhaustive
model check of the real :class:`~repro.serve.paged_cache.PagePool`).
``--family SUBSTR`` filters what gets checked/reported; ``--json
[PATH]`` emits a machine-readable report (schema pinned in
``tests/test_analysis.py``).  Exit code 1 on any violation.  Wired
into ``scripts/ci.sh`` with a 60 s budget per invocation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, List, Tuple

from . import checker
from .contracts import LaunchContract, capture

BAND_LS = (64, 1024)
SUB_CASES = ((2, 256), (8, 1024))   # (ratio, L): wide AND deep layouts


def _trace(fn, *args) -> List[LaunchContract]:
    import jax
    with capture() as got:
        jax.eval_shape(fn, *args)
    return got


def band_contracts(policy, *, nr: int, d: int):
    """(label, contract) for every band/sub candidate config."""
    import jax

    from repro.kernels import h1d_block, h1d_block_bwd

    f32 = "float32"
    out: List[Tuple[str, LaunchContract]] = []
    cases = [(m, 1, L) for m in h1d_block.MODES for L in BAND_LS]
    cases += [("sub", r, L) for r, L in SUB_CASES]
    for mode, ratio, L in cases:
        fam_f = "sub_fwd" if mode == "sub" else "band_fwd"
        fam_b = "sub_bwd" if mode == "sub" else "band_bwd"
        Lk = L // ratio if mode == "sub" else L
        B, G = 1, 2
        q = jax.ShapeDtypeStruct((B, G, L, d), f32)
        k = jax.ShapeDtypeStruct((B, Lk, d), f32)
        v = jax.ShapeDtypeStruct((B, Lk, d), f32)
        w = jax.ShapeDtypeStruct((B, Lk), f32)
        y = jax.ShapeDtypeStruct((B, G, L, d), f32)
        r_ = jax.ShapeDtypeStruct((B, G, L), f32)
        for cand in policy.candidates(fam_f, L=L, nr=nr, mode=mode,
                                      ratio=ratio):
            tq = cand["tq"]
            label = f"{mode} r{ratio} L{L} tq{tq}"
            for c in _trace(
                    lambda *a: h1d_block.band_attention_fwd(
                        *a, nr=nr, mode=mode, tq=tq, ratio=ratio),
                    q, k, v, w):
                out.append((f"{fam_f} {label}", c))
            for c in _trace(
                    lambda *a: h1d_block_bwd.band_attention_bwd(
                        *a, nr=nr, mode=mode, tq=tq, ratio=ratio),
                    q, k, v, w, y, r_, r_, y, r_, r_):
                out.append((f"{fam_b} {label}", c))
    return out


def decode_contracts(*, nr: int, d: int):
    """(label, contract) for every decode family at two geometries."""
    import jax.numpy as jnp

    from repro.core import h1d_decode as hd
    from repro.kernels import h1d_decode_kernel as dk

    out: List[Tuple[str, LaunchContract]] = []
    for Lmax, R, G in ((16 * nr, 3, 2), (64 * nr, 4, 1)):
        label = f"nr{nr} Lmax{Lmax} R{R}"
        cache = hd.init_cache(R, Lmax, d, d, nr)
        q = jnp.zeros((R, G, d))
        t = jnp.zeros((R,), jnp.int32)
        kn = jnp.zeros((R, d))
        vn = jnp.zeros((R, d))
        nbands = 2 + len(cache.ck)
        nlev = 1 + len(cache.ck)
        bidx = jnp.zeros((R, nbands), jnp.int32)
        ownb = jnp.ones((R, nbands), jnp.int32)
        own1 = jnp.ones((R,), jnp.int32)
        utab = jnp.zeros((R, nlev), jnp.int32)
        # per-level page pools deliberately NOT equal-sized: the checker
        # must see each level's own page-count domain
        pages = [8 + 2 * nbands - 2 * i for i in range(nlev)]
        pool = hd.init_paged_pool(pages, nr, d, d)
        qpool = hd.init_quant_paged_pool(
            pages, nr, d, d,
            quant=tuple(i % 2 == 0 for i in range(nlev)))
        for fam, fn, args in (
            ("decode_attend",
             lambda c, q, t: dk.decode_attend_fused(c, q, t, nr=nr),
             (cache, q, t)),
            ("decode_update",
             lambda c, k, v, t: dk.update_cache_fused(c, k, v, t),
             (cache, kn, vn, t)),
            ("decode_attend_partial",
             lambda c, q, t, b, o: dk.decode_attend_partial(
                 c, q, t, b, o, nr=nr),
             (cache, q, t, bidx, ownb)),
            ("decode_update_partial",
             lambda c, k, v, t, o: dk.update_cache_partial(c, k, v, t, o),
             (cache, kn, vn, t, own1)),
            ("decode_attend_paged",
             lambda p, q, t, b: dk.decode_attend_paged(p, q, t, b, nr=nr),
             (pool, q, t, bidx)),
            ("decode_update_paged",
             lambda p, k, v, t, u: dk.update_cache_paged(p, k, v, t, u),
             (pool, kn, vn, t, utab)),
            ("decode_attend_paged_quant",
             lambda p, q, t, b: dk.decode_attend_paged_quant(
                 p, q, t, b, nr=nr),
             (qpool, q, t, bidx)),
            ("decode_update_paged_quant",
             lambda p, k, v, t, u: dk.update_cache_paged_quant(
                 p, k, v, t, u),
             (qpool, kn, vn, t, utab)),
        ):
            for c in _trace(fn, *args):
                out.append((f"{fam} {label}", c))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nr", type=int, default=16,
                    help="paper block size for the band sweep")
    ap.add_argument("--d", type=int, default=16,
                    help="head dim for the traced shapes (candidate "
                         "spaces do not depend on it)")
    ap.add_argument("--samples", type=int, default=checker.DEFAULT_SAMPLES)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernels", action="store_true",
                    help="check kernel launch contracts (the default "
                         "when no section flag is given)")
    ap.add_argument("--dist", action="store_true",
                    help="check SP cross-shard ownership/halo/comm")
    ap.add_argument("--pool", action="store_true",
                    help="model-check the paged-pool state machine")
    ap.add_argument("--pool-states", type=int, default=12000,
                    help="distinct-state budget for --pool")
    ap.add_argument("--family", default=None, metavar="SUBSTR",
                    help="only check/report contracts and violations "
                         "whose family or label contains SUBSTR")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="write a JSON report to PATH ('-' = stdout)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    sections = [s for s, on in (("kernels", args.kernels),
                                ("dist", args.dist),
                                ("pool", args.pool)) if on] or ["kernels"]

    t0 = time.time()
    fams: Dict[str, int] = {}
    violations: List[Tuple[str, checker.Violation]] = []
    dist_stats = pool_stats = None
    n_contracts = 0
    t_trace = 0.0

    if "kernels" in sections:
        from repro.kernels import tuning
        policy = tuning.KernelPolicy()
        labeled = band_contracts(policy, nr=args.nr, d=args.d)
        labeled += decode_contracts(nr=4, d=args.d)
        labeled += decode_contracts(nr=args.nr, d=args.d)
        if args.family:
            labeled = [(lb, c) for lb, c in labeled
                       if args.family in lb or args.family in c.family]
        t_trace = time.time() - t0
        n_contracts = len(labeled)
        for label, contract in labeled:
            fams[contract.family] = fams.get(contract.family, 0) + 1
            for v in checker.check_contract(contract,
                                            samples=args.samples,
                                            seed=args.seed):
                violations.append((label, v))
            if args.verbose:
                print(f"  {label}: {contract.describe()}")

    if "dist" in sections:
        from . import dist
        dist_stats, vs = dist.run_dist()
        if args.family:
            vs = [v for v in vs if args.family in v.family]
        violations.extend((v.family, v) for v in vs)

    if "pool" in sections:
        from . import pool_model
        pool_stats, vs = pool_model.run_pool(max_states=args.pool_states)
        if args.family:
            vs = [v for v in vs if args.family in v.family]
        violations.extend((v.family, v) for v in vs)

    total = time.time() - t0
    if "kernels" in sections:
        print(f"checked {n_contracts} contracts across {len(fams)} "
              f"families in {total:.1f}s (trace {t_trace:.1f}s):")
        for fam in sorted(fams):
            print(f"  {fam}: {fams[fam]} contracts")
    if dist_stats is not None:
        print(f"dist: {dist_stats['configs']} configs, "
              f"{dist_stats['checks']} ownership/halo/comm checks")
    if pool_stats is not None:
        cov = pool_stats["coverage"]
        print(f"pool: {pool_stats['states']} states, "
              f"{pool_stats['transitions']} transitions "
              f"(cow {cov.get('cow_copies', 0)}, "
              f"evict {cov.get('evictions', 0)}, "
              f"restore {cov.get('restore', 0)})")

    if args.json is not None:
        report = {
            "sections": sections,
            "contracts": n_contracts,
            "families": fams,
            "violations": [dict(label=label,
                                **dataclasses.asdict(v))
                           for label, v in violations],
            "dist": dist_stats,
            "pool": pool_stats,
            "ok": not violations,
            "runtime_s": round(total, 3),
        }
        if args.json == "-":
            json.dump(report, sys.stdout, indent=2)
            print()
        else:
            with open(args.json, "w") as f:
                json.dump(report, f, indent=2)

    if violations:
        print(f"FAILED: {len(violations)} violations")
        for label, v in violations:
            print(f"  {label}: {v}")
        return 1
    print("OK: no violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
