"""Per-launch VMEM footprint estimates from launch contracts.

The model: a Pallas TPU launch keeps every operand's *block* resident
in VMEM, double-buffered (the pipeline prefetches grid step i+1 while
computing step i), so the footprint is

    sum over operands of  prod(block_shape) * itemsize * 2

against the ~16 MiB/core VMEM budget (a fraction is reserved for
scalars, semaphores and spills).  Scalar-prefetch tables live in SMEM
and are excluded.

The estimates are computed from TRACED contracts -- the band entry
points are run under ``jax.eval_shape`` inside ``contracts.capture()``
for the exact candidate being considered -- not from hand-maintained
closed forms, so the byte counts cannot drift from the kernels.
``kernels/tuning.py`` calls :func:`band_launch_bytes` during candidate
enumeration to reject over-budget configs statically (logged as
``rejected:vmem``) before any measurement runs.
"""
from __future__ import annotations

import math
import os
import warnings
from typing import Optional

import numpy as np

from .contracts import LaunchContract, capture

#: per-core VMEM, bytes (TPU v4/v5 class; the budget below leaves room)
VMEM_BYTES = 16 * 1024 * 1024
#: fraction of VMEM the pipelined operand blocks may claim
DEFAULT_FRACTION = 0.75
#: pipeline double-buffering factor on every operand block
DOUBLE_BUFFER = 2


def default_budget() -> int:
    """The static VMEM budget in bytes ($REPRO_VMEM_BUDGET overrides).

    A malformed override warns and falls back to the default -- an
    autotune run deep inside a training script must not die on a typo'd
    environment variable."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env:
        try:
            return int(env)
        except ValueError:
            warnings.warn(
                f"REPRO_VMEM_BUDGET={env!r} is not an integer; using "
                f"the default budget",
                RuntimeWarning, stacklevel=2)
    return int(VMEM_BYTES * DEFAULT_FRACTION)


def contract_vmem_bytes(contract: LaunchContract) -> int:
    """Estimated VMEM bytes for one launch (double-buffered blocks)."""
    total = 0
    for op in (*contract.inputs, *contract.outputs):
        total += (math.prod(op.block) * np.dtype(op.dtype).itemsize
                  * DOUBLE_BUFFER)
    return int(total)


def band_launch_bytes(family: str, *, L: int, nr: int, mode: str,
                      tq: int, ratio: int = 1, d: int = 64,
                      dv: Optional[int] = None, B: int = 1, G: int = 1,
                      dtype: str = "float32") -> int:
    """Max per-launch VMEM footprint of one band candidate config.

    Traces the real entry point(s) for ``(family, shape, tq)`` under
    ``eval_shape`` (nothing is compiled or executed) and sizes the
    captured contracts; backward families cover both the dQ and dKVW
    launches and return the larger."""
    import jax

    from repro.kernels import h1d_block, h1d_block_bwd

    dv = d if dv is None else dv
    Lk = L // ratio if mode == h1d_block.SUB_MODE else L
    f32 = "float32"
    q = jax.ShapeDtypeStruct((B, G, L, d), dtype)
    k = jax.ShapeDtypeStruct((B, Lk, d), dtype)
    v = jax.ShapeDtypeStruct((B, Lk, dv), dtype)
    w = jax.ShapeDtypeStruct((B, Lk), dtype)
    with capture() as got:
        if family.endswith("bwd"):
            y = jax.ShapeDtypeStruct((B, G, L, dv), f32)
            r = jax.ShapeDtypeStruct((B, G, L), f32)
            jax.eval_shape(
                lambda *a: h1d_block_bwd.band_attention_bwd(
                    *a, nr=nr, mode=mode, tq=tq, ratio=ratio),
                q, k, v, w, y, r, r, y, r, r)
        else:
            jax.eval_shape(
                lambda *a: h1d_block.band_attention_fwd(
                    *a, nr=nr, mode=mode, tq=tq, ratio=ratio),
                q, k, v, w)
    if not got:
        raise RuntimeError(f"band_launch_bytes: no contract captured for "
                           f"{family} L={L} nr={nr} tq={tq}")
    return max(contract_vmem_bytes(c) for c in got)
