"""Declarative launch contracts for every Pallas kernel launch.

Every ``pl.pallas_call`` in the kernel modules goes through one shared
:func:`launch` builder.  Besides dispatching the actual call (plain
``grid=`` launch, or a ``PrefetchScalarGridSpec`` when scalar-prefetch
tables are present), ``launch`` records a :class:`LaunchContract` -- a
frozen, declarative description of exactly what was launched:

* the grid and every operand's array shape/dtype, block shape and
  BlockSpec index map (the live lambdas, not copies);
* the scalar-prefetch tables with their *bound domains* (the legal
  value range of every table entry, declared from the call site's
  geometry -- e.g. a page index is bounded by the pool's page count);
* ``input_output_aliases`` normalized to *operand* indices, so the
  hand-maintained "+3"/"+4" call-arg offsets live in exactly one place
  (here) instead of at every aliased call site.

The static checker (:mod:`repro.analysis.checker`) consumes these
contracts: because they are recorded by the same code path that issues
the launch, the checker verifies what the runtime actually runs -- the
contract cannot drift from the call (``tests/test_analysis.py`` pins
this with a ``pallas_call``-shim agreement test).

Capture model: contracts are recorded at *trace* time.  ``jax.eval_shape``
of a kernel wrapper inside :func:`capture` yields the wrapper's
contracts without compiling or executing anything -- that is how both
the checker CLI and the VMEM estimator obtain contracts for arbitrary
shapes.  A bounded deque of recent contracts (:func:`recent`) is also
kept for interactive inspection.

This module imports only jax/pallas (never the kernel modules), so the
kernels can import it without cycles.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class Operand:
    """One (non-scalar) kernel operand: array geometry + its BlockSpec."""
    name: str
    shape: Tuple[int, ...]
    dtype: str
    block: Tuple[int, ...]
    index_map: Callable[..., Tuple[Any, ...]]


@dataclasses.dataclass(frozen=True)
class ScalarSpec:
    """One scalar-prefetch table and the declared domain of its values.

    ``lo``/``hi`` are inclusive bounds, either ints or integer arrays
    broadcastable to ``shape`` (e.g. a per-column page-count bound for a
    ``(R, nbands)`` page table)."""
    name: str
    shape: Tuple[int, ...]
    dtype: str
    lo: Any
    hi: Any


@dataclasses.dataclass(frozen=True)
class LaunchContract:
    """Everything the static checker needs about one ``pallas_call``.

    ``aliases`` maps input *operand* index (position in ``inputs``, not
    counting scalar-prefetch args) to output index.  Grid iteration is
    row-major with the LAST axis fastest (the Pallas TPU order) -- the
    checker's revisit-contiguity rule depends on it.
    """
    family: str
    grid: Tuple[int, ...]
    scalars: Tuple[ScalarSpec, ...]
    inputs: Tuple[Operand, ...]
    outputs: Tuple[Operand, ...]
    aliases: Tuple[Tuple[int, int], ...]
    meta: Dict[str, Any]

    @property
    def alias_map(self) -> Dict[int, int]:
        return dict(self.aliases)

    def describe(self) -> str:
        ins = ", ".join(f"{o.name}{list(o.block)}" for o in self.inputs)
        outs = ", ".join(f"{o.name}{list(o.block)}" for o in self.outputs)
        return (f"{self.family} grid={self.grid} "
                f"scalars={[s.name for s in self.scalars]} "
                f"in=[{ins}] out=[{outs}] aliases={dict(self.aliases)}")


# -- recording --------------------------------------------------------------

_RECENT: collections.deque = collections.deque(maxlen=256)
_CAPTURES: List[List[LaunchContract]] = []
_LAUNCH_HOOKS: List[Callable[[LaunchContract], None]] = []


def _record(contract: LaunchContract) -> None:
    _RECENT.append(contract)
    for buf in _CAPTURES:
        buf.append(contract)
    for hook in _LAUNCH_HOOKS:
        hook(contract)


def add_launch_hook(hook: Callable[[LaunchContract], None]) -> None:
    """Register a callback fired on every recorded contract (i.e. once
    per *traced* ``pallas_call``, not per device execution).  This is
    how the telemetry layer (:mod:`repro.obs`) observes launches
    without this module importing it; the disabled path costs an
    iteration over an empty list."""
    if hook not in _LAUNCH_HOOKS:
        _LAUNCH_HOOKS.append(hook)


def remove_launch_hook(hook: Callable[[LaunchContract], None]) -> None:
    if hook in _LAUNCH_HOOKS:
        _LAUNCH_HOOKS.remove(hook)


@contextlib.contextmanager
def capture():
    """Collect every contract recorded while the context is active.

    ``jax.eval_shape`` of a kernel wrapper inside this context yields
    the wrapper's contracts without running (or compiling) anything."""
    buf: List[LaunchContract] = []
    _CAPTURES.append(buf)
    try:
        yield buf
    finally:
        _CAPTURES.remove(buf)


def recent(family: Optional[str] = None) -> List[LaunchContract]:
    """Recently recorded contracts (newest last), optionally filtered."""
    return [c for c in _RECENT if family is None or c.family == family]


# -- the shared launch builder ----------------------------------------------

def _as_tuple(x) -> tuple:
    return tuple(x) if isinstance(x, (list, tuple)) else (x,)


def _operands(names, arrays, specs, kind: str) -> Tuple[Operand, ...]:
    if len(arrays) != len(specs):
        raise ValueError(
            f"launch: {len(arrays)} {kind} operands vs {len(specs)} specs")
    if names is None:
        names = tuple(f"{kind}{i}" for i in range(len(arrays)))
    if len(names) != len(arrays):
        raise ValueError(
            f"launch: {len(names)} {kind} names vs {len(arrays)} operands")
    return tuple(
        Operand(name=str(nm), shape=tuple(a.shape), dtype=str(a.dtype),
                block=tuple(sp.block_shape), index_map=sp.index_map)
        for nm, a, sp in zip(names, arrays, specs))


def launch(kernel, *, family: str, grid: Tuple[int, ...],
           in_specs: Sequence[pl.BlockSpec], out_specs, out_shape,
           operands: Sequence[Any], scalars: Sequence[Any] = (),
           scalar_bounds: Sequence[Tuple[Any, Any]] = (),
           aliases: Optional[Dict[int, int]] = None,
           interpret: bool = False,
           in_names: Optional[Sequence[str]] = None,
           out_names: Optional[Sequence[str]] = None,
           scalar_names: Optional[Sequence[str]] = None,
           meta: Optional[Dict[str, Any]] = None):
    """Issue one ``pallas_call`` and record its :class:`LaunchContract`.

    ``operands`` are the non-scalar inputs (aligned with ``in_specs``);
    ``scalars`` are scalar-prefetch tables, each with an inclusive
    ``(lo, hi)`` domain in ``scalar_bounds``.  ``aliases`` maps operand
    index -> output index; the translation to Pallas call-arg indices
    (which count the scalar args first) happens here, once.
    """
    out_specs_t = _as_tuple(out_specs)
    out_shape_t = _as_tuple(out_shape)
    if len(out_specs_t) != len(out_shape_t):
        raise ValueError(
            f"launch: {len(out_specs_t)} out_specs vs "
            f"{len(out_shape_t)} out_shapes")
    if len(scalar_bounds) != len(scalars):
        raise ValueError(
            f"launch: {len(scalars)} scalars need {len(scalars)} bounds, "
            f"got {len(scalar_bounds)}")
    if scalar_names is None:
        scalar_names = tuple(f"s{i}" for i in range(len(scalars)))

    alias_items = tuple(sorted((aliases or {}).items()))
    for i, o in alias_items:
        if not (0 <= i < len(operands) and 0 <= o < len(out_shape_t)):
            raise ValueError(f"launch: alias {i}->{o} out of range "
                             f"({len(operands)} operands, "
                             f"{len(out_shape_t)} outputs)")

    contract = LaunchContract(
        family=family, grid=tuple(int(g) for g in grid),
        scalars=tuple(
            ScalarSpec(name=str(nm), shape=tuple(s.shape),
                       dtype=str(s.dtype), lo=lo, hi=hi)
            for nm, s, (lo, hi) in zip(scalar_names, scalars,
                                       scalar_bounds)),
        inputs=_operands(in_names, operands, in_specs, "in"),
        outputs=_operands(out_names, out_shape_t, out_specs_t, "out"),
        aliases=alias_items,
        meta=dict(meta or {}))
    _record(contract)

    # call args are (*scalars, *operands): Pallas alias keys count the
    # scalar-prefetch args, so shift the operand index by len(scalars).
    ns = len(scalars)
    call_aliases = {ns + i: o for i, o in alias_items}
    if ns:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=ns, grid=tuple(grid),
            in_specs=list(in_specs), out_specs=out_specs)
        return pl.pallas_call(
            kernel, grid_spec=grid_spec, out_shape=out_shape,
            input_output_aliases=call_aliases, interpret=interpret,
        )(*scalars, *operands)
    return pl.pallas_call(
        kernel, grid=tuple(grid), in_specs=list(in_specs),
        out_specs=out_specs, out_shape=out_shape,
        input_output_aliases=call_aliases, interpret=interpret,
    )(*operands)
