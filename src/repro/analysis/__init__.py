"""Static analysis over kernel launches (DESIGN.md section 11).

* :mod:`repro.analysis.contracts` -- the :class:`LaunchContract` schema
  and the shared :func:`launch` builder every ``pallas_call`` site in
  ``repro.kernels`` goes through.
* :mod:`repro.analysis.checker` -- abstract evaluation of the index
  maps over the full grid: in-bounds blocks, exactly-once output
  coverage, alias agreement, scalar-prefetch domains.
* :mod:`repro.analysis.vmem` -- per-launch VMEM footprint estimates
  (consumed by ``kernels/tuning.py`` candidate enumeration).
* ``python -m repro.analysis.check`` -- the CI gate: every kernel
  family x the full tuning candidate spaces.

Only ``contracts`` is imported eagerly (the kernels import it);
checker/vmem import the kernel modules lazily.
"""
from .contracts import (LaunchContract, Operand, ScalarSpec, capture,
                        launch, recent)

__all__ = ["LaunchContract", "Operand", "ScalarSpec", "capture",
           "launch", "recent"]
