"""Static analysis over kernel launches (DESIGN.md section 11).

* :mod:`repro.analysis.contracts` -- the :class:`LaunchContract` schema
  and the shared :func:`launch` builder every ``pallas_call`` site in
  ``repro.kernels`` goes through.
* :mod:`repro.analysis.checker` -- abstract evaluation of the index
  maps over the full grid: in-bounds blocks, exactly-once output
  coverage, alias agreement, scalar-prefetch domains.
* :mod:`repro.analysis.vmem` -- per-launch VMEM footprint estimates
  (consumed by ``kernels/tuning.py`` candidate enumeration).
* :mod:`repro.analysis.dist` -- cross-shard ownership / halo-protocol
  / comm-volume verification of the SP layer over mesh sizes 1..8,
  with zero devices (DESIGN.md section 12).
* :mod:`repro.analysis.pool_model` -- bounded exhaustive model checker
  for the serving layer's :class:`~repro.serve.paged_cache.PagePool`
  (refcounts, COW, eviction, registry liveness), with replayable
  minimized counterexamples.
* ``python -m repro.analysis.check`` -- the CI gate: every kernel
  family x the full tuning candidate spaces, plus ``--dist``/``--pool``
  for the distributed and serving checks and ``--json`` reports.

Only ``contracts`` is imported eagerly (the kernels import it);
checker/vmem import the kernel modules lazily.
"""
from .contracts import (LaunchContract, Operand, ScalarSpec, capture,
                        launch, recent)

__all__ = ["LaunchContract", "Operand", "ScalarSpec", "capture",
           "launch", "recent"]
