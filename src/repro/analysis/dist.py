"""Distributed-ownership checker for the sequence-parallel layer.

Abstract evaluation of ``parallel/sp_attention.py``'s cross-shard
dispatch over mesh sizes {1, 2, 4, 8} with ZERO devices: the ownership
/ translation rules (``_band_geometry``, ``sp_update_owner``,
``sp_update_local_t``, ``sp_n_shallow``) are plain eager functions of
the shard index, and the partial kernels' launch contracts are captured
under ``jax.eval_shape`` -- so every rule the shard_map bodies rely on
can be checked exhaustively on the host, per global position, without a
mesh (DESIGN.md section 12).

Checks, per (mesh size d, geometry):

* **decode attend ownership** -- every (position, band) pair is owned by
  exactly ONE shard (``ownership-gap`` / ``ownership-overlap``), and on
  the owning shard the partial contract's index map reconstructs the
  SAME global block the single-chip ``decode_attend_fused`` contract
  reads (``halo-mismatch``); non-owner fetches stay inside the local
  slab and the real prefetch tables stay inside the contracts' declared
  scalar domains.
* **decode update ownership** -- ``sp_update_owner`` covers every
  ``t`` in ``[0, Lmax]`` exactly once including the last-shard
  ``t == Lmax`` rule; the owner's local position keeps the sibling
  parity bits, and the partial/deep update contracts' pair maps agree
  with the single-chip ``decode_update`` maps level by level.
* **halo protocol** -- for every banded mode/level the set of
  out-of-shard key blocks the global ``band_mask`` makes a shard's
  queries attend is exactly covered by the one ``nr``-row block per
  direction the halo exchange delivers (``halo-mismatch``).
* **transition threshold + comm volume** -- ``sp_n_shallow`` matches
  the ``L >> l >= d * nr`` sharding rule (and the decode path's
  ``sp_sharded_levels``), the packed halo buffer built by the REAL
  ``sp_halo_pack`` matches the pinned DESIGN.md section 7 formula, and
  the gathered transition-level KV stays under the ``d * nr / 2``-row
  bound (``comm-mismatch``).

Every rule is injectable (``band_geometry=``, ``update_owner=``, ...)
so the seeded-mutation suite in ``tests/test_dist.py`` can prove each
violation kind is actually caught.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .checker import Violation, _eval_map
from .contracts import capture

#: data-axis sizes the checks sweep (1 == the degenerate single chip)
MESH_SIZES = (1, 2, 4, 8)
#: (nr, Lmax) decode cache geometries
DECODE_GEOMS = ((4, 64), (4, 128))
#: (nr, L) training/prefill geometries for the halo + comm checks
BAND_GEOMS = ((4, 64), (4, 128))

DIST_KINDS = ("ownership-gap", "ownership-overlap", "halo-mismatch",
              "comm-mismatch")

#: head dim for traced shapes (the index maps never depend on it)
_D = 8


def _np(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int64)


# ---------------------------------------------------------------------------
# decode: attend-band + update ownership
# ---------------------------------------------------------------------------

def check_decode(d: int, nr: int, Lmax: int, *,
                 band_geometry: Optional[Callable] = None,
                 update_owner: Optional[Callable] = None,
                 update_local_t: Optional[Callable] = None,
                 update_owned: Optional[Callable] = None,
                 ) -> Tuple[int, List[Violation]]:
    """All decode-path ownership checks for one ``(d, nr, Lmax)``.

    Returns ``(checks_run, violations)``.  The ``*_owner``/``*_owned``/
    ``band_geometry`` hooks default to the REAL ``sp_attention`` rules;
    tests inject broken ones to validate the checker itself
    (``update_owned(t, s, Lloc, d) -> bool array`` overrides the
    per-shard ownership bit derived from ``update_owner``)."""
    import jax
    import jax.numpy as jnp
    from repro.core import h1d_decode as hd
    from repro.core import hierarchy as hc
    from repro.kernels import h1d_decode_kernel as dk
    from repro.parallel import sp_attention as sp

    band_geometry = band_geometry or sp._band_geometry
    update_owner = update_owner or sp.sp_update_owner
    update_local_t = update_local_t or sp.sp_update_local_t

    out: List[Violation] = []
    checks = 0
    fam = f"sp_decode d{d} nr{nr} L{Lmax}"
    Lloc = Lmax // d
    M = hc.num_levels(Lmax, nr)
    nsh = sp.sp_sharded_levels(Lmax, nr, d)
    if nsh < 1:
        return 0, []          # sp_cache_specs refuses this config loudly
    nsh_u = min(nsh, M)       # nsh > M just means ALL levels shard
    nbands = M + 1
    R = Lmax                  # one grid row per global position
    t = np.arange(R, dtype=np.int64)
    tj = jnp.asarray(t, jnp.int32)
    gargs = [np.arange(R, dtype=np.int64)]

    # real per-shard geometry tables (the values sp_decode_attend
    # scalar-prefetches), computed eagerly with a concrete shard index
    geo = []
    for s in range(d):
        bidx, own = band_geometry(tj, jnp.asarray(s, jnp.int32), nr,
                                  Lmax, d, nsh, M - 1)
        geo.append((_np(bidx), _np(own)))

    # -- (1) exactly-once attend-band ownership across shards ----------
    own_total = np.sum([o for _, o in geo], axis=0)
    for band in range(nbands):
        checks += 1
        col = own_total[:, band]
        gaps = np.nonzero(col == 0)[0]
        if gaps.size:
            out.append(Violation(
                fam, f"band{band}", "ownership-gap",
                f"{gaps.size} global positions owned by NO shard "
                f"(first: t={int(gaps[0])})"))
        over = np.nonzero(col > 1)[0]
        if over.size:
            out.append(Violation(
                fam, f"band{band}", "ownership-overlap",
                f"{over.size} global positions owned by "
                f"{int(col[over[0]])} shards (first: t={int(over[0])})"))

    # -- (2) partial-vs-dense attend index-map agreement ---------------
    cache = hd.init_cache(R, Lmax, _D, _D, nr)
    q = jnp.zeros((R, 1, _D))
    with capture() as got:
        jax.eval_shape(
            lambda c, qq, tt: dk.decode_attend_fused(c, qq, tt, nr=nr),
            cache, q, tj)
    dense_at = got[0]
    dense_blk = {b: _eval_map(dense_at.inputs[1 + b], gargs, (t,), R)[:, 1]
                 for b in range(nbands)}

    slab = type(cache)(
        k=jnp.zeros((R, Lloc, _D)), v=jnp.zeros((R, Lloc, _D)),
        ck=tuple(jnp.zeros((R, (Lmax >> l) // (d if l < nsh else 1), _D))
                 for l in range(1, M)),
        cv=tuple(jnp.zeros((R, (Lmax >> l) // (d if l < nsh else 1), _D))
                 for l in range(1, M)))
    with capture() as got:
        jax.eval_shape(
            lambda c, qq, tt, bb, oo: dk.decode_attend_partial(
                c, qq, tt, bb, oo, nr=nr, t_hi=Lmax - 1),
            slab, q, tj, jnp.zeros((R, nbands), jnp.int32),
            jnp.zeros((R, nbands), jnp.int32))
    part_at = got[0]
    band_lvl = part_at.meta["band_levels"]

    for s, (bidx_s, own_s) in enumerate(geo):
        stabs = (t, bidx_s, own_s)
        # the REAL prefetch tables must fit the declared scalar domains
        for spec, tab in zip(part_at.scalars, stabs):
            checks += 1
            lo = np.broadcast_to(np.asarray(spec.lo, np.int64), tab.shape)
            hi = np.broadcast_to(np.asarray(spec.hi, np.int64), tab.shape)
            bad = np.nonzero((tab < lo) | (tab > hi))
            if bad[0].size:
                out.append(Violation(
                    fam, spec.name, "halo-mismatch",
                    f"shard {s}: real {spec.name} table value "
                    f"{int(tab[tuple(i[0] for i in bad)])} escapes the "
                    f"contract's declared domain at index "
                    f"{tuple(int(i[0]) for i in bad)}"))
        for b in range(nbands):
            lam = band_lvl[b]
            nbl = (Lmax >> lam) // nr
            nbl_loc = nbl // d if lam < nsh else nbl
            loc = _eval_map(part_at.inputs[1 + b], gargs, stabs, R)[:, 1]
            checks += 1
            if not np.array_equal(loc, bidx_s[:, b]):
                out.append(Violation(
                    fam, f"band{b}", "halo-mismatch",
                    f"shard {s}: partial contract map does not read the "
                    f"prefetched band table"))
                continue
            oob = np.nonzero((loc < 0) | (loc >= nbl_loc))[0]
            if oob.size:
                out.append(Violation(
                    fam, f"band{b}", "halo-mismatch",
                    f"shard {s}: local block {int(loc[oob[0]])} escapes "
                    f"the {nbl_loc}-block slab at t={int(oob[0])} "
                    f"(non-owners must fetch clamped in-slab blocks)"))
                continue
            ownm = own_s[:, b] > 0
            glob = loc + (s * nbl_loc if lam < nsh else 0)
            mism = np.nonzero(ownm & (glob != dense_blk[b]))[0]
            if mism.size:
                tt = int(mism[0])
                out.append(Violation(
                    fam, f"band{b}", "halo-mismatch",
                    f"shard {s} owns t={tt} but reads global block "
                    f"{int(glob[tt])}; the single-chip kernel reads "
                    f"{int(dense_blk[b][tt])}"))

    # -- (3) update ownership: exactly-once over [0, Lmax] -------------
    tu = np.arange(Lmax + 1, dtype=np.int64)
    tuj = jnp.asarray(tu, jnp.int32)
    if update_owned is None:
        owners_all = _np(update_owner(tuj, Lloc, d))
        owned_bits = np.stack([(owners_all == s).astype(np.int64)
                               for s in range(d)])
    else:
        owned_bits = np.stack([_np(update_owned(tuj, s, Lloc, d))
                               for s in range(d)])
    checks += 1
    tot = owned_bits.sum(axis=0)
    gaps = np.nonzero(tot == 0)[0]
    if gaps.size:
        out.append(Violation(
            fam, "update_owner", "ownership-gap",
            f"{gaps.size} update positions owned by NO shard (first: "
            f"t={int(gaps[0])}; t=Lmax={Lmax} must go to the LAST "
            f"shard)"))
    over = np.nonzero(tot > 1)[0]
    if over.size:
        out.append(Violation(
            fam, "update_owner", "ownership-overlap",
            f"{over.size} update positions owned by "
            f"{int(tot[over[0]])} shards (first: t={int(over[0])})"))
    checks += 1
    if not owned_bits[d - 1, Lmax]:
        out.append(Violation(
            fam, "update_owner", "ownership-gap",
            f"defensive row t=Lmax={Lmax} is not owned by the last "
            f"shard (the masked-psum carry would write zeros)"))

    # owner's local position must keep the sibling parity bits of the
    # unclamped single-chip value at every sharded level
    owner_of = np.argmax(owned_bits, axis=0)
    tl_owner = np.empty_like(tu)
    for s in range(d):
        rows = np.nonzero(owner_of == s)[0]
        tl_owner[rows] = _np(update_local_t(
            jnp.asarray(tu[rows], jnp.int32), s, Lloc))
    for l in range(nsh_u):
        checks += 1
        bad = np.nonzero(((tl_owner >> l) & 1) != ((tu >> l) & 1))[0]
        if bad.size:
            out.append(Violation(
                fam, "update_local_t", "halo-mismatch",
                f"owner-local position loses the level-{l} sibling "
                f"parity bit at t={int(bad[0])} (t_loc="
                f"{int(tl_owner[bad[0]])}) -- the pair select writes "
                f"the wrong row"))

    # -- (4) partial/deep update pair maps vs the single-chip maps -----
    kn = jnp.zeros((R, _D))
    with capture() as got:
        jax.eval_shape(
            lambda c, k2, v2, tt: dk.update_cache_fused(c, k2, v2, tt),
            cache, kn, kn, tj)
    dense_up = got[0]

    up_slab = type(cache)(
        k=jnp.zeros((R, Lloc, _D)), v=jnp.zeros((R, Lloc, _D)),
        ck=tuple(jnp.zeros((R, Lloc >> l, _D)) for l in range(1, nsh_u)),
        cv=tuple(jnp.zeros((R, Lloc >> l, _D)) for l in range(1, nsh_u)))
    ones = np.ones((R,), np.int64)
    with capture() as got:
        jax.eval_shape(
            lambda c, k2, v2, tt, oo: dk.update_cache_partial(
                c, k2, v2, tt, oo, t_hi=Lmax),
            up_slab, kn, kn, tj, jnp.ones((R,), jnp.int32))
    part_up = got[0]

    # real per-shard t_loc tables fit the declared domain
    t_spec = part_up.scalars[0]
    for s in range(d):
        checks += 1
        tab = _np(update_local_t(tj, s, Lloc))
        bad = np.nonzero((tab < int(np.min(t_spec.lo)))
                         | (tab > int(np.max(t_spec.hi))))[0]
        if bad.size:
            out.append(Violation(
                fam, t_spec.name, "halo-mismatch",
                f"shard {s}: real t_loc value {int(tab[bad[0]])} escapes "
                f"the declared domain [{t_spec.lo}, {t_spec.hi}] at "
                f"t={int(bad[0])}"))

    tlo = tl_owner[:Lmax]
    own_idx = owner_of[:Lmax]
    for l in range(nsh_u):
        checks += 1
        dense_pair = _eval_map(dense_up.inputs[2 + 2 * l], gargs,
                               (t,), R)[:, 1]
        part_pair = _eval_map(part_up.inputs[2 + 2 * l], gargs,
                              (tlo, ones), R)[:, 1]
        glob = part_pair + own_idx * (Lloc >> (l + 1))
        mism = np.nonzero(glob != dense_pair)[0]
        if mism.size:
            tt = int(mism[0])
            out.append(Violation(
                fam, f"k_l{l}", "halo-mismatch",
                f"owner shard writes global level-{l} pair "
                f"{int(glob[tt])} at t={tt}; the single-chip kernel "
                f"writes {int(dense_pair[tt])}"))
    if nsh < M:
        deep = type(cache)(k=cache.ck[nsh - 1], v=cache.cv[nsh - 1],
                           ck=cache.ck[nsh:], cv=cache.cv[nsh:])
        with capture() as got:
            jax.eval_shape(
                lambda c, k2, v2, tt: dk.update_cache_fused(c, k2, v2, tt),
                deep, kn, kn, tj)
        deep_up = got[0]
        t_deep = t >> nsh
        for ld in range(M - nsh):
            checks += 1
            dense_pair = _eval_map(dense_up.inputs[2 + 2 * (nsh + ld)],
                                   gargs, (t,), R)[:, 1]
            deep_pair = _eval_map(deep_up.inputs[2 + 2 * ld], gargs,
                                  (t_deep,), R)[:, 1]
            mism = np.nonzero(deep_pair != dense_pair)[0]
            if mism.size:
                tt = int(mism[0])
                out.append(Violation(
                    fam, f"k_l{nsh + ld}", "halo-mismatch",
                    f"replicated deep level {nsh + ld}: carried update "
                    f"writes pair {int(deep_pair[tt])} at t={tt}; the "
                    f"single-chip kernel writes {int(dense_pair[tt])}"))
    return checks, out


# ---------------------------------------------------------------------------
# training/prefill: halo protocol vs the global band_mask
# ---------------------------------------------------------------------------

def _default_halo_blocks(s: int, nbl_loc: int, d: int,
                         causal: bool) -> set:
    """Key blocks (GLOBAL nr-row block indices, in the level's coarse
    resolution) the halo exchange delivers to shard ``s``: the left
    neighbour's last block, plus (bidir only) the right neighbour's
    first block."""
    provided = set()
    if s > 0:
        provided.add(s * nbl_loc - 1)
    if not causal and s < d - 1:
        provided.add((s + 1) * nbl_loc)
    return provided


def check_halo(d: int, nr: int, L: int, *,
               halo_blocks: Optional[Callable] = None,
               n_shallow_fn: Optional[Callable] = None,
               ) -> Tuple[int, List[Violation]]:
    """Every out-of-shard key block the global ``band_mask`` requires
    must be delivered by the halo protocol, for every mode x shallow
    level x shard.  Returns ``(checks_run, violations)``."""
    import jax.numpy as jnp
    from repro.core import hierarchy as hc
    from repro.kernels import h1d_block
    from repro.parallel import sp_attention as sp

    halo_blocks = halo_blocks or _default_halo_blocks
    n_shallow_fn = n_shallow_fn or sp.sp_n_shallow

    out: List[Violation] = []
    checks = 0
    fam = f"sp_halo d{d} nr{nr} L{L}"
    Lloc = L // d
    if L % d or Lloc % nr or Lloc < nr:
        return 0, []          # _validate_sp_shape refuses this config
    M = hc.num_levels(L, nr)
    n_shallow = n_shallow_fn(M, Lloc, nr)

    cases = [("l0_causal", 0, 1), ("l0_bidir", 0, 1)]
    for l in range(1, n_shallow):
        cases += [("coarse_causal", l, 1), ("coarse_bidir", l, 1),
                  ("sub", l, 1 << l)]
    for mode, l, ratio in cases:
        lk = L >> l
        cl = Lloc >> l                      # local coarse length
        nbl_loc = cl // nr                  # local nr-row key blocks
        causal = mode.endswith("causal") or mode == h1d_block.SUB_MODE
        ki = np.arange(lk, dtype=np.int64)
        for s in range(d):
            checks += 1
            if mode == h1d_block.SUB_MODE:
                qi = s * Lloc + np.arange(Lloc, dtype=np.int64)
            else:
                qi = s * cl + np.arange(cl, dtype=np.int64)
            mask = np.asarray(h1d_block.band_mask(
                jnp.asarray(qi[:, None]), jnp.asarray(ki[None, :]),
                nr, mode, lk, ratio))
            needed_keys = ki[mask.any(axis=0)]
            outside = needed_keys[(needed_keys < s * cl)
                                  | (needed_keys >= (s + 1) * cl)]
            needed = set(int(b) for b in np.unique(outside // nr))
            provided = halo_blocks(s, nbl_loc, d, causal)
            missing = needed - provided
            if missing:
                out.append(Violation(
                    fam, f"{mode} l{l}", "halo-mismatch",
                    f"shard {s} needs out-of-shard key block(s) "
                    f"{sorted(missing)} under the global band_mask but "
                    f"the halo exchange only delivers "
                    f"{sorted(provided)}"))
    return checks, out


# ---------------------------------------------------------------------------
# transition threshold + per-step comm volume (DESIGN.md section 7)
# ---------------------------------------------------------------------------

def check_comm(d: int, nr: int, L: int, *, B: int = 1, Dk: int = _D,
               Dv: int = _D,
               n_shallow_fn: Optional[Callable] = None,
               ) -> Tuple[int, List[Violation]]:
    """Transition-threshold consistency and the pinned per-step comm
    formulas.  The halo byte count comes from the REAL ``sp_halo_pack``
    buffer, not a re-derived closed form."""
    from repro.core import hierarchy as hc
    from repro.parallel import sp_attention as sp

    n_shallow_fn = n_shallow_fn or sp.sp_n_shallow

    out: List[Violation] = []
    checks = 0
    fam = f"sp_comm d{d} nr{nr} L{L}"
    Lloc = L // d
    if L % d or Lloc % nr or Lloc < nr:
        return 0, []
    M = hc.num_levels(L, nr)
    n_shallow = n_shallow_fn(M, Lloc, nr)

    # threshold: level l runs locally iff L >> l >= d * nr (section 7.1)
    for l in range(M):
        checks += 1
        rule = (L >> l) >= d * nr
        code = l < n_shallow
        if rule != code:
            out.append(Violation(
                fam, f"level{l}", "comm-mismatch",
                f"all_gather transition threshold: level {l} is "
                f"{'local' if code else 'gathered'} but L>>l={L >> l} "
                f"{'>=' if rule else '<'} d*nr={d * nr} says it must be "
                f"{'local' if rule else 'gathered'}"))
    # one cache layout: the decode path's sharded-level rule must agree
    checks += 1
    nsh_dec = min(sp.sp_sharded_levels(L, nr, d), M)
    if nsh_dec != n_shallow:
        out.append(Violation(
            fam, "sharded_levels", "comm-mismatch",
            f"decode shards {nsh_dec} levels but the prefill path keeps "
            f"{n_shallow} local -- attend and update would disagree on "
            f"the cache layout"))

    # halo volume from the real packer: one buffer per direction
    kc = [np.zeros((B, Lloc >> l, Dk), np.float32)
          for l in range(n_shallow)]
    vc = [np.zeros((B, Lloc >> l, Dv), np.float32)
          for l in range(n_shallow)]
    wc = [np.zeros((B, Lloc >> l), np.float32) for l in range(n_shallow)]
    buf = np.asarray(sp.sp_halo_pack(kc, vc, wc, n_shallow, nr, "prev"))
    checks += 1
    pinned = B * n_shallow * nr * (Dk + Dv + 1)
    if buf.size != pinned:
        out.append(Violation(
            fam, "halo", "comm-mismatch",
            f"packed halo buffer carries {buf.size} words per "
            f"direction; DESIGN.md section 7 pins "
            f"B*n_shallow*nr*(Dk+Dv+1) = {pinned}"))
    # deep-level gather: <= d*nr/2 transition-level rows in total
    if n_shallow < M:
        checks += 1
        rows = L >> n_shallow
        if rows > d * nr // 2:
            out.append(Violation(
                fam, "gather", "comm-mismatch",
                f"all_gather moves {rows} transition-level rows; "
                f"DESIGN.md section 7 bounds it by d*nr/2 = "
                f"{d * nr // 2}"))
    return checks, out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_dist(*, mesh_sizes=MESH_SIZES, decode_geoms=DECODE_GEOMS,
             band_geoms=BAND_GEOMS,
             ) -> Tuple[Dict[str, int], List[Violation]]:
    """Sweep every check over the mesh x geometry grid.  Returns
    ``({'configs': ..., 'checks': ...}, violations)``."""
    violations: List[Violation] = []
    checks = 0
    configs = 0
    for d in mesh_sizes:
        for nr, Lmax in decode_geoms:
            n, vs = check_decode(d, nr, Lmax)
            if n:
                configs += 1
            checks += n
            violations.extend(vs)
        for nr, L in band_geoms:
            for fn in (check_halo, check_comm):
                n, vs = fn(d, nr, L)
                if n:
                    configs += 1
                checks += n
                violations.extend(vs)
    return {"configs": configs, "checks": checks}, violations
