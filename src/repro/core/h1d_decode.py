"""Incremental decoding with a hierarchical KV cache (beyond-paper).

The paper evaluates training/encoding only.  For autoregressive serving we
derive the incremental form of the leak-free (``fine-q``) causal
hierarchical attention: alongside the fine KV cache we maintain its
coarsened levels (k: pairwise mean, v: pairwise sum).  Per generated
token:

* cache update touches O(log L) rows (the token's ancestors);
* attention reads 2*nr fine keys + nr coarse keys per level
  => O(nr log L) work instead of O(L).

``decode_attend`` is bit-exact against ``h1d_attention(causal=True,
causal_mode='fine-q')`` on the same prefix (tested).

Shapes: the caller folds batch*kv_heads into ``B``; ``G`` is the GQA group.
Cache arrays: fine (B, Lmax, D); level-l coarse (B, Lmax >> l, D).
Positions ``t``: (B,) int32 -- the index of the *current* token (0-based),
whose K/V must already be written by ``update_cache``.

Backends (``impl``, threaded from ``ModelConfig.decode_impl``):
``'jnp'`` is the pure-XLA oracle below; ``'pallas'`` routes
``update_cache`` / ``decode_attend`` (and the uniform-position variants)
through the fused single-launch kernels in
``repro.kernels.h1d_decode_kernel`` -- one HBM read per needed block and
one output write per decode tick, instead of ~2(M+1) one-hot einsums
that stream the whole cache (EXPERIMENTS.md P25);
``'pallas_interpret'`` runs the same kernel bodies interpreted on CPU
(the CI parity path).

Sequence-sharded caches are a kernel-path fast path too: inside an
``sp_scope(mesh)`` region (``repro.parallel.sp_attention``) every entry
point below routes through the shard_map'd fused kernels -- shard-local
block indices and ownership bits are scalar-prefetched so each shard
reads/updates only the blocks it owns, and the partial softmax triples
merge with one pmax + psum.  The old restriction (fused kernels forced
sequence-sharded caches back to ``impl='jnp'``, P21/P22) is gone; the
jnp path remains the decode oracle and the GSPMD-partitionable
fallback.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hierarchy as hc
from . import quantization as qz

NEG_INF = hc.NEG_INF


class H1DCache(NamedTuple):
    k: jnp.ndarray            # (B, Lmax, D) fine keys
    v: jnp.ndarray            # (B, Lmax, Dv) fine values
    ck: Tuple[jnp.ndarray, ...]  # level-l coarse keys, (B, Lmax>>l, D)
    cv: Tuple[jnp.ndarray, ...]  # level-l coarse values (pairwise sums)


def init_cache(B: int, Lmax: int, D: int, Dv: int, nr: int,
               dtype=jnp.float32) -> H1DCache:
    M = hc.num_levels(Lmax, nr)
    ck = tuple(jnp.zeros((B, Lmax >> l, D), dtype) for l in range(1, M))
    cv = tuple(jnp.zeros((B, Lmax >> l, Dv), dtype) for l in range(1, M))
    return H1DCache(
        k=jnp.zeros((B, Lmax, D), dtype),
        v=jnp.zeros((B, Lmax, Dv), dtype),
        ck=ck, cv=cv,
    )


def prefill_cache(k: jnp.ndarray, v: jnp.ndarray, Lmax: int, nr: int) -> H1DCache:
    """Build a cache from a full prefix (B, Lp, D); pads to Lmax."""
    B, Lp, D = k.shape
    pad = Lmax - Lp
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    M = hc.num_levels(Lmax, nr)
    ck, cv = [], []
    kc, vc = kf, vf
    for l in range(1, M):
        kc = hc.coarsen_mean(kc, axis=-2)
        vc = hc.coarsen_sum(vc, axis=-2)
        ck.append(kc)
        cv.append(vc)
    return H1DCache(k=kf, v=vf, ck=tuple(ck), cv=tuple(cv))


def _update_one(cache: H1DCache, k_new, v_new, t):
    """Single-row update; k_new: (D,), v_new: (Dv,), t: scalar int32."""
    k = jax.lax.dynamic_update_slice(cache.k, k_new[None], (t, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new[None], (t, 0))
    ck, cv = [], []
    k_lo, v_lo = k, v
    for l, (ckl, cvl) in enumerate(zip(cache.ck, cache.cv), start=1):
        c = t >> l                        # this token's ancestor at level l
        # children at level l-1 live in the previous level's buffer
        pair_k = jax.lax.dynamic_slice(k_lo, (2 * c, 0), (2, k_lo.shape[-1]))
        pair_v = jax.lax.dynamic_slice(v_lo, (2 * c, 0), (2, v_lo.shape[-1]))
        new_k = pair_k.mean(0)
        new_v = pair_v.sum(0)
        ckl = jax.lax.dynamic_update_slice(ckl, new_k[None], (c, 0))
        cvl = jax.lax.dynamic_update_slice(cvl, new_v[None], (c, 0))
        ck.append(ckl)
        cv.append(cvl)
        k_lo, v_lo = ckl, cvl
    return H1DCache(k=k, v=v, ck=tuple(ck), cv=tuple(cv))


def _resolve_impl(impl: str, family: str) -> str:
    """Canonicalize/resolve the decode ``impl`` through the process
    launch policy (``repro.kernels.tuning``): unknown strings raise
    with the allowed enum, ``'auto'`` resolves per backend.  Every
    decode entry point calls this BEFORE its ``impl != 'jnp'`` branch
    so ``'auto'`` reaches the right path."""
    from repro.kernels.tuning import get_policy
    return get_policy().resolve_impl(impl, family)


def _decode_kernels(impl: str, family: str = "decode_attend"):
    """Lazy import (kernels -> core would otherwise cycle) + interpret
    flag resolution for ``impl in ('pallas', 'pallas_interpret')``.
    Logs the (fixed, one-program-per-row) launch config so the policy
    decision log covers the decode families too."""
    from repro.kernels import h1d_decode_kernel as dk
    from repro.kernels.tuning import get_policy
    get_policy().note_launch(family, impl=impl)
    return dk, impl == "pallas_interpret"


def _sp_decode_ctx(cache: H1DCache, nr: Optional[int] = None):
    """Active SP scope if the cache can shard its fine level (>= one
    nr-row block per shard), else None.  When the caller has no ``nr``
    (the update path) it is recovered from the cache's level count
    (Lmax = nr << num_levels) -- unambiguous only with at least one
    coarse level, so coarse-less caches stay on the single-launch
    kernel."""
    from repro.parallel.sp_attention import sp_ctx, sp_sharded_levels
    ctx = sp_ctx()
    if ctx is None:
        return None
    Lmax = cache.k.shape[-2]
    if nr is None:
        if not cache.ck:          # M in {0, 1}: shape alone can't tell
            return None
        nr = Lmax >> (len(cache.ck) + 1)
    d = dict(ctx[0].shape).get(ctx[1], 1)
    if sp_sharded_levels(Lmax, nr, d) < 1:
        return None
    return ctx


def update_cache(cache: H1DCache, k_new, v_new, t, *,
                 impl: str = "jnp") -> H1DCache:
    """Batched cache update.  k_new: (B, D), v_new: (B, Dv), t: (B,).

    Kernel impls inside an ``sp_scope(mesh)`` run the shard_map'd fused
    update: each token's ancestor pairs are rewritten on their owning
    shard only (see ``parallel.sp_attention.sp_update_cache``)."""
    impl = _resolve_impl(impl, "decode_update")
    if impl != "jnp":
        ctx = _sp_decode_ctx(cache)
        if ctx is not None:
            from repro.parallel.sp_attention import sp_update_cache
            return sp_update_cache(cache, k_new, v_new, t, impl=impl,
                                   mesh=ctx[0], axis=ctx[1])
        dk, interpret = _decode_kernels(impl, "decode_update")
        return dk.update_cache_fused(cache, k_new, v_new, t,
                                     interpret=interpret)
    return jax.vmap(_update_one)(cache, k_new, v_new, t)


def _attend_one(cache: H1DCache, q, t, nr, scale):
    """q: (G, D), t: scalar.  Returns (G, Dv)."""
    f32 = jnp.float32
    G, D = q.shape
    q = q.astype(f32) * scale
    Lmax = cache.k.shape[-2]
    M = hc.num_levels(Lmax, nr)

    logits, values, weights = [], [], []

    def band(keys, vals, mask, wgt):
        s = jnp.einsum("gd,kd->gk", q, keys.astype(f32),
                       preferred_element_type=f32)
        logits.append(jnp.where(mask[None], s, NEG_INF))
        values.append(vals.astype(f32))
        weights.append(jnp.where(mask, wgt, 0.0))

    # level 0: own block (causal) + previous block
    blk0 = t // nr
    s0 = blk0 * nr
    own_k = jax.lax.dynamic_slice(cache.k, (s0, 0), (nr, D))
    own_v = jax.lax.dynamic_slice(cache.v, (s0, 0), (nr, cache.v.shape[-1]))
    pos = s0 + jnp.arange(nr)
    band(own_k, own_v, pos <= t, jnp.ones((nr,), f32))

    sp = jnp.maximum(s0 - nr, 0)
    prev_k = jax.lax.dynamic_slice(cache.k, (sp, 0), (nr, D))
    prev_v = jax.lax.dynamic_slice(cache.v, (sp, 0), (nr, cache.v.shape[-1]))
    band(prev_k, prev_v, jnp.broadcast_to(blk0 >= 1, (nr,)),
         jnp.ones((nr,), f32))

    # coarse levels
    for l in range(1, M):
        span = nr << l
        Il = t // span
        start = jnp.maximum((Il - 1) * nr, 0)
        ckl = cache.ck[l - 1]
        cvl = cache.cv[l - 1]
        kk = jax.lax.dynamic_slice(ckl, (start, 0), (nr, D))
        vv = jax.lax.dynamic_slice(cvl, (start, 0), (nr, cvl.shape[-1]))
        first_half_q = (t % span) < (span // 2)
        key_last_half = jnp.arange(nr) >= nr // 2
        mask = (Il >= 1) & ~(first_half_q & key_last_half)
        band(kk, vv, mask, jnp.full((nr,), float(1 << l), f32))

    s = jnp.concatenate(logits, axis=-1)           # (G, K)
    vcat = jnp.concatenate(values, axis=-2)        # (K, Dv)
    wcat = jnp.concatenate(weights, axis=-1)       # (K,)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = a @ vcat
    den = a @ wcat
    return num / jnp.maximum(den, 1e-9)[..., None]


def _block_read_rows(arr, blk, size):
    """Per-row block read: arr (B, L, D), blk (B,) -> (B, size, D).

    One-hot contraction over the block axis: batch-aligned, so it stays
    fully local on a batch-sharded cache (the vmap'd dynamic_slice
    variant lowers to a cross-batch gather that GSPMD all-gathers --
    EXPERIMENTS.md P21/P22)."""
    B, L, D = arr.shape
    nb = L // size
    a2 = arr.reshape(B, nb, size * D)
    sel = jax.nn.one_hot(blk, nb, dtype=arr.dtype)        # (B, nb)
    out = jnp.einsum("bn,bnf->bf", sel, a2,
                     preferred_element_type=arr.dtype)
    return out.reshape(B, size, D)


def decode_attend(cache: H1DCache, q, t, *, nr: int,
                  softmax_scale=None, impl: str = "jnp") -> jnp.ndarray:
    """Batched single-token attention.  q: (B, G, D), t: (B,) per-row
    positions.  Returns (B, G, Dv) in q.dtype.

    Kernel impls inside an ``sp_scope(mesh)`` run the shard_map'd fused
    attend (per-shard partial kernels over owned blocks, one pmax+psum
    merge -- ``parallel.sp_attention.sp_decode_attend``)."""
    impl = _resolve_impl(impl, "decode_attend")
    if impl != "jnp":
        ctx = _sp_decode_ctx(cache, nr)
        if ctx is not None:
            from repro.parallel.sp_attention import sp_decode_attend
            return sp_decode_attend(cache, q, t, nr=nr,
                                    softmax_scale=softmax_scale, impl=impl,
                                    mesh=ctx[0], axis=ctx[1])
        dk, interpret = _decode_kernels(impl, "decode_attend")
        return dk.decode_attend_fused(cache, q, t, nr=nr,
                                      softmax_scale=softmax_scale,
                                      interpret=interpret)
    f32 = jnp.float32
    B, G, D = q.shape
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    qs = q.astype(f32) * scale
    Lmax = cache.k.shape[-2]
    M = hc.num_levels(Lmax, nr)

    logits, values, weights = [], [], []

    def band(keys, vals, mask, wgt):
        """keys (B,nr,D), vals (B,nr,Dv), mask (B,nr), wgt (B,nr)."""
        s = jnp.einsum("bgd,bkd->bgk", qs, keys.astype(f32),
                       preferred_element_type=f32)
        logits.append(jnp.where(mask[:, None, :], s, NEG_INF))
        values.append(vals.astype(f32))
        weights.append(jnp.where(mask, wgt, 0.0))

    blk0 = t // nr                                        # (B,)
    pos = blk0[:, None] * nr + jnp.arange(nr)[None, :]    # (B, nr)
    ones = jnp.ones((B, nr), f32)
    band(_block_read_rows(cache.k, blk0, nr),
         _block_read_rows(cache.v, blk0, nr),
         pos <= t[:, None], ones)
    band(_block_read_rows(cache.k, jnp.maximum(blk0 - 1, 0), nr),
         _block_read_rows(cache.v, jnp.maximum(blk0 - 1, 0), nr),
         jnp.broadcast_to((blk0 >= 1)[:, None], (B, nr)), ones)
    for l in range(1, M):
        span = nr << l
        Il = t // span
        blk = jnp.maximum(Il - 1, 0)
        first_half_q = (t % span) < (span // 2)           # (B,)
        key_last_half = jnp.arange(nr) >= nr // 2         # (nr,)
        mask = (Il >= 1)[:, None] & ~(first_half_q[:, None]
                                      & key_last_half[None, :])
        band(_block_read_rows(cache.ck[l - 1], blk, nr),
             _block_read_rows(cache.cv[l - 1], blk, nr),
             mask, jnp.full((B, nr), float(1 << l), f32))

    s = jnp.concatenate(logits, axis=-1)                  # (B, G, K)
    vcat = jnp.concatenate(values, axis=-2)               # (B, K, Dv)
    wcat = jnp.concatenate(weights, axis=-1)              # (B, K)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = jnp.einsum("bgk,bkv->bgv", a, vcat)
    den = jnp.einsum("bgk,bk->bg", a, wcat)
    return (num / jnp.maximum(den, 1e-9)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged cache pool (serving-memory subsystem, serve/paged_cache.py)
# ---------------------------------------------------------------------------
# The dense H1DCache pins Lmax rows per row-slot.  The paged layout
# replaces each level's (R, L_l, D) slab with a POOL of nr-row pages
# (NP_l, nr, D) plus host-side per-request page tables; the decode entry
# points below take the physical page row of every block they touch as a
# small per-tick indirection table (one column per band / level), so the
# math is the dense oracle's with the block reads/writes routed through
# the tables.  Pools are host-local (no sp_scope dispatch): the serving
# engine forbids mesh+paged at construction.


class PagedH1DCache(NamedTuple):
    """Per-layer paged pools.  ``k``/``v``: (NP0, nr, D/Dv) fine pages;
    ``ck[l-1]``/``cv[l-1]``: (NP_l, nr, ...) level-l coarse pages.  A
    "page" here is one pool row: ``nr`` consecutive level-l rows of ONE
    cache row (batch*kv-head).  Logical (slot, level, block) -> pool row
    mapping lives in ``serve.paged_cache.PagePool`` (host)."""
    k: jnp.ndarray
    v: jnp.ndarray
    ck: Tuple[jnp.ndarray, ...]
    cv: Tuple[jnp.ndarray, ...]


class PageTables(NamedTuple):
    """Per-tick device indirection tables (host-built, jit arguments).

    ``attend``: (R, 2 + levels) int32 -- physical pool rows for the own
    level-0 page, the previous level-0 page, and each level's ``I_l - 1``
    page (columns for masked-out bands hold any in-range row).
    ``update``: (R, 1 + levels) int32 -- physical pool rows of the
    token's ancestor pages (column l holds the page of row ``t >> l``);
    inactive engine rows point at a trash page."""
    attend: jnp.ndarray
    update: jnp.ndarray


def init_paged_pool(num_pages, nr: int, D: int, Dv: int,
                    dtype=jnp.float32) -> PagedH1DCache:
    """Zeroed pools.  ``num_pages``: sequence of per-level pool sizes
    (index 0 = fine, index l = coarse level l); its length fixes the
    number of hierarchy levels."""
    k = jnp.zeros((num_pages[0], nr, D), dtype)
    v = jnp.zeros((num_pages[0], nr, Dv), dtype)
    ck = tuple(jnp.zeros((n, nr, D), dtype) for n in num_pages[1:])
    cv = tuple(jnp.zeros((n, nr, Dv), dtype) for n in num_pages[1:])
    return PagedH1DCache(k=k, v=v, ck=ck, cv=cv)


class QuantPagedH1DCache(NamedTuple):
    """Quantized paged pools: same page geometry as
    :class:`PagedH1DCache`, but any subset of levels stores its pages in
    int8 with one float32 symmetric absmax scale PER CACHED ROW
    (``core.quantization``, axis=-1), i.e. scale arrays of shape
    ``(NP_l, nr)`` riding next to the ``(NP_l, nr, D)`` data.  Scale
    arrays exist for EVERY level so the pytree structure is independent
    of which levels are quantized (fp32 levels carry all-ones scales
    that are never read) -- which levels ARE quantized is a static
    property of the array dtypes (:func:`quant_level_flags`), so jit
    retraces only when the quantization config changes."""
    k: jnp.ndarray
    v: jnp.ndarray
    ck: Tuple[jnp.ndarray, ...]
    cv: Tuple[jnp.ndarray, ...]
    ksc: jnp.ndarray              # (NP0, nr) f32 per-row scales for k
    vsc: jnp.ndarray              # (NP0, nr)
    cksc: Tuple[jnp.ndarray, ...]  # (NP_l, nr) per coarse level
    cvsc: Tuple[jnp.ndarray, ...]


def quant_level_flags(pool: QuantPagedH1DCache) -> Tuple[bool, ...]:
    """Per-level "is int8" flags (index 0 = fine), read off the array
    dtypes -- static under jit."""
    return tuple(bool(a.dtype == jnp.int8) for a in (pool.k, *pool.ck))


def init_quant_paged_pool(num_pages, nr: int, D: int, Dv: int,
                          dtype=jnp.float32,
                          quant=None) -> QuantPagedH1DCache:
    """Zeroed quantized pools.  ``quant``: per-level bool sequence
    (index 0 = fine); ``None`` quantizes every level.  Scales init to
    1.0 so zero pages dequantize to exact zeros."""
    M = len(num_pages)
    if quant is None:
        quant = (True,) * M

    def data(n, d, is_q):
        return jnp.zeros((n, nr, d), jnp.int8 if is_q else dtype)

    def sc(n):
        return jnp.ones((n, nr), jnp.float32)

    return QuantPagedH1DCache(
        k=data(num_pages[0], D, quant[0]),
        v=data(num_pages[0], Dv, quant[0]),
        ck=tuple(data(n, D, quant[l])
                 for l, n in enumerate(num_pages[1:], 1)),
        cv=tuple(data(n, Dv, quant[l])
                 for l, n in enumerate(num_pages[1:], 1)),
        ksc=sc(num_pages[0]), vsc=sc(num_pages[0]),
        cksc=tuple(sc(n) for n in num_pages[1:]),
        cvsc=tuple(sc(n) for n in num_pages[1:]),
    )


def update_cache_paged(pool, k_new, v_new, t, utab, *,
                       impl: str = "jnp"):
    """Paged batched append.  ``k_new``: (R, D), ``v_new``: (R, Dv),
    ``t``: (R,) global positions, ``utab``: (R, 1 + levels) physical
    page rows (see :class:`PageTables`).  Same ancestor-chain math as
    ``update_cache``: the level-l row ``t >> l`` becomes the pairwise
    mean/sum of the freshly updated level-(l-1) sibling pair -- which
    lives in the level-(l-1) page just written (clearing bit 0 of
    ``t >> (l-1)`` never crosses a page boundary for nr >= 2).

    A :class:`QuantPagedH1DCache` pool routes to the quantized variants:
    each level's sibling pair is dequantized, the new row substituted,
    and the 2-row pair REwritten through quantize (fresh per-row scales)
    -- the ancestor carry uses the pre-quantization f32 pair so the
    hierarchy invariants (mean/sum of the *stored* children up to one
    quantization step) hold at every level."""
    quant = isinstance(pool, QuantPagedH1DCache)
    family = "decode_update_paged_quant" if quant else "decode_update_paged"
    impl = _resolve_impl(impl, family)
    if quant:
        if impl != "jnp":
            dk, interpret = _decode_kernels(impl, family)
            return dk.update_cache_paged_quant(pool, k_new, v_new, t, utab,
                                               interpret=interpret)
        return _update_cache_paged_quant_jnp(pool, k_new, v_new, t, utab)
    if impl != "jnp":
        dk, interpret = _decode_kernels(impl, family)
        return dk.update_cache_paged(pool, k_new, v_new, t, utab,
                                     interpret=interpret)
    t = jnp.asarray(t, jnp.int32)
    utab = jnp.asarray(utab, jnp.int32)
    nr = pool.k.shape[-2]
    row0 = t % nr
    k = pool.k.at[utab[:, 0], row0].set(k_new)
    v = pool.v.at[utab[:, 0], row0].set(v_new)
    ck, cv = [], []
    base = row0 & ~1
    pair_k = jnp.stack([k[utab[:, 0], base], k[utab[:, 0], base + 1]])
    pair_v = jnp.stack([v[utab[:, 0], base], v[utab[:, 0], base + 1]])
    for l, (ckl, cvl) in enumerate(zip(pool.ck, pool.cv), start=1):
        rowl = (t >> l) % nr
        ckl = ckl.at[utab[:, l], rowl].set(pair_k.mean(0))
        cvl = cvl.at[utab[:, l], rowl].set(pair_v.sum(0))
        ck.append(ckl)
        cv.append(cvl)
        if l < len(pool.ck):
            base = rowl & ~1
            pair_k = jnp.stack([ckl[utab[:, l], base],
                                ckl[utab[:, l], base + 1]])
            pair_v = jnp.stack([cvl[utab[:, l], base],
                                cvl[utab[:, l], base + 1]])
    return PagedH1DCache(k=k, v=v, ck=tuple(ck), cv=tuple(cv))


def _update_cache_paged_quant_jnp(pool: QuantPagedH1DCache, k_new, v_new,
                                  t, utab) -> QuantPagedH1DCache:
    """jnp oracle for the quantized paged append.  Unlike the fp32 path
    (single-row level-0 write), EVERY level rewrites its full 2-row
    sibling pair -- requantizing the untouched sibling in place -- which
    is exactly what the fused kernel does, so the two are bit-exact on
    the int8 payload AND the scales."""
    t = jnp.asarray(t, jnp.int32)
    utab = jnp.asarray(utab, jnp.int32)
    nr = pool.k.shape[-2]
    f32 = jnp.float32
    quant = quant_level_flags(pool)
    ks = [pool.k] + list(pool.ck)
    vs = [pool.v] + list(pool.cv)
    kscs = [pool.ksc] + list(pool.cksc)
    vscs = [pool.vsc] + list(pool.cvsc)
    carry_k = jnp.asarray(k_new, f32)
    carry_v = jnp.asarray(v_new, f32)
    two = jnp.arange(2)
    for l in range(len(ks)):
        rowl = (t >> l) % nr
        page = utab[:, l]
        rows2 = (rowl & ~1)[:, None] + two[None, :]          # (R, 2)
        pk = ks[l][page[:, None], rows2].astype(f32)         # (R, 2, D)
        pv = vs[l][page[:, None], rows2].astype(f32)
        if quant[l]:
            pk = pk * kscs[l][page[:, None], rows2][..., None]
            pv = pv * vscs[l][page[:, None], rows2][..., None]
        sel = (two[None, :] == ((t >> l) & 1)[:, None])[..., None]
        pk = jnp.where(sel, carry_k[:, None, :], pk)
        pv = jnp.where(sel, carry_v[:, None, :], pv)
        if quant[l]:
            qk, sk = qz.quantize_int8(pk, axis=-1)
            qv, sv = qz.quantize_int8(pv, axis=-1)
            ks[l] = ks[l].at[page[:, None], rows2].set(qk)
            vs[l] = vs[l].at[page[:, None], rows2].set(qv)
            kscs[l] = kscs[l].at[page[:, None], rows2].set(sk[..., 0])
            vscs[l] = vscs[l].at[page[:, None], rows2].set(sv[..., 0])
        else:
            ks[l] = ks[l].at[page[:, None], rows2].set(pk.astype(ks[l].dtype))
            vs[l] = vs[l].at[page[:, None], rows2].set(pv.astype(vs[l].dtype))
        carry_k = pk.mean(axis=1)
        carry_v = pv.sum(axis=1)
    return QuantPagedH1DCache(
        k=ks[0], v=vs[0], ck=tuple(ks[1:]), cv=tuple(vs[1:]),
        ksc=kscs[0], vsc=vscs[0],
        cksc=tuple(kscs[1:]), cvsc=tuple(vscs[1:]))


def decode_attend_paged(pool, q, t, bidx, *, nr: int,
                        softmax_scale=None, impl: str = "jnp") -> jnp.ndarray:
    """Paged batched single-token attention.  ``q``: (R, G, D); ``t``:
    (R,) global positions; ``bidx``: (R, 2 + levels) physical page rows
    (see :class:`PageTables`).  Same bands, masks and single-max
    weighted-LSE combine as ``decode_attend`` -- the page tables only
    relocate the block reads.  A :class:`QuantPagedH1DCache` pool
    dequantizes each gathered page row with its per-row scale before
    the band math; everything downstream is identical."""
    quant = isinstance(pool, QuantPagedH1DCache)
    family = "decode_attend_paged_quant" if quant else "decode_attend_paged"
    impl = _resolve_impl(impl, family)
    if quant:
        if impl != "jnp":
            dk, interpret = _decode_kernels(impl, family)
            return dk.decode_attend_paged_quant(pool, q, t, bidx, nr=nr,
                                                softmax_scale=softmax_scale,
                                                interpret=interpret)
        return _decode_attend_paged_quant_jnp(pool, q, t, bidx, nr=nr,
                                              softmax_scale=softmax_scale)
    if impl != "jnp":
        dk, interpret = _decode_kernels(impl, family)
        return dk.decode_attend_paged(pool, q, t, bidx, nr=nr,
                                      softmax_scale=softmax_scale,
                                      interpret=interpret)
    f32 = jnp.float32
    R, G, D = q.shape
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    qs = q.astype(f32) * scale
    t = jnp.asarray(t, jnp.int32)
    bidx = jnp.asarray(bidx, jnp.int32)
    M = 1 + len(pool.ck)

    logits, values, weights = [], [], []

    def band(keys, vals, mask, wgt):
        s = jnp.einsum("bgd,bkd->bgk", qs, keys.astype(f32),
                       preferred_element_type=f32)
        logits.append(jnp.where(mask[:, None, :], s, NEG_INF))
        values.append(vals.astype(f32))
        weights.append(jnp.where(mask, wgt, 0.0))

    blk0 = t // nr
    pos = blk0[:, None] * nr + jnp.arange(nr)[None, :]
    ones = jnp.ones((R, nr), f32)
    band(pool.k[bidx[:, 0]], pool.v[bidx[:, 0]], pos <= t[:, None], ones)
    band(pool.k[bidx[:, 1]], pool.v[bidx[:, 1]],
         jnp.broadcast_to((blk0 >= 1)[:, None], (R, nr)), ones)
    for l in range(1, M):
        span = nr << l
        Il = t // span
        first_half_q = (t % span) < (span // 2)
        key_last_half = jnp.arange(nr) >= nr // 2
        mask = (Il >= 1)[:, None] & ~(first_half_q[:, None]
                                      & key_last_half[None, :])
        band(pool.ck[l - 1][bidx[:, 1 + l]], pool.cv[l - 1][bidx[:, 1 + l]],
             mask, jnp.full((R, nr), float(1 << l), f32))

    s = jnp.concatenate(logits, axis=-1)                  # (R, G, K)
    vcat = jnp.concatenate(values, axis=-2)               # (R, K, Dv)
    wcat = jnp.concatenate(weights, axis=-1)              # (R, K)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = jnp.einsum("bgk,bkv->bgv", a, vcat)
    den = jnp.einsum("bgk,bk->bg", a, wcat)
    return (num / jnp.maximum(den, 1e-9)[..., None]).astype(q.dtype)


def _decode_attend_paged_quant_jnp(pool: QuantPagedH1DCache, q, t, bidx, *,
                                   nr: int, softmax_scale=None):
    """jnp oracle for quantized paged attention: the fp32 band math with
    per-row dequantization at the gathers."""
    f32 = jnp.float32
    R, G, D = q.shape
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    qs = q.astype(f32) * scale
    t = jnp.asarray(t, jnp.int32)
    bidx = jnp.asarray(bidx, jnp.int32)
    M = 1 + len(pool.ck)
    quant = quant_level_flags(pool)

    def deq(arr, sc, idx, is_q):
        x = arr[idx].astype(f32)
        return x * sc[idx][..., None] if is_q else x

    logits, values, weights = [], [], []

    def band(keys, vals, mask, wgt):
        s = jnp.einsum("bgd,bkd->bgk", qs, keys,
                       preferred_element_type=f32)
        logits.append(jnp.where(mask[:, None, :], s, NEG_INF))
        values.append(vals)
        weights.append(jnp.where(mask, wgt, 0.0))

    blk0 = t // nr
    pos = blk0[:, None] * nr + jnp.arange(nr)[None, :]
    ones = jnp.ones((R, nr), f32)
    band(deq(pool.k, pool.ksc, bidx[:, 0], quant[0]),
         deq(pool.v, pool.vsc, bidx[:, 0], quant[0]),
         pos <= t[:, None], ones)
    band(deq(pool.k, pool.ksc, bidx[:, 1], quant[0]),
         deq(pool.v, pool.vsc, bidx[:, 1], quant[0]),
         jnp.broadcast_to((blk0 >= 1)[:, None], (R, nr)), ones)
    for l in range(1, M):
        span = nr << l
        Il = t // span
        first_half_q = (t % span) < (span // 2)
        key_last_half = jnp.arange(nr) >= nr // 2
        mask = (Il >= 1)[:, None] & ~(first_half_q[:, None]
                                      & key_last_half[None, :])
        band(deq(pool.ck[l - 1], pool.cksc[l - 1], bidx[:, 1 + l], quant[l]),
             deq(pool.cv[l - 1], pool.cvsc[l - 1], bidx[:, 1 + l], quant[l]),
             mask, jnp.full((R, nr), float(1 << l), f32))

    s = jnp.concatenate(logits, axis=-1)
    vcat = jnp.concatenate(values, axis=-2)
    wcat = jnp.concatenate(weights, axis=-1)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = jnp.einsum("bgk,bkv->bgv", a, vcat)
    den = jnp.einsum("bgk,bk->bg", a, wcat)
    return (num / jnp.maximum(den, 1e-9)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# uniform-position fast path (single-sequence / long-context decode)
# ---------------------------------------------------------------------------
# When every batch row decodes the same position (B=1 with kv-heads folded,
# the long_500k serving shape), the vmap'd dynamic_slices above become
# gathers, which GSPMD lowers as full all-gathers of the sequence-sharded
# cache (~2 GB/step/layer at 512k).  With a *scalar* t the reads stay
# dynamic-slices on the sharded dim, which partition efficiently
# (EXPERIMENTS.md P21).

def _batched_slice(arr, start, size):
    """arr: (B, L, D) -> (B, size, D) at scalar ``start`` along L."""
    return jax.lax.dynamic_slice(
        arr, (0, start, 0), (arr.shape[0], size, arr.shape[-1]))


def _block_read(arr, blk, size):
    """Block-aligned read: arr (B, L, D) -> (B, size, D) at row
    ``blk * size`` (scalar ``blk``).

    Implemented as a one-hot contraction over the block axis instead of
    a dynamic_slice: on a sequence-sharded cache GSPMD contracts locally
    and psums only the (B, size, D) result (~KBs), where a dynamic_slice
    would all-gather the whole cache (EXPERIMENTS.md P22).  Costs
    O(L * D / size) extra FLOPs -- noise next to the saved wire bytes.
    """
    B, L, D = arr.shape
    nb = L // size
    a2 = arr.reshape(B, nb, size * D)
    sel = jax.nn.one_hot(blk, nb, dtype=arr.dtype)        # (nb,)
    out = jnp.einsum("n,bnf->bf", sel, a2,
                     preferred_element_type=arr.dtype)
    return out.reshape(B, size, D)


def update_cache_uniform(cache: H1DCache, k_new, v_new, t, *,
                         impl: str = "jnp") -> H1DCache:
    """k_new: (B, D), v_new: (B, Dv), t: scalar int32 (same for all rows).

    ``impl != 'jnp'`` routes through the SAME fused kernel as the batched
    path with the scalar ``t`` broadcast per row.  A SEQUENCE-SHARDED
    cache is a fast path too: inside ``sp_scope(mesh)`` the broadcast
    goes through the shard_map'd kernel with shard-local index maps
    (``parallel.sp_attention``), so the long-context serving shape no
    longer downgrades to ``impl='jnp'`` (the old P21/P22 restriction);
    outside an SP scope the jnp scalar-``t`` dynamic-slices remain the
    GSPMD fallback.
    """
    impl = _resolve_impl(impl, "decode_update")
    if impl != "jnp":
        tt = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (cache.k.shape[0],))
        ctx = _sp_decode_ctx(cache)
        if ctx is not None:
            from repro.parallel.sp_attention import sp_update_cache
            return sp_update_cache(cache, k_new, v_new, tt, impl=impl,
                                   mesh=ctx[0], axis=ctx[1])
        dk, interpret = _decode_kernels(impl, "decode_update")
        return dk.update_cache_fused(cache, k_new, v_new, tt,
                                     interpret=interpret)
    k = jax.lax.dynamic_update_slice(cache.k, k_new[:, None], (0, t, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new[:, None], (0, t, 0))
    ck, cv = [], []
    k_lo, v_lo = k, v
    for l, (ckl, cvl) in enumerate(zip(cache.ck, cache.cv), start=1):
        c = t >> l
        pair_k = _block_read(k_lo, c, 2)
        pair_v = _block_read(v_lo, c, 2)
        ckl = jax.lax.dynamic_update_slice(
            ckl, pair_k.mean(1, keepdims=True), (0, c, 0))
        cvl = jax.lax.dynamic_update_slice(
            cvl, pair_v.sum(1, keepdims=True), (0, c, 0))
        ck.append(ckl)
        cv.append(cvl)
        k_lo, v_lo = ckl, cvl
    return H1DCache(k=k, v=v, ck=tuple(ck), cv=tuple(cv))


def decode_attend_uniform(cache: H1DCache, q, t, *, nr: int,
                          softmax_scale=None,
                          impl: str = "jnp") -> jnp.ndarray:
    """q: (B, G, D); t: scalar int32.  Returns (B, G, Dv).

    ``impl != 'jnp'``: scalar-``t`` specialization of the fused decode
    kernel (broadcast per row); inside ``sp_scope(mesh)`` a
    sequence-sharded cache stays on the kernel path via the shard_map'd
    partial attend (see ``update_cache_uniform``)."""
    impl = _resolve_impl(impl, "decode_attend")
    if impl != "jnp":
        tt = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (cache.k.shape[0],))
        ctx = _sp_decode_ctx(cache, nr)
        if ctx is not None:
            from repro.parallel.sp_attention import sp_decode_attend
            return sp_decode_attend(cache, q, tt, nr=nr,
                                    softmax_scale=softmax_scale, impl=impl,
                                    mesh=ctx[0], axis=ctx[1])
        dk, interpret = _decode_kernels(impl, "decode_attend")
        return dk.decode_attend_fused(cache, q, tt, nr=nr,
                                      softmax_scale=softmax_scale,
                                      interpret=interpret)
    f32 = jnp.float32
    B, G, D = q.shape
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    qs = q.astype(f32) * scale
    Lmax = cache.k.shape[-2]
    M = hc.num_levels(Lmax, nr)

    logits, values, weights = [], [], []

    def band(keys, vals, mask, wgt):
        s = jnp.einsum("bgd,bkd->bgk", qs, keys.astype(f32),
                       preferred_element_type=f32)
        logits.append(jnp.where(mask[None, None, :], s, NEG_INF))
        values.append(vals.astype(f32))
        weights.append(jnp.where(mask, wgt, 0.0))

    blk0 = t // nr
    s0 = blk0 * nr
    pos = s0 + jnp.arange(nr)
    band(_block_read(cache.k, blk0, nr), _block_read(cache.v, blk0, nr),
         pos <= t, jnp.ones((nr,), f32))
    band(_block_read(cache.k, jnp.maximum(blk0 - 1, 0), nr),
         _block_read(cache.v, jnp.maximum(blk0 - 1, 0), nr),
         jnp.broadcast_to(blk0 >= 1, (nr,)), jnp.ones((nr,), f32))
    for l in range(1, M):
        span = nr << l
        Il = t // span
        blk = jnp.maximum(Il - 1, 0)
        first_half_q = (t % span) < (span // 2)
        key_last_half = jnp.arange(nr) >= nr // 2
        mask = (Il >= 1) & ~(first_half_q & key_last_half)
        band(_block_read(cache.ck[l - 1], blk, nr),
             _block_read(cache.cv[l - 1], blk, nr),
             mask, jnp.full((nr,), float(1 << l), f32))

    s = jnp.concatenate(logits, axis=-1)              # (B, G, K)
    vcat = jnp.concatenate(values, axis=-2)           # (B, K, Dv)
    wcat = jnp.concatenate(weights, axis=-1)          # (K,)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = jnp.einsum("bgk,bkv->bgv", a, vcat)
    den = jnp.einsum("bgk,k->bg", a, wcat)
    return (num / jnp.maximum(den, 1e-9)[..., None]).astype(q.dtype)
