"""Core H-Transformer-1D hierarchical attention (the paper's contribution)."""
from .h1d_attention import (h1d_attention, h1d_attention_mha,
                            fold_kv_heads, unfold_kv_heads)
from .ref_attention import dense_attention, h1d_dense_oracle
from .h1d_decode import (
    H1DCache,
    init_cache,
    prefill_cache,
    update_cache,
    decode_attend,
    update_cache_uniform,
    decode_attend_uniform,
)
from . import hierarchy
from . import quantization
from .quantization import quantize_int8, dequantize_int8

__all__ = [
    "h1d_attention",
    "h1d_attention_mha",
    "fold_kv_heads",
    "unfold_kv_heads",
    "dense_attention",
    "h1d_dense_oracle",
    "H1DCache",
    "init_cache",
    "prefill_cache",
    "update_cache",
    "decode_attend",
    "update_cache_uniform",
    "decode_attend_uniform",
    "hierarchy",
    "quantization",
    "quantize_int8",
    "dequantize_int8",
]
