"""Binary-tree hierarchy utilities for H-Transformer-1D attention.

Terminology (paper Eq. 25-33):
  * ``nr``      -- numerical rank == level-0 block size (paper: N_r).
  * level-l sequence: the original sequence coarsened ``l`` times; its
    length is ``L / 2**l`` and it is partitioned into blocks of ``nr``
    coarse tokens (``nb_l = L / (nr * 2**l)`` blocks).
  * Queries/keys coarsen with a pairwise *mean* (Eq. 25-26), values and
    key-weights with a pairwise *sum* (Eq. 27) so that the normalizer
    ``D = A @ 1`` falls out of the same operator applied to the weight
    vector.

Partition rule (DESIGN.md section 1.1): a fine token pair ``(i, j)`` is
attended at the smallest level ``l`` with ``|blk_l(i) - blk_l(j)| <= 1``
where ``blk_l(x) = x // (nr * 2**l)``.  For ``l >= 1`` this yields the
uniform quadrant exclusion masks implemented below.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "validate_h1d_shape",
    "num_levels",
    "coarsen_mean",
    "coarsen_sum",
    "block",
    "unblock",
    "shift_blocks",
    "quadrant_mask",
    "causal_block_mask",
    "interp_repeat",
    "level_assignment_map",
    "padded_length",
]

NEG_INF = float(np.finfo(np.float32).min)


def padded_length(L: int, nr: int) -> int:
    """Smallest L' >= L with L' = nr * 2**k (k >= 0)."""
    if L <= nr:
        return nr
    nb = (L + nr - 1) // nr
    return nr * (1 << max(0, math.ceil(math.log2(nb))))


def validate_h1d_shape(L: int, nr: int) -> int:
    """Check L == nr * 2**k, return number of level-0 blocks."""
    if nr < 2 or nr & (nr - 1):
        raise ValueError(f"nr must be a power of two >= 2, got {nr}")
    if L % nr:
        raise ValueError(f"L={L} not a multiple of nr={nr}")
    nb = L // nr
    if nb & (nb - 1):
        raise ValueError(f"num blocks L/nr={nb} must be a power of two")
    return nb


def num_levels(L: int, nr: int) -> int:
    """Number of hierarchy levels M = log2(L / nr); 0 means single block."""
    nb = validate_h1d_shape(L, nr)
    return int(math.log2(nb)) if nb > 1 else 0


def coarsen_mean(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Pairwise mean along ``axis`` (Eq. 25/26). Length must be even."""
    shape = list(x.shape)
    axis = axis % x.ndim
    shape[axis : axis + 1] = [shape[axis] // 2, 2]
    return jnp.reshape(x, shape).mean(axis=axis + 1)


def coarsen_sum(x: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Pairwise sum along ``axis`` (Eq. 27)."""
    shape = list(x.shape)
    axis = axis % x.ndim
    shape[axis : axis + 1] = [shape[axis] // 2, 2]
    return jnp.reshape(x, shape).sum(axis=axis + 1)


def coarsen_weighted_mean(x: jnp.ndarray, w: jnp.ndarray):
    """Weighted pairwise mean along the token axis; returns (coarse_x, coarse_w).

    ``x``: (B, ..., L, D); ``w``: (B, L) (or (L,)).  Padded (weight-0)
    tokens then do not pollute coarse rows.
    """
    wx = w
    if wx.ndim < x.ndim - 1:  # insert middle broadcast dims after batch
        shape = (w.shape[0],) + (1,) * (x.ndim - 1 - w.ndim) + (w.shape[-1],)
        wx = jnp.reshape(w, shape)
    xw = coarsen_sum(x * wx[..., None], axis=-2)
    ws = coarsen_sum(w, axis=-1)
    wsx = ws
    if wsx.ndim < x.ndim - 1:
        shape = (ws.shape[0],) + (1,) * (x.ndim - 1 - ws.ndim) + (ws.shape[-1],)
        wsx = jnp.reshape(ws, shape)
    return xw / jnp.maximum(wsx, 1.0)[..., None], ws


def block(x: jnp.ndarray, n: int, axis: int = -2) -> jnp.ndarray:
    """(... , L, ...) -> (..., L//n, n, ...) along ``axis``."""
    shape = list(x.shape)
    axis = axis % x.ndim
    shape[axis : axis + 1] = [shape[axis] // n, n]
    return jnp.reshape(x, shape)


def unblock(x: jnp.ndarray, axis: int = -3) -> jnp.ndarray:
    """Inverse of :func:`block`: merge (nb, n) axes."""
    shape = list(x.shape)
    axis = axis % x.ndim
    shape[axis : axis + 2] = [shape[axis] * shape[axis + 1]]
    return jnp.reshape(x, shape)


def shift_blocks(xb: jnp.ndarray, offset: int, block_axis: int = -3) -> jnp.ndarray:
    """Return ``yb[i] = xb[i + offset]`` with zero padding out of range.

    ``offset=-1`` gives each block its left neighbour ("prev"),
    ``offset=+1`` the right neighbour ("next").
    """
    axis = block_axis % xb.ndim
    nb = xb.shape[axis]
    if offset == 0:
        return xb
    pad = [(0, 0)] * xb.ndim
    if offset > 0:
        pad[axis] = (0, offset)
        sl = [slice(None)] * xb.ndim
        sl[axis] = slice(offset, offset + nb)
    else:
        pad[axis] = (-offset, 0)
        sl = [slice(None)] * xb.ndim
        sl[axis] = slice(0, nb)
    return jnp.pad(xb, pad)[tuple(sl)]


def quadrant_mask(nq: int, nk: int, kind: str) -> jnp.ndarray:
    """Boolean (nq, nk) mask of *allowed* entries for level >= 1 blocks.

    ``kind='sub'``  : query block I attends key block I-1.  Excluded:
        queries in the first half of their span x keys in the last half
        of the previous block (covered at the finer level).
    ``kind='super'``: query block I attends key block I+1.  Excluded:
        last-half queries x first-half keys.

    ``nq`` may exceed ``nk`` (fine-query causal path): the query half is
    measured against ``nq``, the key half against ``nk``.
    """
    q = np.arange(nq)[:, None]
    k = np.arange(nk)[None, :]
    if kind == "sub":
        excl = (q < nq // 2) & (k >= nk // 2)
    elif kind == "super":
        excl = (q >= nq // 2) & (k < nk // 2)
    else:
        raise ValueError(kind)
    return jnp.asarray(~excl)


def causal_block_mask(n: int) -> jnp.ndarray:
    """Lower-triangular (n, n) allowed-mask for level-0 diagonal blocks."""
    return jnp.asarray(np.tril(np.ones((n, n), dtype=bool)))


def interp_repeat(x: jnp.ndarray, factor: int, axis: int = -2) -> jnp.ndarray:
    """Piecewise-constant prolongation P^(l) (Eq. 38-40): repeat rows."""
    if factor == 1:
        return x
    return jnp.repeat(x, factor, axis=axis)


def level_assignment_map(L: int, nr: int, causal: bool = False) -> np.ndarray:
    """(L, L) int map: level at which pair (i, j) is attended; -1 = never.

    Pure-numpy specification of the partition used by property tests and
    the dense reference oracle.
    """
    M = num_levels(L, nr)
    i = np.arange(L)[:, None]
    j = np.arange(L)[None, :]
    out = np.full((L, L), -1, dtype=np.int64)
    for l in range(max(M, 1) - 1, -1, -1):
        span = nr * (1 << l)
        bi, bj = i // span, j // span
        out[np.abs(bi - bj) <= 1] = l
    if causal:
        out[j > i] = -1
    return out
