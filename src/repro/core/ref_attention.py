"""Dense reference oracles for H-Transformer-1D attention.

Two independent implementations used by tests and benchmarks:

* :func:`dense_attention` -- standard O(L^2) softmax attention (the
  paper's baseline Transformer attention, Eq. 1-6).
* :func:`h1d_dense_oracle` -- O(L^2) *dense reconstruction* of the exact
  hierarchical approximation: builds the per-level coarse similarity
  matrices, expands them back to the fine grid (Eq. 49-51) with the
  disjoint partition masks, and normalizes.  Must match
  ``h1d_attention`` to float tolerance for every mode.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from . import hierarchy as hc

NEG_INF = hc.NEG_INF


def dense_attention(q, k, v, *, causal=False, kv_weight=None,
                    softmax_scale=None):
    """q: (B, G, Lq, D); k, v: (B, Lk, Dv). Standard softmax attention.
    Supports rectangular (cross-) attention; ``causal`` requires
    Lq == Lk."""
    B, G, Lq, D = q.shape
    kv_g = k.ndim == 4
    Lk = k.shape[-2]
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    s = jnp.einsum("bgqd,bgkd->bgqk" if kv_g else "bgqd,bkd->bgqk",
                   q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    allow = jnp.ones((B, 1, Lq, Lk), bool)
    if kv_weight is not None:
        allow = jnp.logical_and(allow, (kv_weight > 0)[:, None, None, :])
    if causal:
        assert Lq == Lk, "causal dense attention requires square shapes"
        allow = jnp.logical_and(allow, np.tril(np.ones((Lq, Lk), bool)))
    s = jnp.where(allow, s, NEG_INF)
    m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s - m)
    num = jnp.einsum("bgqk,bgkv->bgqv" if kv_g else "bgqk,bkv->bgqv",
                     a, v.astype(jnp.float32))
    den = a.sum(-1, keepdims=True)
    return (num / jnp.maximum(den, 1e-9)).astype(v.dtype)


# ---------------------------------------------------------------------------
# level masks in coarse coordinates (independent re-derivation)
# ---------------------------------------------------------------------------

def _level_mask_coarse(Lc: int, nr: int, level: int, causal: bool) -> np.ndarray:
    """Allowed-mask over coarse pairs (a, b), both at level ``level``."""
    a = np.arange(Lc)[:, None]
    b = np.arange(Lc)[None, :]
    blk_a, blk_b = a // nr, b // nr
    if level == 0:
        m = np.abs(blk_a - blk_b) <= 1
        if causal:
            m &= b <= a
    else:
        diff = blk_a - blk_b
        m = (diff == 1) if causal else (np.abs(diff) == 1)
        # exclude pairs covered at level-1: children block distance <= 1
        child_blk_a = (2 * a) // nr
        child_blk_b = (2 * b) // nr
        m &= np.abs(child_blk_a - child_blk_b) >= 2
    return m


def _level_mask_fine_q(L: int, Lc: int, nr: int, level: int) -> np.ndarray:
    """Allowed-mask over (fine query i, coarse key b) for fine-q causal."""
    span = nr * (1 << level)
    i = np.arange(L)[:, None]
    b = np.arange(Lc)[None, :]
    blk_i = i // span          # query block at this level
    blk_b = b // nr            # key block (coarse coords)
    m = (blk_i - blk_b) == 1   # strict sub-diagonal
    s_i = (i % span) < span // 2      # query in first half of its span
    s_b = (b % nr) >= nr // 2         # key in last half of its block
    m &= ~(s_i & s_b)
    return m


def _expand(x, frow: int, fcol: int):
    if frow > 1:
        x = jnp.repeat(x, frow, axis=-2)
    if fcol > 1:
        x = jnp.repeat(x, fcol, axis=-1)
    return x


def h1d_dense_oracle(q, k, v, *, nr=16, causal=False, causal_mode="fine-q",
                     kv_weight=None, softmax_scale=None):
    """Dense reconstruction of h1d_attention.  Same signature semantics."""
    B, G, L, D = q.shape
    M = hc.num_levels(L, nr)
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    f32 = jnp.float32
    q = q.astype(f32) * scale
    k = k.astype(f32)
    v = v.astype(f32)
    w = (jnp.ones((B, L), f32) if kv_weight is None
         else jnp.broadcast_to(kv_weight.astype(f32), (B, L)))
    v = v * w[..., None]

    if M == 0:
        return dense_attention(q, k, v, causal=causal, kv_weight=kv_weight,
                               softmax_scale=1.0).astype(v.dtype)

    fine_q = causal and causal_mode == "fine-q"
    # build the combined fine-grid log-similarity matrix; per-level masked
    # supports are disjoint by the partition rule, so elementwise max works.
    s_total = jnp.full((B, G, L, L), NEG_INF, f32)
    kc, wc, qc, wq = k, w, q, w
    for l in range(M):
        if l > 0:
            kc, _ = hc.coarsen_weighted_mean(kc, wc)
            wc = hc.coarsen_sum(wc, axis=-1)
            if not fine_q:
                qc, _ = hc.coarsen_weighted_mean(qc, wq)
                wq = hc.coarsen_sum(wq, axis=-1)
        Lc = kc.shape[-2]
        if fine_q or l == 0:
            s = jnp.einsum("bgqd,bkd->bgqk", q if l else qc, kc)
            mask = (_level_mask_fine_q(L, Lc, nr, l) if l
                    else _level_mask_coarse(L, nr, 0, causal))
            s = jnp.where(jnp.asarray(mask)[None, None], s, NEG_INF)
            s = jnp.where((wc > 0)[:, None, None, :], s, NEG_INF)
            s = _expand(s, 1, 1 << l)
        else:
            s = jnp.einsum("bgqd,bkd->bgqk", qc, kc)
            mask = _level_mask_coarse(Lc, nr, l, causal)
            s = jnp.where(jnp.asarray(mask)[None, None], s, NEG_INF)
            s = jnp.where((wc > 0)[:, None, None, :], s, NEG_INF)
            s = _expand(s, 1 << l, 1 << l)
        s_total = jnp.maximum(s_total, s)

    m = jnp.maximum(s_total.max(-1, keepdims=True), -1e30)
    a = jnp.exp(s_total - m)
    num = jnp.einsum("bgqk,bkv->bgqv", a, v)
    den = jnp.einsum("bgqk,bk->bgq", a, w)[..., None]
    return (num / jnp.maximum(den, 1e-9)).astype(v.dtype)
