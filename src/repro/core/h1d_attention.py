"""H-Transformer-1D hierarchical attention (paper core, pure JAX).

Public entry points
-------------------
``h1d_attention(q, k, v, ...)``
    Core operator.  ``q``: (B, G, L, D), ``k``/``v``: (B, L, D) where the
    caller folds ``batch * kv_heads`` into B and the GQA group size into
    G (G=1 for MHA).  Returns (B, G, L, Dv).

``h1d_attention_mha(q, k, v, ...)``
    Convenience wrapper over (B, L, H, D) / (B, L, Hkv, D) layouts.

Modes
-----
* ``causal=False``              -- paper-faithful encoder attention
  (symmetric coarsening of Q, K, V; Eq. 25-29).
* ``causal=True, mode='coarse-q'`` -- paper-style decoder attention with
  coarsened queries.  NOTE: coarse query rows average embeddings of
  *future* tokens inside a cluster, so attention **weights** leak future
  information.  Kept as the paper-faithful reference; see DESIGN.md.
* ``causal=True, mode='fine-q'``   -- leak-free variant (default): fine
  queries attend coarse keys/values.  Exactly consistent with the
  hierarchical KV-cache incremental decode in ``h1d_decode.py``.

All softmax arithmetic runs in float32 with a cross-level stable max:
each level's band contribution is folded into ONE running fine-resolution
``(y, dn, m)`` accumulator as soon as it is computed (streaming
log-sum-exp combine, ``_stream_combine``) -- no per-level tensors are
kept live.  With ``impl='pallas*'`` every level runs a fused kernel:
level 0 via the symmetric band modes and each coarse fine-q level via
``mode='sub'`` (fine queries x shifted coarse KV blocks).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from . import hierarchy as hc

NEG_INF = hc.NEG_INF
_MIN_M = -1e30  # clamp for row-max so fully-masked rows yield zero weight


# ---------------------------------------------------------------------------
# banded block attention at a single level
# ---------------------------------------------------------------------------

def _band_terms(qb, terms, *, f32=jnp.float32):
    """Attention of query blocks against a list of key-block bands.

    qb: (B, G, NB, NQ, D)
    terms: list of (kb, vb, wb, mask) with
        kb: (B, NB, NK, D) or (B, G, NB, NK, D) (per-head KV),
        vb likewise, wb: (B, NB, NK),
        mask: (NQ, NK) bool allowed-mask or None.
    Returns Y: (B, G, NB, NQ, Dv), Dn: (B, G, NB, NQ), m: (B, G, NB, NQ).
    """
    sims = []
    for kb, vb, wb, mask in terms:
        kv_g = kb.ndim == qb.ndim
        s = jnp.einsum("bgnqd,bgnkd->bgnqk" if kv_g else "bgnqd,bnkd->bgnqk",
                       qb, kb, preferred_element_type=f32)
        valid = wb > 0  # (B, NB, NK)
        allow = valid[:, None, :, None, :]
        if mask is not None:
            allow = jnp.logical_and(allow, mask[None, None, None])
        s = jnp.where(allow, s, NEG_INF)
        sims.append(s)

    m = jnp.maximum(
        jnp.max(jnp.stack([s.max(axis=-1) for s in sims], 0), axis=0), _MIN_M
    )
    y = None
    dn = None
    for (kb, vb, wb, mask), s in zip(terms, sims):
        kv_g = kb.ndim == qb.ndim
        a = jnp.exp(s - m[..., None])
        yt = jnp.einsum("bgnqk,bgnkv->bgnqv" if kv_g else "bgnqk,bnkv->bgnqv",
                        a, vb.astype(f32), preferred_element_type=f32)
        dt = jnp.einsum("bgnqk,bnk->bgnq", a, wb.astype(f32),
                        preferred_element_type=f32)
        y = yt if y is None else y + yt
        dn = dt if dn is None else dn + dt
    return y, dn, m


# ---------------------------------------------------------------------------
# single-level contributions
# ---------------------------------------------------------------------------

def _level_fine_q(qb, kb, vb, wb):
    """Level >= 1, fine queries (leak-free causal).  qb: (B,G,NB,NQ,D)
    with NQ = nr * 2**l fine queries per block; kb: (B,NB,nr,Dk)."""
    nr = kb.shape[-2]
    terms = [
        (hc.shift_blocks(kb, -1), hc.shift_blocks(vb, -1),
         hc.shift_blocks(wb, -1, block_axis=-2),
         hc.quadrant_mask(qb.shape[-2], nr, "sub")),
    ]
    return _band_terms(qb, terms)


# ---------------------------------------------------------------------------
# full operator
# ---------------------------------------------------------------------------

def _stream_combine(acc, yl, dl, ml):
    """Fold one level's (Y, D, m) into the running fine-resolution
    accumulator with a log-sum-exp shift.

    Streaming replacement for the old list-based ``_combine_levels``:
    each level is merged as soon as its band kernel returns, so the
    operator keeps ONE (y, dn, m) triple live instead of materializing
    all M per-level tensors in HBM and merging at the end (DESIGN.md
    section 1.3; EXPERIMENTS.md P24 has the traffic accounting).
    """
    y, d, m = acc
    m_new = jnp.maximum(m, ml)
    e_acc = jnp.exp(m - m_new)
    e_l = jnp.exp(ml - m_new)
    return (y * e_acc[..., None] + yl * e_l[..., None],
            d * e_acc + dl * e_l, m_new)


def h1d_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    nr: int = 16,
    causal: bool = False,
    causal_mode: str = "fine-q",
    kv_weight: Optional[jnp.ndarray] = None,
    softmax_scale: Optional[float] = None,
    impl: str = "jnp",
    tq: Optional[int] = None,
) -> jnp.ndarray:
    """Hierarchical attention.  See module docstring for shapes/modes.

    ``impl``: banded-level backend -- ``'jnp'`` (blocked XLA; default and
    the dry-run path), ``'pallas'`` (fused TPU kernel),
    ``'pallas_interpret'`` (kernel body on CPU, for validation) or
    ``'auto'`` (backend-resolved by the process ``KernelPolicy``).
    ``tq``: Pallas query-tile rows override (multiple of nr); ``None``
    lets the policy's tuning table pick per level.

    ``k``/``v`` may be (B, L, Dk) (shared across G) or (B, G, L, Dk)
    (per-head KV -- the GSPMD-friendly layout: the head axis flows
    through every einsum unchanged).
    """
    from repro.kernels.tuning import get_policy
    impl = get_policy().resolve_impl(impl)
    B, G, L, D = q.shape
    kv_g = k.ndim == 4
    if kv_g:
        assert k.shape[:3] == (B, G, L) and v.shape[:3] == (B, G, L)
        assert impl == "jnp", "per-head KV layout is the XLA path"
    else:
        assert k.shape == (B, L, k.shape[-1]) and v.shape[:2] == (B, L)
    if impl in ("pallas", "pallas_interpret"):
        # sequence-parallel dispatch: inside an sp_scope(mesh) region,
        # shard the WHOLE hierarchy over the data axis (local kernels +
        # one packed halo ppermute per direction + a gathered tail for
        # the deep levels).  Shapes whose local slab cannot hold an
        # nr-row block stay on the single-launch kernel path.
        from repro.parallel.sp_attention import sp_ctx, sp_h1d_attention
        ctx = sp_ctx()
        if ctx is not None:
            d = dict(ctx[0].shape).get(ctx[1], 1)
            if L % d == 0 and (L // d) % nr == 0 and L // d >= nr:
                return sp_h1d_attention(
                    q, k, v, mesh=ctx[0], axis=ctx[1], nr=nr, causal=causal,
                    causal_mode=causal_mode, kv_weight=kv_weight,
                    softmax_scale=softmax_scale, impl=impl, tq=tq)
    M = hc.num_levels(L, nr)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    f32 = jnp.float32
    out_dtype = v.dtype

    from repro.kernels.ops import band_attention

    q = q.astype(f32) * scale
    k = k.astype(f32)
    v = v.astype(f32)
    w = (jnp.ones((B, L), f32) if kv_weight is None
         else jnp.broadcast_to(kv_weight.astype(f32), (B, L)))
    wv = w[:, None, :, None] if kv_g else w[..., None]
    v = v * wv

    if M == 0:  # single block: exact dense attention
        s = jnp.einsum("bgqd,bgkd->bgqk" if kv_g else "bgqd,bkd->bgqk",
                       q, k, preferred_element_type=f32)
        allow = (w > 0)[:, None, None, :]
        if causal:
            allow = jnp.logical_and(allow, hc.causal_block_mask(L)[None, None])
        s = jnp.where(allow, s, NEG_INF)
        m = jnp.maximum(s.max(-1, keepdims=True), _MIN_M)
        a = jnp.exp(s - m)
        z = jnp.einsum("bgqk,bgkv->bgqv" if kv_g else "bgqk,bkv->bgqv",
                       a, v) / jnp.maximum(
            jnp.einsum("bgqk,bk->bgq", a, w), 1e-9)[..., None]
        return z.astype(out_dtype)

    # ---- level 0 seeds the streaming accumulator --------------------------
    acc = band_attention(
        q, k, v, w, nr=nr, mode="l0_causal" if causal else "l0_bidir",
        impl=impl, tq=tq)

    fine_q = causal and causal_mode == "fine-q"
    kc, vc, wc = k, v, w
    qc, wq = q, w
    for l in range(1, M):
        kc, _ = hc.coarsen_weighted_mean(kc, wc)
        vc = hc.coarsen_sum(vc, axis=-2)
        wc = hc.coarsen_sum(wc, axis=-1)
        if fine_q:
            if impl in ("pallas", "pallas_interpret"):
                # fused fine-q level: fine query tiles x shifted coarse
                # KV blocks, one kernel launch per level
                yl, dl, ml = band_attention(
                    q, kc, vc, wc, nr=nr, mode="sub", ratio=1 << l,
                    impl=impl, tq=tq)
            else:
                # fine queries grouped per coarse key block (jnp oracle;
                # the deep-level einsums are already MXU-shaped)
                qbl = hc.block(q, nr * (1 << l))
                ylb, dlb, mlb = _level_fine_q(
                    qbl, hc.block(kc, nr), hc.block(vc, nr),
                    hc.block(wc, nr, axis=-1))
                yl = hc.unblock(ylb, axis=-3)
                dl = hc.unblock(dlb, axis=-2)
                ml = hc.unblock(mlb, axis=-2)
        else:
            # paper-faithful: coarsen queries too (weighted mean)
            qc, _ = hc.coarsen_weighted_mean(qc, wq)
            wq = hc.coarsen_sum(wq, axis=-1)
            yl, dl, ml = band_attention(
                qc, kc, vc, wc, nr=nr,
                mode="coarse_causal" if causal else "coarse_bidir",
                impl=impl, tq=tq)
            rep = 1 << l
            yl = hc.interp_repeat(yl, rep, axis=-2)
            dl = hc.interp_repeat(dl, rep, axis=-1)
            ml = hc.interp_repeat(ml, rep, axis=-1)
        acc = _stream_combine(acc, yl, dl, ml)

    y, d, _ = acc
    z = y / jnp.maximum(d, 1e-9)[..., None]
    return z.astype(out_dtype)


def fold_kv_heads(q, k, v):
    """(B, L, Hq, D) / (B, L, Hkv, Dk) -> the core (B*Hkv, G, L, *)
    layout: kv-heads fold into the batch dim and the GQA group size
    into G (kv_head = h // G).  Shared by every kernel-path caller so
    the head-ordering convention cannot drift.  Returns
    (qh, kh, vh, (B, Hkv, G))."""
    B, L, Hq, D = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    # (B, L, Hq, D) -> (B, Hkv, G, L, D) -> (B*Hkv, G, L, D)
    qh = q.reshape(B, L, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    qh = qh.reshape(B * Hkv, G, L, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, L, k.shape[-1])
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, L, v.shape[-1])
    return qh, kh, vh, (B, Hkv, G)


def unfold_kv_heads(z, fold):
    """Inverse of :func:`fold_kv_heads` for the (B*Hkv, G, L, Dv)
    output: returns (B, L, Hq, Dv)."""
    B, Hkv, G = fold
    L = z.shape[-2]
    z = z.reshape(B, Hkv, G, L, -1).transpose(0, 3, 1, 2, 4)
    return z.reshape(B, L, Hkv * G, -1)


def h1d_attention_mha(
    q: jnp.ndarray,      # (B, L, Hq, D)
    k: jnp.ndarray,      # (B, L, Hkv, D)
    v: jnp.ndarray,      # (B, L, Hkv, Dv)
    **kwargs,
) -> jnp.ndarray:
    """GQA-aware multi-head wrapper: folds (B, Hkv) into the core batch dim
    and the Hq/Hkv group size into G.  Returns (B, L, Hq, Dv)."""
    B, L = q.shape[:2]
    qh, kh, vh, fold = fold_kv_heads(q, k, v)
    Hkv = fold[1]
    kw = kwargs.pop("kv_weight", None)
    if kw is not None:
        kw = jnp.repeat(jnp.broadcast_to(kw, (B, L)), Hkv, axis=0)
    z = h1d_attention(qh, kh, vh, kv_weight=kw, **kwargs)
    return unfold_kv_heads(z, fold)
