"""Shared symmetric int8 quantization.

One rounding rule for every int8 surface in the repo -- the paged
KV-cache pages (``serve/paged_cache.py`` + the quantized decode
kernels) and the cross-pod gradient compressor
(``optim/compression.py``) both call these helpers, so a change to the
scale floor or the rounding mode shows up in ONE place and is pinned by
``tests/test_optim.py::test_int8_rounding_shared_across_call_sites``.

The scheme is plain symmetric absmax quantization:

    scale = max(|x|) / 127        (floored at EPS so all-zero tensors
                                   quantize to q=0, scale=EPS/127)
    q     = clip(round(x / scale), -127, 127)  as int8
    deq   = float32(q) * scale

``round`` is jnp.round = round-half-to-even, which is what both call
sites historically used; -128 is never produced, keeping the code
symmetric (q(-x) == -q(x)) and the dequantized range balanced.

``axis`` selects the scale granularity: ``None`` gives one scale per
tensor (the gradient-compression wire format), an int/tuple gives one
scale per slice along the remaining axes (the KV-cache uses
``axis=-1``: one scale per cached row, so a single outlier token cannot
wash out its page-mates' precision).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax.numpy as jnp

QMAX = 127.0
# The scale is computed as ``amax * (1/127)`` -- a multiply by this
# f32-rounded constant, NOT a divide: XLA's fast-math pipeline rewrites
# divides-by-constant into reciprocal multiplies inside fused kernels
# but not in eagerly dispatched ops, so a divide here would leave the
# jnp oracle and the Pallas kernels one ulp apart on the stored scales.
RECIP_QMAX = 1.0 / 127.0
# Scale floor: keeps x/scale finite for all-zero inputs.  Small enough
# that any real activation/gradient dominates it.
EPS = 1e-12

Axis = Optional[Union[int, Tuple[int, ...]]]


def int8_scale(x, axis: Axis = None):
    """Symmetric absmax scale of ``x`` over ``axis`` (keepdims)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, EPS) * RECIP_QMAX


def quantize_int8(x, axis: Axis = None):
    """Returns ``(q int8, scale f32)``.  ``scale`` keeps reduced dims
    (size 1) when ``axis`` is given, so ``q * scale`` broadcasts back."""
    x = jnp.asarray(x, jnp.float32)
    scale = int8_scale(x, axis=axis)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale
