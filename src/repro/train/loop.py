"""Training loop: jit'd train step factory, gradient accumulation,
cross-pod gradient compression hook, checkpoint/restart, watchdog.

``make_train_step`` builds a single pjit-able function
``(state, batch) -> (state, metrics)`` -- this is also exactly what the
multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.models import ModelConfig, get_model
from repro.optim import (
    adamw, adafactor, apply_updates, cosine_schedule, init_error_feedback,
    int8_compress, Optimizer)


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    ef_state: Optional[Any]   # error-feedback residual (grad compression)


@dataclasses.dataclass
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 200
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    optimizer: str = "adamw"          # adamw | adafactor
    grad_accum: int = 1
    compress_grads: str = "none"      # none | int8 | topk
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 500
    log_every: int = 10
    seed: int = 0
    watchdog_factor: float = 3.0      # straggler alarm threshold
    # attention backend overrides (None = keep the ModelConfig value);
    # setting attn_impl='pallas' runs BOTH passes of EVERY banded level
    # on the fused kernels (forward + hand-written backward) -- including
    # the causal_mode='fine-q' coarse levels, which lower to the 'sub'
    # kernel, so a default-config causal train step is kernel-complete.
    attn_impl: Optional[str] = None   # auto | jnp | pallas | pallas_interpret
    attn_tq: Optional[int] = None     # Pallas query-tile rows override
                                      # (None = KernelPolicy tuning table)
    attn_causal_mode: Optional[str] = None  # fine-q | coarse-q


def resolve_model_config(cfg: ModelConfig, tc: "TrainConfig") -> ModelConfig:
    """Apply the TrainConfig attention-backend overrides to ``cfg``."""
    updates = {}
    if tc.attn_impl is not None:
        updates["attn_impl"] = tc.attn_impl
    if tc.attn_tq is not None:
        updates["attn_tq"] = tc.attn_tq
    if tc.attn_causal_mode is not None:
        updates["causal_mode"] = tc.attn_causal_mode
    return dataclasses.replace(cfg, **updates) if updates else cfg


def make_optimizer(tc: TrainConfig) -> Optimizer:
    sched = cosine_schedule(tc.peak_lr, tc.warmup, tc.total_steps)
    if tc.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched, weight_decay=tc.weight_decay,
                 clip_norm=tc.clip_norm)


def init_state(key, cfg: ModelConfig, tc: TrainConfig):
    cfg = resolve_model_config(cfg, tc)
    fns = get_model(cfg)
    params, specs = fns.init(key, cfg)
    opt = make_optimizer(tc)
    ef = (init_error_feedback(params)
          if tc.compress_grads != "none" else None)
    return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params),
                      ef), specs


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient accumulation is a lax.scan over microbatches (the leading
    batch dim is split); compute/comm overlap between the microbatch
    gradient psums is XLA's latency-hiding scheduler's job, enabled via
    mesh flags in launch/mesh.py.
    """
    cfg = resolve_model_config(cfg, tc)
    fns = get_model(cfg)
    opt = make_optimizer(tc)

    def loss_fn(params, batch):
        loss, metrics = fns.loss(params, cfg, batch)
        return loss, metrics

    def train_step(state: TrainState, batch):
        if tc.grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((tc.grad_accum,
                                     x.shape[0] // tc.grad_accum)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), metrics = jax.lax.scan(acc, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / tc.grad_accum, gsum)
            loss = lsum / tc.grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)

        ef = state.ef_state
        if tc.compress_grads == "int8":
            grads, ef = int8_compress(grads, ef)

        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(state.step + 1, params, opt_state, ef)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


class Watchdog:
    """Step-time straggler detector: EMA of step latency; flags (and
    counts) steps slower than ``factor`` x the EMA.  On a real cluster the
    callback would trigger hot-spare swap / re-scheduling; here it logs."""

    def __init__(self, factor: float = 3.0):
        self.factor = factor
        self.ema: Optional[float] = None
        self.alarms = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.alarms += int(slow)
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return slow


def train(cfg: ModelConfig, tc: TrainConfig, data_source, num_steps: int,
          *, state=None, log=print):
    """Single-host driver with checkpoint/restart; the multi-pod driver in
    launch/train.py wraps this with mesh + sharded batches."""
    from . import checkpoint as ckpt

    key = jax.random.PRNGKey(tc.seed)
    if state is None:
        state, _ = init_state(key, cfg, tc)
        start = ckpt.latest_step(tc.ckpt_dir)
        if start is not None:
            state = ckpt.restore(tc.ckpt_dir, start, state)
            log(f"[restart] resumed from step {start}")
    step0 = int(state.step)
    train_step = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))
    saver = ckpt.AsyncCheckpointer(tc.ckpt_dir)
    wd = Watchdog(tc.watchdog_factor)
    metrics = {}
    for step in range(step0, num_steps):
        batch = jax.tree.map(jnp.asarray, data_source.batch(step))
        t0 = time.perf_counter()
        with obs.span("train.step", tid=obs.TRACK_TRAIN,
                      args={"step": step}):
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if obs.enabled():
            obs.counter("train.steps").inc()
            obs.histogram("train.step_s").observe(dt)
            obs.gauge("train.loss").set(float(metrics["loss"]))
        if wd.observe(dt):
            obs.counter("train.watchdog_alarms").inc()
            log(f"[watchdog] step {step} took {dt:.3f}s "
                f"(ema {wd.ema:.3f}s) -- straggler suspected")
        if step % tc.log_every == 0:
            log(f"step {step}: loss={float(metrics['loss']):.4f} "
                f"({dt*1e3:.1f} ms)")
        if tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
            saver.save(step + 1, state)
    saver.wait()
    return state, metrics
