"""Training loop, checkpointing, fault tolerance."""
from .loop import (TrainState, TrainConfig, make_train_step, init_state,
                   train, Watchdog, make_optimizer, resolve_model_config)
from . import checkpoint
