"""Sharded, atomic, reshardable checkpointing (orbax unavailable offline).

Layout per step::

    <dir>/step_000100.tmp/      # written first
        manifest.json           # tree structure, dtypes, shapes, step
        arr_00000.npy ...       # one file per leaf (host-local full value)
    <dir>/step_000100/          # atomic rename on completion
        ...
        COMMIT                  # marker written last

Fault-tolerance properties:
* a crash mid-write leaves only a ``.tmp`` dir -- ``latest_step`` ignores
  it, restart resumes from the previous complete checkpoint;
* restore is *resharding*: arrays are loaded as host values and
  ``jax.device_put`` onto whatever mesh/sharding the restarted job uses,
  so the job can come back elastically on a different device count;
* saves can run on a background thread (``async_save``) so the train loop
  only blocks on the previous save (checkpoint never stalls steps).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [v for _, v in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (p, v) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(v))
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            best = max(best or 0, int(m.group(1)))
    return best


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` (a pytree
    of Sharding or a single Sharding) is given, device_put accordingly --
    this is the elastic resharding path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None and not hasattr(
                        shardings, "device_set") else None)
    for i, (p, ref) in enumerate(zip(paths, leaves)):
        e = by_path[p]
        arr = np.load(os.path.join(path, e["file"]))
        if shardings is None:
            out.append(jax.device_put(arr))
        elif hasattr(shardings, "device_set"):
            out.append(jax.device_put(arr, shardings))
        else:
            out.append(jax.device_put(arr, shard_leaves[i]))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Background-thread saver; blocks only if a save is still running."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _run():
            try:
                save(self.dir, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.dir))
            if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
