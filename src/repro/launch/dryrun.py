import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and dump memory/cost/collective analyses.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models import get_model, set_mesh_axes
from repro.models.common import ModelConfig
from repro.parallel import (param_shardings, batch_shardings,
                            cache_shardings, replicated)
from repro.train import TrainConfig, make_train_step, TrainState

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../artifacts/dryrun")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt):
    """Sum byte sizes of all array shapes in an HLO result-type string."""
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Per-collective wire-byte estimates from optimized HLO.

    For each op we estimate bytes crossing a link per participating
    device with ring formulas (documented in EXPERIMENTS.md):
      all-reduce: 2 (n-1)/n * size ; all-gather: (n-1)/n * size(out)
      reduce-scatter: (n-1)/n * size(in) ~ (n-1) * size(out)
      all-to-all / collective-permute: size
    Returns dict kind -> {count, result_bytes, wire_bytes}.
    """
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
           for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\([^)]*\)|\S+) ([\w\-]+)", ls)
        if not m:
            continue
        kind_raw = m.group(2)
        kind = next((k for k in COLLECTIVES
                     if kind_raw == k or kind_raw.startswith(k + ".")), None)
        if kind is None or "-start" in kind_raw and False:
            continue
        size = _shape_bytes(m.group(1))
        n = 1
        g = _GROUPS_RE.search(ls)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_RE2.search(ls)
            if g2:
                n = int(g2.group(2))
        if kind == "all-reduce":
            wire = 2 * (n - 1) / max(n, 1) * size
        elif kind == "all-gather":
            wire = (n - 1) / max(n, 1) * size
        elif kind == "reduce-scatter":
            wire = (n - 1) * size
        else:
            wire = size
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += size
        out[kind]["wire_bytes"] += wire
    return out


def _shardings_for_tree(mesh, tree, spec_tree=None):
    if spec_tree is not None:
        return param_shardings(mesh, spec_tree)
    return jax.tree.map(lambda _: replicated(mesh), tree)


def build_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (fn, arg_structs, in_shardings[, out_shardings])."""
    kind, seq, batch = S.cell(cfg, shape_name)
    fns = get_model(cfg)
    pstruct = S.param_struct(cfg)
    pspecs = S.param_specs(cfg)
    psh = param_shardings(mesh, pspecs)

    if kind == "train":
        from repro.train import make_optimizer
        from repro.optim import AdamWState
        tc = TrainConfig()
        bstruct = S.train_batch_specs(cfg, seq, batch)
        bsh = batch_shardings(mesh, bstruct)
        opt_like = jax.eval_shape(lambda p: make_optimizer(tc).init(p),
                                  pstruct)
        state_struct = TrainState(
            jax.ShapeDtypeStruct((), jnp.int32), pstruct, opt_like, None)
        # optimizer moments mirror the param sharding; step replicated
        opt_sh = AdamWState(replicated(mesh), psh, psh)
        state_sh = TrainState(replicated(mesh), psh, opt_sh, None)
        step_fn = make_train_step(cfg, tc)
        return step_fn, (state_struct, bstruct), (state_sh, bsh)

    if kind == "prefill":
        bstruct = S.prefill_batch_specs(cfg, seq, batch)
        bsh = batch_shardings(mesh, bstruct)
        fn = lambda p, b: fns.prefill(p, cfg, b, seq)
        return fn, (pstruct, bstruct), (psh, bsh)

    # decode
    caches, tok, t = S.decode_arg_specs(cfg, seq, batch)
    csh = cache_shardings(mesh, caches, batch=batch,
                          kv_heads=max(cfg.num_kv_heads, 1),
                          long_context=batch == 1,
                          num_layers=cfg.num_layers)
    toksh = (batch_shardings(mesh, tok) if batch > 1 else replicated(mesh))
    fn = lambda p, c, token, tt: fns.decode_step(p, cfg, c, token, tt)
    # pin the OUTPUT cache sharding too: otherwise XLA may pick a
    # different layout for the updated cache and round-trip it through
    # an all-to-all every step
    out_sh = (replicated(mesh), csh)
    return (fn, (pstruct, caches, tok, t), (psh, csh, toksh, toksh),
            out_sh)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR, cfg: ModelConfig = None,
             tag: str = ""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    label = f"{arch}__{shape_name}__{mesh_name}{tag}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, label + ".json")
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        set_mesh_axes(mesh.shape.get("model"))
        if cfg is None:
            cfg = get_config(arch)
        built = build_cell(cfg, shape_name, mesh)
        fn, args, in_sh = built[:3]
        out_sh = built[3] if len(built) > 3 else None
        with jax.set_mesh(mesh):
            jit_kw = {"in_shardings": in_sh}
            if out_sh is not None:
                jit_kw["out_shardings"] = out_sh
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        rec["cost"] = {k: float(v) for k, v in dict(cost).items()
                       if isinstance(v, (int, float))}
        rec["hlo_bytes"] = len(hlo)
        rec["seconds"] = time.time() - t0
        rec["num_devices"] = int(mesh.size)
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["seconds"] = time.time() - t0
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else "FAIL"
    print(f"[dryrun] {label}: {status} ({rec['seconds']:.1f}s)", flush=True)
    if not rec["ok"]:
        print(rec["error"], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))   # [False, True] default

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    ARTIFACT_DIR, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
                rec = run_cell(arch, shape, mp)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
