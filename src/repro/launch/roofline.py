import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Roofline analysis per (arch x shape) on the single-pod 16x16 mesh.

Methodology (documented in EXPERIMENTS.md section Roofline):
XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so scanned layer
stacks would be undercounted.  We therefore lower each cell twice with a
python-loop layer stack at depths (u, 2u) -- u = the arch's cadence unit
(1 for homogeneous stacks, 6 for gemma3/zamba2) -- and extrapolate:

    total(L) = c(u) + (L/u - 1) * (c(2u) - c(u))

which is exact for homogeneous/periodic stacks.  Collective wire bytes
come from the optimized per-device HLO of the same unrolled compiles
(ring formulas; see dryrun.parse_collectives).

Terms (TPU v5e constants in launch/mesh.py):
    compute   = flops_per_device / PEAK_FLOPS_BF16
    memory    = bytes_per_device / HBM_BW
    collective= wire_bytes_per_device / ICI_BW

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--arch A] [--shape S]
Artifacts: artifacts/roofline/<arch>__<shape>.json (+ summary table)
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                               HBM_BW, ICI_BW)
from repro.launch import specs as S
from repro.launch.dryrun import build_cell, parse_collectives
from repro.models import set_mesh_axes

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../artifacts/roofline")
CHIPS = 256


def cadence_unit(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.hybrid_attn_every
    if cfg.global_every > 0:
        return cfg.global_every
    return 1


def _depth_cfg(cfg, layers: int):
    kw = dict(num_layers=layers, force_loop=True)
    if cfg.family == "encdec":
        kw["encoder_layers"] = layers
    return dataclasses.replace(cfg, **kw)


def _measure(cfg, shape_name, mesh):
    fn, args, in_sh = build_cell(cfg, shape_name, mesh)
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    wire = sum(v["wire_bytes"] for v in coll.values())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": wire,
        "collectives": coll,
    }


def param_count(cfg):
    struct = S.param_struct(cfg)
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(struct)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if cfg.moe_experts and any(w in keys for w in
                                   ("/moe/w1", "/moe/w2", "/moe/w3")):
            active += n * cfg.moe_top_k / cfg.moe_experts
        else:
            active += n
    return total, active


def _encdec_split(cfg):
    """(enc_params, dec_params) from the param tree paths."""
    struct = S.param_struct(cfg)
    enc = dec = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if keys.startswith("encoder"):
            enc += n
        else:
            dec += n
    return enc, dec


def model_flops(cfg, shape_name):
    """6*N*D train / 2*N*D per decode token (active params for MoE;
    enc/dec split by the tokens each stack actually processes)."""
    seq, batch, kind = SHAPES[shape_name]
    total, active = param_count(cfg)
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    if cfg.family == "encdec":
        from repro.configs.seamless_m4t_medium import DECODER_LEN
        enc, dec = _encdec_split(cfg)
        dec_tokens = batch * (min(DECODER_LEN, seq) if kind != "decode"
                              else 1)
        enc_tokens = batch * seq if kind != "decode" else 0
        enc_mult = 2.0 if kind == "prefill" else (6.0 if kind == "train"
                                                  else 2.0)
        return enc_mult * enc * enc_tokens + mult * dec * dec_tokens
    if kind == "train":
        return 6.0 * active * batch * seq
    if kind == "prefill":
        return 2.0 * active * batch * seq
    return 2.0 * active * batch          # one token per sequence


def analyze_cell(arch: str, shape_name: str, cfg=None, tag=""):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    set_mesh_axes(mesh.shape.get("model"))
    if cfg is None:
        cfg = get_config(arch)
    u = cadence_unit(cfg)
    L = cfg.num_layers
    rec = {"arch": arch, "shape": shape_name, "tag": tag, "ok": False,
           "unit": u, "num_layers": L}
    t0 = time.time()
    try:
        c1 = _measure(_depth_cfg(cfg, u), shape_name, mesh)
        c2 = _measure(_depth_cfg(cfg, 2 * u), shape_name, mesh)
        reps = L / u - 1.0
        tot = {k: c1[k] + reps * (c2[k] - c1[k])
               for k in ("flops", "bytes", "wire_bytes")}
        terms = {
            "compute_s": tot["flops"] / PEAK_FLOPS_BF16,
            "memory_s": tot["bytes"] / HBM_BW,
            "collective_s": tot["wire_bytes"] / ICI_BW,
        }
        dom = max(terms, key=terms.get)
        mf = model_flops(cfg, shape_name)
        hlo_global = tot["flops"] * CHIPS
        rec.update({
            "per_device": tot,
            "per_layer_unit": {k: c2[k] - c1[k]
                               for k in ("flops", "bytes", "wire_bytes")},
            "collectives_depth2": c2["collectives"],
            "terms_s": terms,
            "dominant": dom,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_fraction": (max(terms.values()) and
                                  terms["compute_s"] / max(terms.values())),
            "seconds": time.time() - t0,
            "ok": True,
        })
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["seconds"] = time.time() - t0
    out = os.path.join(ARTIFACT_DIR, f"{arch}__{shape_name}{tag}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else "FAIL"
    if rec["ok"]:
        t = rec["terms_s"]
        print(f"[roofline] {arch}__{shape_name}{tag}: {status} "
              f"compute={t['compute_s']*1e3:.2f}ms "
              f"memory={t['memory_s']*1e3:.2f}ms "
              f"coll={t['collective_s']*1e3:.2f}ms dom={rec['dominant']} "
              f"useful={rec['useful_ratio']:.2f} ({rec['seconds']:.0f}s)",
              flush=True)
    else:
        print(f"[roofline] {arch}__{shape_name}{tag}: FAIL {rec['error']}",
              flush=True)
    return rec


def summarize(out_path=None):
    rows = []
    for fname in sorted(os.listdir(ARTIFACT_DIR)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(ARTIFACT_DIR, fname)) as f:
            r = json.load(f)
        if r.get("ok") and not r.get("tag"):
            t = r["terms_s"]
            rows.append((r["arch"], r["shape"], t["compute_s"],
                         t["memory_s"], t["collective_s"], r["dominant"],
                         r["useful_ratio"]))
    lines = ["| arch | shape | compute (ms) | memory (ms) | collective (ms)"
             " | dominant | useful ratio |",
             "|---|---|---|---|---|---|---|"]
    for a, s, c, m, co, d, u in rows:
        lines.append(f"| {a} | {s} | {c*1e3:.2f} | {m*1e3:.2f} | "
                     f"{co*1e3:.2f} | {d.replace('_s','')} | {u:.2f} |")
    table = "\n".join(lines)
    if out_path:
        with open(out_path, "w") as f:
            f.write(table + "\n")
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--summary", action="store_true")
    args = ap.parse_args()
    if args.summary:
        print(summarize())
        return
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            path = os.path.join(ARTIFACT_DIR, f"{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        continue
            analyze_cell(arch, shape)
    print(summarize())


if __name__ == "__main__":
    main()
