"""ShapeDtypeStruct input builders for every (arch x shape) dry-run cell.

``input_specs(cfg, shape_name)`` returns ``(kind, args, shardings_fn)``
where ``args`` are ShapeDtypeStructs (no allocation) for the lowered
function and ``shardings_fn(mesh)`` produces the matching in_shardings.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs import SHAPES
from repro.configs.seamless_m4t_medium import DECODER_LEN
from repro.models import ModelConfig, get_model


def train_batch_specs(cfg: ModelConfig, seq: int, batch: int) -> Dict:
    i32 = jnp.int32
    if cfg.family == "encdec":
        return {"frames": SDS((batch, seq, cfg.d_model), cfg.jdtype),
                "tokens": SDS((batch, min(DECODER_LEN, seq)), i32)}
    if cfg.family == "vlm":
        toks = max(seq - cfg.prefix_len, cfg.nr)
        return {"tokens": SDS((batch, toks), i32),
                "patch_embeds": SDS((batch, cfg.prefix_len, cfg.d_model),
                                    cfg.jdtype)}
    return {"tokens": SDS((batch, seq), i32)}


def prefill_batch_specs(cfg: ModelConfig, seq: int, batch: int) -> Dict:
    return train_batch_specs(cfg, seq, batch)


def decode_arg_specs(cfg: ModelConfig, seq: int, batch: int):
    """Returns (caches_struct, token_struct, t_struct)."""
    fns = get_model(cfg)
    i32 = jnp.int32
    if cfg.family == "encdec":
        # caches come from prefill (need encoder memory shapes)
        batch_specs = {
            "frames": SDS((batch, seq, cfg.d_model), cfg.jdtype),
            "tokens": SDS((batch, min(DECODER_LEN, seq)), i32)}
        _, caches, _ = jax.eval_shape(
            lambda p, b: fns.prefill(p, cfg, b, min(DECODER_LEN, seq)),
            param_struct(cfg), batch_specs)
    else:
        caches = jax.eval_shape(
            lambda p: fns.init_caches(p, cfg, batch, seq), param_struct(cfg))
    return caches, SDS((batch,), i32), SDS((batch,), i32)


_PSTRUCT_CACHE: Dict[Tuple, Tuple] = {}


def _param_struct_cached(cfg: ModelConfig):
    import dataclasses
    key = dataclasses.astuple(cfg)
    if key not in _PSTRUCT_CACHE:
        fns = get_model(cfg)
        captured = {}

        def f(k):
            p, s = fns.init(k, cfg)
            captured["specs"] = s
            return p

        struct = jax.eval_shape(f, jax.random.PRNGKey(0))
        _PSTRUCT_CACHE[key] = (struct, captured["specs"])
    return _PSTRUCT_CACHE[key]


def param_struct(cfg: ModelConfig):
    return _param_struct_cached(cfg)[0]


def param_specs(cfg: ModelConfig):
    return _param_struct_cached(cfg)[1]


def cell(cfg: ModelConfig, shape_name: str):
    """Returns (kind, seq, batch)."""
    seq, batch, kind = SHAPES[shape_name]
    return kind, seq, batch
