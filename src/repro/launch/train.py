"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch h1d-lm-53m \
        --steps 200 --batch 8 --seq 512 [--smoke] [--mesh 1x1]

On a real cluster this process runs per host under
``jax.distributed.initialize()``; here the same code drives whatever
devices exist.  Features: sharded state, checkpoint/restart (atomic +
resharding), gradient accumulation, optional cross-pod gradient
compression, watchdog straggler alarms.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import ZipfLM, HierarchicalLM, Prefetcher
from repro.launch.mesh import make_mesh
from repro.models import set_mesh_axes
from repro.parallel import param_shardings
from repro.train import (TrainConfig, TrainState, init_state,
                         make_train_step, Watchdog, checkpoint as ckpt)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h1d-lm-53m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--data", default="zipf", choices=["zipf", "hier"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "jnp", "pallas", "pallas_interpret"],
                    help="banded-attention backend override (both passes "
                         "run on the fused kernels for 'pallas'; 'auto' "
                         "resolves per backend via the KernelPolicy)")
    ap.add_argument("--attn-tq", type=int, default=None,
                    help="Pallas query-tile rows override (multiple of "
                         "nr; default: the KernelPolicy tuning table)")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel attention: shard L over the "
                         "'data' axis and run the fused band kernels per "
                         "shard (shard_map halo exchange); pairs with "
                         "--attn-impl pallas for long-sequence training")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable repro.obs metrics + train-step spans "
                         "(implied by --trace-out)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON "
                         "(Perfetto-loadable) at exit")
    args = ap.parse_args(argv)

    from repro import obs
    if args.telemetry or args.trace_out:
        obs.enable()

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model")[:len(dshape)] if
                     len(dshape) == 2 else ("data",))
    set_mesh_axes(mesh.shape.get("model"))

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    tc = TrainConfig(peak_lr=args.lr, total_steps=args.steps,
                     warmup=max(10, args.steps // 20),
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     grad_accum=args.grad_accum,
                     compress_grads=args.compress, seed=args.seed,
                     attn_impl=args.attn_impl, attn_tq=args.attn_tq)

    src_cls = ZipfLM if args.data == "zipf" else HierarchicalLM
    data = src_cls(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   batch_per_host=args.batch, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    from repro.launch.mesh import use_mesh
    with use_mesh(mesh):
        state, specs = init_state(key, cfg, tc)
        psh = param_shardings(mesh, specs)
        state = TrainState(
            state.step,
            jax.tree.map(jax.device_put, state.params, psh),
            state.opt_state, state.ef_state)

        start = ckpt.latest_step(tc.ckpt_dir) if args.ckpt_every else None
        if start is not None:
            state = ckpt.restore(tc.ckpt_dir, start, state)
            print(f"[restart] resumed from step {start}")

        raw_step = make_train_step(cfg, tc)
        if args.sp:
            # enter the SP scope while TRACING, so every kernel-path
            # attention call shards its sequence axis over 'data'
            from repro.parallel import sp_scope

            def sp_step(state, batch, _inner=raw_step):
                with sp_scope(mesh, "data"):
                    return _inner(state, batch)
            raw_step = sp_step
        step_fn = jax.jit(raw_step, donate_argnums=(0,))
        saver = ckpt.AsyncCheckpointer(tc.ckpt_dir)
        wd = Watchdog()
        pre = Prefetcher(data, start_step=int(state.step))
        try:
            for step in range(int(state.step), args.steps):
                batch = jax.tree.map(jnp.asarray, pre.next())
                t0 = time.perf_counter()
                with obs.span("train.step", tid=obs.TRACK_TRAIN,
                              args={"step": step}):
                    state, metrics = step_fn(state, batch)
                    loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if obs.enabled():
                    obs.counter("train.steps").inc()
                    obs.histogram("train.step_s").observe(dt)
                    obs.gauge("train.loss").set(loss)
                if wd.observe(dt):
                    obs.counter("train.watchdog_alarms").inc()
                    print(f"[watchdog] slow step {step}: {dt:.2f}s")
                if step % 10 == 0 or step == args.steps - 1:
                    print(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f} ms)")
                if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                    saver.save(step + 1, state)
        finally:
            pre.close()
        saver.wait()
    if args.trace_out:
        obs.export.write_trace(args.trace_out)
        print(f"[train] telemetry: trace -> {args.trace_out}")
    print("[train] done")
    return state


if __name__ == "__main__":
    main()
