"""Launchers: mesh construction, multi-pod dry-run, roofline, train/serve.

NOTE: do NOT import dryrun/roofline from here -- they set XLA_FLAGS on
import and must be invoked as entry points (python -m repro.launch.dryrun).
"""
from .mesh import make_production_mesh, make_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW
