"""Production mesh construction.

Defined as functions (not module constants) so importing never touches
jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to fabricate the device pool; real deployments get the same mesh
from the actual TPU topology.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import axis_type_kwargs


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **axis_type_kwargs(len(axes)))


def use_mesh(mesh):
    """Version-compat mesh context: ``jax.set_mesh`` (jax >= 0.6) or the
    ``Mesh`` object's own context manager (0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# TPU v5e-ish hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
