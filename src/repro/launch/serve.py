"""Serving driver: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 8 --slots 4 --new-tokens 16

On a production mesh the same engine runs under jax.set_mesh with the
decode-cache shardings from repro.parallel (the dry-run proves those
lower); this driver exercises the engine on local devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import get_model
from repro.serve import ServeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--decode-impl", default=None,
                    choices=["auto", "jnp", "pallas", "pallas_interpret"],
                    help="h1d decode tick backend (pallas = fused "
                         "single-launch kernels; 'auto' resolves per "
                         "backend; default: cfg.decode_impl)")
    ap.add_argument("--sp-data", type=int, default=1,
                    help="sequence-parallel degree: shard the "
                         "hierarchical KV cache over an N-way 'data' "
                         "axis and run the fused decode kernels per "
                         "shard (shard_map halo exchange)")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged hierarchical cache pool "
                         "(prefix sharing + copy-on-write + preemption; "
                         "serve/paged_cache.py) instead of one dense "
                         "max-len cache per slot")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged pool size in nr-row level-0 pages "
                         "(default: dense-equivalent slots*Lmax/nr)")
    ap.add_argument("--cache-dtype", default=None,
                    choices=["fp32", "int8"],
                    help="paged KV-page storage dtype (int8: symmetric "
                         "per-row scales, ~4x pages at fixed HBM; "
                         "requires --paged)")
    ap.add_argument("--quant-levels", type=int, default=None,
                    help="with --cache-dtype int8: quantize hierarchy "
                         "levels [0, n) only (default -1 = all levels)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="continuous-batching per-tick token budget "
                         "(decode slots + admitted prefill chunks)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admit long prompts on their first N tokens; "
                         "the tail streams through decode ticks")
    ap.add_argument("--lookahead", type=int, default=0,
                    help="admission skip-ahead window past a "
                         "head-of-queue that does not fit")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable repro.obs metrics + serve-tick spans "
                         "(implied by --trace-out / --prom-out / "
                         "--metrics-jsonl)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON "
                         "(Perfetto-loadable) at exit")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition at exit")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append periodic metrics snapshots as JSON "
                         "lines while serving")
    ap.add_argument("--metrics-period", type=float, default=10.0,
                    help="--metrics-jsonl emission period in seconds")
    args = ap.parse_args(argv)

    from repro import obs
    telemetry = (args.telemetry or args.trace_out or args.prom_out
                 or args.metrics_jsonl)
    if telemetry:
        obs.enable()
    emitter = (obs.export.JsonlEmitter(args.metrics_jsonl,
                                       args.metrics_period)
               if args.metrics_jsonl else None)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    fns = get_model(cfg)
    params, _ = fns.init(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.sp_data > 1:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((args.sp_data,), ("data",))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      greedy=not args.sample, decode_impl=args.decode_impl,
                      mesh=mesh, paged=args.paged, pool_pages=args.pool_pages,
                      cache_dtype=args.cache_dtype,
                      quant_levels=args.quant_levels,
                      token_budget=args.token_budget,
                      prefill_chunk=args.prefill_chunk,
                      lookahead=args.lookahead)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(8, 32))
        ).astype(np.int32)
        r = Request(uid=i, prompt=prompt, max_new_tokens=args.new_tokens)
        reqs.append(r)
        eng.submit(r)
    t0 = time.perf_counter()
    if emitter is None:
        eng.run()
    else:
        while eng.queue or eng.active.any():
            eng.step()
            emitter.maybe_emit()
        emitter.emit()       # short runs still get >= 1 line
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total} tokens, {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    if args.paged:
        st = eng.pool.stats
        print(f"[serve] paged: shared={st.shared_maps} cow={st.cow_copies} "
              f"evict={st.evictions} preempt={eng.preemptions} "
              f"hit_rate={st.prefix_hit_rate():.2f}")
    if telemetry:
        if args.trace_out:
            obs.export.write_trace(args.trace_out)
            print(f"[serve] telemetry: trace -> {args.trace_out}")
        if args.prom_out:
            obs.export.write_prometheus(args.prom_out)
            print(f"[serve] telemetry: prometheus -> {args.prom_out}")
        c = obs.export.snapshot()["metrics"]["counters"]
        print(f"[serve] telemetry: ticks={c.get('serve.ticks', 0)} "
              f"finished={c.get('serve.finished', 0)}")
    return reqs


if __name__ == "__main__":
    main()
