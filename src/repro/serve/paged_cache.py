"""Paged hierarchical KV-cache pool: vLLM-style block-pool memory
management specialized to the H-Matrix cache layout (DESIGN.md
section 8).

The dense serving cache pins ``Lmax`` rows (plus the coarse pyramid)
per slot, so HBM -- not FLOPs -- caps concurrency.  This module carves
every level of the hierarchical cache into PAGES of ``nr`` level-l rows
and manages them with:

* a host-side allocator (:class:`PagePool`): per-level free lists,
  per-request page tables, refcounts;
* hierarchical prefix sharing: a page's content is a pure function of
  the token prefix up to the end of its span (clamped to the prompt),
  so a registry keyed by ``(level, block, clamped_len, prefix_hash)``
  lets requests with a common prompt prefix map the SAME physical pages
  -- including each shared subtree's ancestor rows, which are pairwise
  means/sums of the same prefix and therefore bit-identical too;
* copy-on-write: pages are COW'd lazily on the first divergent write
  (the per-tick ancestor update touches exactly one page per level --
  the one whose span contains ``t``), so identical prompts share even
  their incomplete frontier pages until generation actually diverges;
* eviction: pages whose refcount drops to zero but that remain in the
  prefix registry park on an LRU list and are reclaimed on demand;
* preemption hooks: when the pool is exhausted the engine releases a
  victim's pages via :func:`PagePool.release_slot` and requeues it
  (recompute-on-resume, ``serve/scheduler.py``).

Two logical pages per level are reserved: ``ZERO`` (page 0, never
written -- fresh decode pages are initialized by copying it, which keeps
paged pools bit-identical to the zero-initialized dense cache) and
``TRASH`` (page 1 -- inactive engine rows point their update tables at
it, making their in-kernel writes inert without any extra masking).

Physical layout: a logical page covers all ``Hkv`` kv-head rows of its
request, so the device pools have ``num_pages * Hkv`` pool rows and
logical page ``p`` owns rows ``[p*Hkv, (p+1)*Hkv)``; the tick tables
handed to the kernels are already physical (``page * Hkv + head``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy as hc
from repro.core import h1d_decode as hd
from repro.core import quantization as qz


class PoolExhausted(RuntimeError):
    """Raised by the allocator when a level's free list and evictable
    list are both empty; the engine answers with preemption."""

    def __init__(self, level: int):
        super().__init__(f"page pool exhausted at level {level}")
        self.level = level


ZERO = 0      # reserved all-zeros page (never written)
TRASH = 1     # reserved write sink for inactive engine rows


@dataclasses.dataclass
class PoolStats:
    """Monotonic pool counters.  ``prefix_hits``/``prefix_misses``
    count LOOKUPS against the prefix registry during prefix-sharing
    admissions (one per page span), so ``prefix_hit_rate()`` is a true
    rate; ``shared_maps`` keeps counting the hit *mappings* for
    backward compatibility (equal to ``prefix_hits`` in practice)."""
    cow_copies: int = 0
    evictions: int = 0
    shared_maps: int = 0
    fresh_pages: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def prefix_hit_rate(self) -> float:
        """Registry hit rate over prefix-sharing admissions (0.0 when
        no sharing-eligible lookup has happened)."""
        lookups = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / lookups if lookups else 0.0


class PagePool:
    """Host-side allocator for the paged hierarchical cache.

    All bookkeeping is numpy/python -- the device only ever sees the
    zeroed pools, batched page copies, prefill scatters, and the small
    per-tick indirection tables.
    """

    def __init__(self, *, slots: int, max_len: int, nr: int,
                 pool_pages: int, coarse_pages: Optional[Sequence[int]] = None,
                 quant_levels: int = 0):
        self.nr = nr
        self.Lp = hc.padded_length(max_len, nr)
        self.M = max(hc.num_levels(self.Lp, nr), 1)   # levels incl. fine
        self.slots = slots
        # dtype identity per level: levels < quant_levels store int8
        # pages with per-row scales.  The tag participates in the
        # prefix-registry keys (see _span_keys) -- it IS part of a
        # page's content identity.
        if quant_levels < 0:
            quant_levels = self.M
        self.quant_levels = min(quant_levels, self.M)
        self.quant = [l < self.quant_levels for l in range(self.M)]
        self.level_dtypes = ["int8:rowscale" if q else "f32"
                             for q in self.quant]
        # logical blocks per level: level l rows (Lp >> l) in nr-row pages
        self.nblocks = [(self.Lp >> l) // nr for l in range(self.M)]
        if pool_pages < 1:
            raise ValueError("pool_pages must be >= 1")
        sizes = [min(pool_pages, slots * self.nblocks[0])]
        for l in range(1, self.M):
            if coarse_pages is not None:
                sizes.append(coarse_pages[l - 1])
            else:
                # keep capacity proportional to the fine pool but never
                # below one page per slot (every request needs >= 1 page
                # per level regardless of its length)
                sizes.append(min(max(slots, pool_pages >> l),
                                 slots * self.nblocks[l]))
        self.num_pages = [s + 2 for s in sizes]          # + ZERO/TRASH
        self.free: List[List[int]] = [
            list(range(n - 1, 1, -1)) for n in self.num_pages]
        self.refcount = [np.zeros(n, np.int32) for n in self.num_pages]
        self.table = [np.full((slots, nb), -1, np.int32)
                      for nb in self.nblocks]
        # prefix-sharing registry: key -> (level, page); the reverse map
        # tells a writer whether its exclusively-owned page is still
        # advertised (and must be unregistered before mutation)
        self.registry: Dict[tuple, Tuple[int, int]] = {}
        self.key_of: Dict[Tuple[int, int], tuple] = {}
        # refcount-0 pages kept alive only by the registry, LRU order
        self.evictable: "OrderedDict[Tuple[int, int], None]" = OrderedDict()
        self.stats = PoolStats()

    # -- capacity ------------------------------------------------------
    def usable(self, l: int) -> int:
        return self.num_pages[l] - 2

    def used(self, l: int) -> int:
        ev = sum(1 for (ll, _) in self.evictable if ll == l)
        return self.usable(l) - len(self.free[l]) - ev

    def available(self, l: int) -> int:
        """Pages obtainable without preemption (free + evictable)."""
        return self.usable(l) - self.used(l)

    def occupancy(self) -> float:
        tot = sum(self.usable(l) for l in range(self.M))
        return sum(self.used(l) for l in range(self.M)) / max(tot, 1)

    def pages_needed(self, S: int) -> List[int]:
        """Per-level page count covering an S-token prompt."""
        return [max(1, -(-S // (self.nr << l))) for l in range(self.M)]

    def net_need(self, tokens: np.ndarray, *,
                 share: bool = True) -> List[int]:
        """Per-level page need for this prompt, net of prefix-registry
        hits (pages an admission would actually have to allocate)."""
        if not share:
            return self.pages_needed(len(tokens))
        return [sum(1 for key in keys if key not in self.registry)
                for keys in self._span_keys(tokens)]

    def can_admit(self, tokens: np.ndarray, *, share: bool = True) -> bool:
        """Conservative availability probe: needed-minus-shared per
        level against free + evictable."""
        return all(nn <= self.available(l) for l, nn in
                   enumerate(self.net_need(tokens, share=share)))

    # -- registry / refcount internals ---------------------------------
    def _span_keys(self, tokens: np.ndarray) -> List[List[tuple]]:
        """Registry keys for every (level, block) the prompt covers:
        ``(l, dtype_tag, blk, clamped_len, digest)`` where the digest is
        a CHAINED sha1 over the prefix bytes -- each level hashes the
        prompt once (O(S) per level, not O(S^2/nr) re-hashes per span),
        and a cryptographic digest makes a cross-prompt collision (which
        would silently serve another request's KV pages) a non-event,
        unlike Python's 64-bit ``hash``.

        ``dtype_tag`` is the level's page dtype + scale-granularity
        identity (``level_dtypes``): a page's bytes are a function of
        the prefix AND the storage format, so a registry persisted or
        re-primed across a ``cache_dtype``/``quant_levels`` config
        change must never hand an fp32-era page to an int8 pool (or
        vice versa)."""
        S = len(tokens)
        out: List[List[tuple]] = []
        for l, need in enumerate(self.pages_needed(S)):
            span = self.nr << l
            tag = self.level_dtypes[l]
            h = hashlib.sha1()
            keys = []
            for blk in range(need):
                n = min((blk + 1) * span, S)
                h.update(tokens[blk * span:n].tobytes())
                keys.append((l, tag, blk, n, h.copy().digest()))
            out.append(keys)
        return out

    def _alloc(self, l: int) -> int:
        if self.free[l]:
            return self.free[l].pop()
        for key2 in self.evictable:            # LRU: oldest first
            if key2[0] == l:
                self._unregister(l, key2[1])
                self.evictable.pop(key2)
                self.stats.evictions += 1
                return key2[1]
        raise PoolExhausted(l)

    def _unregister(self, l: int, page: int) -> None:
        key = self.key_of.pop((l, page), None)
        if key is not None:
            self.registry.pop(key, None)

    def _map(self, slot: int, l: int, blk: int, page: int) -> None:
        self.table[l][slot, blk] = page
        if self.refcount[l][page] == 0:
            self.evictable.pop((l, page), None)
        self.refcount[l][page] += 1

    def _decref(self, l: int, page: int) -> None:
        self.refcount[l][page] -= 1
        assert self.refcount[l][page] >= 0
        if self.refcount[l][page] == 0:
            if (l, page) in self.key_of:
                self.evictable[(l, page)] = None       # park, reclaimable
            else:
                self.free[l].append(page)

    def _maybe_check(self, *, slot: Optional[int] = None,
                     t: Optional[int] = None) -> None:
        """Opt-in runtime invariant mode (``REPRO_POOL_CHECK=1``): run
        the model checker's invariant functions after a mutating op, so
        fuzzing and ``analysis/pool_model.py`` share ONE invariant
        definition.  ``slot``/``t`` additionally run the tick write-set
        postconditions."""
        if not os.environ.get("REPRO_POOL_CHECK"):
            return
        from repro.analysis import pool_model
        vs = pool_model.check_pool_invariants(self)
        if slot is not None and t is not None:
            vs += pool_model.check_tick_postconditions(self, slot, t)
        if vs:
            raise AssertionError(
                "REPRO_POOL_CHECK: pool invariant violated:\n"
                + "\n".join(f"  [{v.kind}] {v.operand}: {v.detail}"
                            for v in vs))

    # -- request lifecycle ---------------------------------------------
    def admit(self, slot: int, tokens: np.ndarray, *,
              share: bool = True) -> Dict[int, List[Tuple[int, int]]]:
        """Map pages covering the prompt into ``slot``'s tables.

        Returns per level the ``(block, page)`` pairs that MISSED the
        prefix registry -- the engine scatters the dense prefill output
        into exactly those pages (registry hits reuse the existing
        physical page, content already bit-identical).

        TRANSACTIONAL: on :class:`PoolExhausted` every map AND every
        registration this call made is rolled back before re-raising.
        Leaving a failed admission's registrations behind is a
        correctness bug, not a leak -- the pages' content is only
        written by the engine's scatter AFTER a successful admit, so a
        stale key would serve GARBAGE to the next prompt that hashes to
        it (typically the same request retrying next tick).
        """
        assert not (self.table[0][slot] >= 0).any(), "slot not released"
        span_keys = self._span_keys(tokens) if share else None
        writes: Dict[int, List[Tuple[int, int]]] = {}
        placed: List[Tuple[int, int, int, Optional[tuple]]] = []
        try:
            for l, need in enumerate(self.pages_needed(len(tokens))):
                wl = []
                for blk in range(need):
                    key = span_keys[l][blk] if share else None
                    hit = self.registry.get(key) if share else None
                    if hit is not None:
                        self._map(slot, l, blk, hit[1])
                        placed.append((l, blk, hit[1], None))
                        self.stats.shared_maps += 1
                        self.stats.prefix_hits += 1
                    else:
                        p = self._alloc(l)
                        self._map(slot, l, blk, p)
                        self.stats.fresh_pages += 1
                        if share:
                            self.stats.prefix_misses += 1
                        wl.append((blk, p))
                        placed.append((l, blk, p, key))
                        if share:
                            self.registry[key] = (l, p)
                            self.key_of[(l, p)] = key
                writes[l] = wl
        except PoolExhausted:
            for l, blk, p, key in placed:
                if key is not None:
                    self._unregister(l, p)
                self.table[l][slot, blk] = -1
                self._decref(l, p)
            self._maybe_check()
            raise
        self._maybe_check()
        return writes

    def release_slot(self, slot: int) -> None:
        """Drop all of a slot's mappings (finish or preemption).
        Registered pages survive on the evictable LRU for future
        prefix hits; private pages return to the free lists."""
        for l in range(self.M):
            row = self.table[l][slot]
            for blk in np.nonzero(row >= 0)[0]:
                self._decref(l, int(row[blk]))
            row[:] = -1
        self._maybe_check()

    def admit_snapshot(self, slot: int,
                       blocks: Dict[int, Sequence[int]],
                       ) -> Dict[int, List[Tuple[int, int]]]:
        """Re-map a preempted slot's snapshotted blocks onto fresh
        PRIVATE pages (no registry sharing -- see :func:`restore_slot`
        for why).  Returns per level the ``(block, page)`` pairs in
        block order so the caller can scatter the saved bytes back.
        Raises :class:`PoolExhausted` with the partial mapping LEFT IN
        PLACE -- the caller unwinds with :func:`release_slot`."""
        out: Dict[int, List[Tuple[int, int]]] = {}
        for l, blks in blocks.items():
            pairs = []
            for b in blks:
                p = self._alloc(l)
                self._map(slot, l, int(b), p)
                pairs.append((int(b), p))
            out[l] = pairs
        self._maybe_check()
        return out

    def prepare_tick(self, slot: int, t: int,
                     copies: Dict[int, List[Tuple[int, int]]]) -> None:
        """Make the write-set of position ``t`` (one page per level: the
        page whose span contains ``t``) present and private.

        Fresh pages are zero-initialized by a ZERO-page copy; shared
        pages are COW'd; exclusively-owned pages still advertised in the
        prefix registry are unregistered (their content is about to
        change).  Device copies accumulate into ``copies`` (level ->
        list of (src_page, dst_page)) so a retry after
        :class:`PoolExhausted` + preemption never loses copies already
        scheduled."""
        for l in range(self.M):
            blk = t // (self.nr << l)
            p = int(self.table[l][slot, blk])
            if p < 0:
                np_ = self._alloc(l)
                self._map(slot, l, blk, np_)
                self.stats.fresh_pages += 1
                copies.setdefault(l, []).append((ZERO, np_))
            elif self.refcount[l][p] > 1:
                np_ = self._alloc(l)
                copies.setdefault(l, []).append((p, np_))
                self.table[l][slot, blk] = -1
                self._decref(l, p)
                self._map(slot, l, blk, np_)
                self.stats.cow_copies += 1
            elif (l, p) in self.key_of:
                self._unregister(l, p)
        self._maybe_check(slot=slot, t=t)

    # -- per-tick device tables ----------------------------------------
    def build_tables(self, pos: np.ndarray, active: np.ndarray,
                     Hkv: int) -> hd.PageTables:
        """Physical indirection tables for one decode tick.

        ``pos``: (slots,) host positions; ``active``: (slots,) bool.
        Inactive rows point at TRASH everywhere (attend output is
        discarded, update writes are inert)."""
        nr, M = self.nr, self.M
        R = self.slots * Hkv
        nbands = 2 + (M - 1)
        attend = np.full((R, nbands), TRASH * Hkv, np.int32)
        update = np.full((R, M), TRASH * Hkv, np.int32)
        heads = np.arange(Hkv, dtype=np.int32)
        for s in range(self.slots):
            rows = slice(s * Hkv, (s + 1) * Hkv)
            attend[rows] += heads[:, None]
            update[rows] += heads[:, None]
            if not active[s]:
                continue
            t = int(pos[s])
            b0 = t // nr
            pages = np.empty((nbands,), np.int32)
            pages[0] = self.table[0][s, b0]
            pages[1] = self.table[0][s, b0 - 1] if b0 >= 1 else TRASH
            for l in range(1, M):
                Il = t // (nr << l)
                pages[1 + l] = (self.table[l][s, Il - 1] if Il >= 1
                                else TRASH)
            upages = np.array(
                [self.table[l][s, t // (nr << l)] for l in range(M)],
                np.int32)
            assert (pages >= 0).all() and (upages >= 0).all(), \
                (s, t, pages, upages)
            attend[rows] = pages[None, :] * Hkv + heads[:, None]
            update[rows] = upages[None, :] * Hkv + heads[:, None]
        return hd.PageTables(attend=jnp.asarray(attend),
                             update=jnp.asarray(update))


# ---------------------------------------------------------------------------
# device-side pool construction and data movement
# ---------------------------------------------------------------------------

def init_paged_caches(cfg, pool: PagePool):
    """Model-level paged caches mirroring ``lm_init_decode_caches``:
    one :class:`~repro.core.h1d_decode.PagedH1DCache` per layer, leaves
    stacked over layers for scan-able stacks (the engine's slot axis
    then being 1, as for the dense cache).  A pool with quantized
    levels (``quant_levels > 0``) yields ``QuantPagedH1DCache`` leaves:
    int8 pages + per-row f32 scale arrays, the dtype split read off
    ``pool.quant`` so the pool object stays the single source of
    storage-format truth."""
    from repro.models.transformer import _stacked_caches
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    rows = [n * Hkv for n in pool.num_pages]
    if any(pool.quant):
        one = hd.init_quant_paged_pool(rows, pool.nr, Dh, Dh, cfg.jdtype,
                                       quant=tuple(pool.quant))
    else:
        one = hd.init_paged_pool(rows, pool.nr, Dh, Dh, cfg.jdtype)
    if _stacked_caches(cfg):
        return jax.tree.map(
            lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), one)
    return [one for _ in range(cfg.num_layers)]


def _quant_flags(cache) -> Tuple[bool, ...]:
    if isinstance(cache, hd.QuantPagedH1DCache):
        return tuple(bool(a.dtype == jnp.int8) for a in (cache.k, *cache.ck))
    return (False,) * (1 + len(cache.ck))


def _per_level(cache, fn, sfn=None):
    """Apply ``fn(level, k_arr, v_arr) -> (k, v)`` to every level's
    data arrays.  For a :class:`~repro.core.h1d_decode.QuantPagedH1DCache`
    the per-row scale arrays (same leading physical-row axes) go through
    ``sfn(level, ksc, vsc) -> (ksc, vsc)`` -- or pass unchanged when
    ``sfn`` is None."""
    k, v = fn(0, cache.k, cache.v)
    ck, cv = [], []
    for i, (a, b) in enumerate(zip(cache.ck, cache.cv)):
        a2, b2 = fn(i + 1, a, b)
        ck.append(a2)
        cv.append(b2)
    if not isinstance(cache, hd.QuantPagedH1DCache):
        return hd.PagedH1DCache(k=k, v=v, ck=tuple(ck), cv=tuple(cv))
    ksc, vsc = cache.ksc, cache.vsc
    cksc, cvsc = list(cache.cksc), list(cache.cvsc)
    if sfn is not None:
        ksc, vsc = sfn(0, ksc, vsc)
        for i in range(len(cksc)):
            cksc[i], cvsc[i] = sfn(i + 1, cksc[i], cvsc[i])
    return hd.QuantPagedH1DCache(k=k, v=v, ck=tuple(ck), cv=tuple(cv),
                                 ksc=ksc, vsc=vsc,
                                 cksc=tuple(cksc), cvsc=tuple(cvsc))


def _map_layers(caches, stacked: bool, fn):
    if stacked:
        return fn(caches)
    return [fn(c) for c in caches]


def apply_copies(caches, copies: Dict[int, List[Tuple[int, int]]],
                 Hkv: int, stacked: bool):
    """Batched page copies (COW + zero-init): for each level, one
    gather/scatter over the expanded physical rows.  ``copies`` maps
    level -> [(src_page, dst_page)].

    A mid-tick preemption can free a page that already has a pending
    copy and hand it to a later allocation, which schedules its own
    copy to the SAME destination -- scatter order over duplicate indices
    is undefined, so only the LAST copy per destination is kept (the
    stale one targeted a page its owner no longer holds)."""
    if not copies:
        return caches
    idx = {}
    for l, pairs in copies.items():
        last = {d: s for s, d in pairs}          # last writer per dst
        pairs = [(s, d) for d, s in last.items()]
        src = np.concatenate([np.arange(Hkv) + s * Hkv for s, _ in pairs])
        dst = np.concatenate([np.arange(Hkv) + d * Hkv for _, d in pairs])
        idx[l] = (jnp.asarray(src), jnp.asarray(dst))

    def per_level(l, ka, va):
        if l not in idx:
            return ka, va
        src, dst = idx[l]
        if stacked:
            return (ka.at[:, dst].set(ka[:, src]),
                    va.at[:, dst].set(va[:, src]))
        return ka.at[dst].set(ka[src]), va.at[dst].set(va[src])

    # scale arrays share the physical-row axis, so the same row copy
    # applies (a page's scales travel with its int8 payload)
    return _map_layers(caches, stacked,
                       lambda c: _per_level(c, per_level, per_level))


def scatter_prefill(caches, dense_caches,
                    writes: List[Tuple[int, Dict[int, List[Tuple[int, int]]]]],
                    Hkv: int, nr: int, stacked: bool):
    """Copy freshly prefilled cache blocks into their allocated pages.

    ``dense_caches``: the group-prefill H1DCache (rows ``gp * Hkv``);
    ``writes``: per admitted request ``(dense_row_index, level ->
    [(block, page)])`` as returned by :func:`PagePool.admit`."""
    idx: Dict[int, Tuple[list, list, list]] = {}
    for i, per_level_writes in writes:
        for l, pairs in per_level_writes.items():
            rows, blks, dst = idx.setdefault(l, ([], [], []))
            for blk, page in pairs:
                for h in range(Hkv):
                    rows.append(i * Hkv + h)
                    blks.append(blk)
                    dst.append(page * Hkv + h)
    if not idx:
        return caches
    jidx = {l: tuple(jnp.asarray(np.asarray(a, np.int32)) for a in v)
            for l, v in idx.items()}

    def per_layer(pool_c, dense_c):
        dlv = [(dense_c.k, dense_c.v)] + list(zip(dense_c.ck, dense_c.cv))
        quant = _quant_flags(pool_c)

        def blocks(dense_arr):
            """Gather the written (..., nr, D) page blocks from the
            dense prefill cache."""
            rows, blks, _ = jidx[l_cur[0]]
            if stacked:
                NL, Rr, Ll, D = dense_arr.shape
                blkd = dense_arr.reshape(NL, Rr, Ll // nr, nr, D)
                return blkd[:, rows, blks]
            Rr, Ll, D = dense_arr.shape
            blkd = dense_arr.reshape(Rr, Ll // nr, nr, D)
            return blkd[rows, blks]

        l_cur = [0]

        def per_level(l, ka, va):
            if l not in jidx:
                return ka, va
            l_cur[0] = l
            dst = jidx[l][2]
            dk, dv = dlv[l]

            def put(pool_arr, dense_arr):
                vals = blocks(dense_arr)
                if quant[l]:
                    vals, _ = qz.quantize_int8(vals, axis=-1)
                if stacked:
                    return pool_arr.at[:, dst].set(vals)
                return pool_arr.at[dst].set(vals)

            return put(ka, dk), put(va, dv)

        def per_level_sc(l, ksa, vsa):
            # prefill scales: same absmax rule the decode kernel applies
            # to its in-place rewrites, so a prefix-shared page and a
            # decode-rebuilt page of the same tokens carry identical
            # scales
            if l not in jidx or not quant[l]:
                return ksa, vsa
            l_cur[0] = l
            dst = jidx[l][2]
            dk, dv = dlv[l]

            def put(sc_arr, dense_arr):
                sc = qz.int8_scale(blocks(dense_arr), axis=-1)[..., 0]
                if stacked:
                    return sc_arr.at[:, dst].set(sc)
                return sc_arr.at[dst].set(sc)

            return put(ksa, dk), put(vsa, dv)

        return _per_level(pool_c, per_level, per_level_sc)

    if stacked:
        return per_layer(caches, dense_caches)
    return [per_layer(c, d) for c, d in zip(caches, dense_caches)]


def snapshot_slot(caches, pool: PagePool, slot: int, Hkv: int,
                  stacked: bool) -> Dict[int, tuple]:
    """Swap-out a slot's mapped pages to host memory (preemption mode
    'swap'): per level ``(blocks, k_content, v_content, k_scales,
    v_scales)`` where the content arrays carry all layers (stacked
    leading dim) and all ``Hkv`` page rows per block -- enough to
    restore the slot bit-exact later, unlike recompute-resume whose
    re-prefill only matches the decode-built cache to ~1e-6.  For int8
    levels the content is the raw int8 payload plus its per-row scales;
    fp32 levels carry ``None`` scales."""
    snap: Dict[int, tuple] = {}
    layers = [caches] if stacked else list(caches)

    for l in range(pool.M):
        blks = np.nonzero(pool.table[l][slot] >= 0)[0]
        if len(blks) == 0:
            continue
        rows = np.concatenate(
            [np.arange(Hkv) + int(pool.table[l][slot, b]) * Hkv
             for b in blks])
        rj = jnp.asarray(rows)

        def lvl_arrays(c, l=l):
            return ((c.k, c.v) if l == 0
                    else (c.ck[l - 1], c.cv[l - 1]))

        def lvl_scales(c, l=l):
            return ((c.ksc, c.vsc) if l == 0
                    else (c.cksc[l - 1], c.cvsc[l - 1]))

        has_sc = isinstance(layers[0], hd.QuantPagedH1DCache) and \
            _quant_flags(layers[0])[l]
        if stacked:
            ka, va = lvl_arrays(caches)
            ks = np.asarray(ka[:, rj])
            vs = np.asarray(va[:, rj])
            kss = vss = None
            if has_sc:
                ksa, vsa = lvl_scales(caches)
                kss = np.asarray(ksa[:, rj])
                vss = np.asarray(vsa[:, rj])
        else:
            ks = np.stack([np.asarray(lvl_arrays(c)[0][rj])
                           for c in layers])
            vs = np.stack([np.asarray(lvl_arrays(c)[1][rj])
                           for c in layers])
            kss = vss = None
            if has_sc:
                kss = np.stack([np.asarray(lvl_scales(c)[0][rj])
                                for c in layers])
                vss = np.stack([np.asarray(lvl_scales(c)[1][rj])
                                for c in layers])
        snap[l] = (blks.astype(np.int64), ks, vs, kss, vss)
    return snap


def restore_slot(caches, pool: PagePool, slot: int, snap, Hkv: int,
                 stacked: bool):
    """Swap-in a preempted slot: allocate private pages for every
    snapshotted block (no registry sharing -- decode-written content is
    only ~1e-6-equal to a prefill of the same tokens, and restore must
    be bit-exact), map them, and scatter the saved bytes back.  Raises
    :class:`PoolExhausted` (caller unwinds with ``release_slot``).

    The snapshot's per-level dtype must MATCH the pool's: a snapshot
    taken under a different ``cache_dtype``/``quant_levels`` config is
    a different wire format (int8 payloads are meaningless without
    their scales and vice versa), so a mismatch raises ``ValueError``
    instead of silently scattering garbage."""
    first = caches if stacked else caches[0]
    lvl_dtype = [a.dtype for a in (first.k, *first.ck)]
    for l, entry in snap.items():
        ks = entry[1]
        if ks.dtype != lvl_dtype[l]:
            raise ValueError(
                f"snapshot level-{l} dtype {ks.dtype} cannot restore "
                f"into a {lvl_dtype[l]} pool -- cache_dtype/quant_levels "
                "changed between snapshot and restore")

    placed = pool.admit_snapshot(
        slot, {l: entry[0] for l, entry in snap.items()})
    per_level_rows = {
        l: np.concatenate([np.arange(Hkv) + p * Hkv for _, p in pairs])
        for l, pairs in placed.items()}

    def per_layer(c, li):
        def per_level(l, ka, va):
            if l not in snap:
                return ka, va
            _, ks, vs, _, _ = snap[l]
            dst = jnp.asarray(per_level_rows[l])
            if stacked:
                return (ka.at[:, dst].set(jnp.asarray(ks)),
                        va.at[:, dst].set(jnp.asarray(vs)))
            return (ka.at[dst].set(jnp.asarray(ks[li])),
                    va.at[dst].set(jnp.asarray(vs[li])))

        def per_level_sc(l, ksa, vsa):
            if l not in snap or snap[l][3] is None:
                return ksa, vsa
            _, _, _, kss, vss = snap[l]
            dst = jnp.asarray(per_level_rows[l])
            if stacked:
                return (ksa.at[:, dst].set(jnp.asarray(kss)),
                        vsa.at[:, dst].set(jnp.asarray(vss)))
            return (ksa.at[dst].set(jnp.asarray(kss[li])),
                    vsa.at[dst].set(jnp.asarray(vss[li])))

        return _per_level(c, per_level, per_level_sc)

    if stacked:
        return per_layer(caches, 0)
    return [per_layer(c, li) for li, c in enumerate(caches)]


def gather_slot_cache(caches, pool: PagePool, slot: int, Hkv: int,
                      stacked: bool):
    """Reconstruct a slot's DENSE H1DCache from its page tables
    (unmapped blocks read as zeros, exactly the dense engine's initial
    state).  Used by the parity tests and debugging tooling.  Quantized
    levels are DEQUANTIZED to f32 on the way out -- the dense H1DCache
    has no scale side-band, so this is the quantized pool's lossy view
    (exact for zero/never-written rows, one rounding step otherwise)."""
    nr, Lp = pool.nr, pool.Lp

    def per_layer(pool_c):
        lvls = [(pool_c.k, pool_c.v)] + list(zip(pool_c.ck, pool_c.cv))
        quant = _quant_flags(pool_c)
        if isinstance(pool_c, hd.QuantPagedH1DCache):
            slvls = ([(pool_c.ksc, pool_c.vsc)]
                     + list(zip(pool_c.cksc, pool_c.cvsc)))
        outs = []
        for l, (ka, va) in enumerate(lvls):
            if quant[l]:
                ksa, vsa = slvls[l]
                ka = jnp.asarray(qz.dequantize_int8(
                    ka, jnp.asarray(ksa)[..., None]))
                va = jnp.asarray(qz.dequantize_int8(
                    va, jnp.asarray(vsa)[..., None]))
            Ll = Lp >> l
            shp = (ka.shape[0], Hkv, Ll, ka.shape[-1]) if stacked else \
                  (Hkv, Ll, ka.shape[-1])
            dk = np.zeros(shp, ka.dtype)
            dv = np.zeros(shp[:-1] + (va.shape[-1],), va.dtype)
            kh = np.asarray(ka)
            vh = np.asarray(va)
            for blk in np.nonzero(pool.table[l][slot] >= 0)[0]:
                page = int(pool.table[l][slot, blk])
                rows = slice(page * Hkv, (page + 1) * Hkv)
                cols = slice(blk * nr, (blk + 1) * nr)
                if stacked:           # (NL, Hkv, nr, D) pool rows
                    dk[:, :, cols] = kh[:, rows]
                    dv[:, :, cols] = vh[:, rows]
                else:
                    dk[:, cols] = kh[rows]
                    dv[:, cols] = vh[rows]
            outs.append((dk, dv))
        k, v = outs[0]
        ck = tuple(o[0] for o in outs[1:])
        cv = tuple(o[1] for o in outs[1:])
        return hd.H1DCache(k=jnp.asarray(k), v=jnp.asarray(v),
                           ck=jax.tree.map(jnp.asarray, ck),
                           cv=jax.tree.map(jnp.asarray, cv))

    return _map_layers(caches, stacked, per_layer)


def pool_bytes(caches) -> int:
    """Total HBM footprint of the paged pools (all layers/levels)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))
