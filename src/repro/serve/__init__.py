"""Batched serving engine with hierarchical KV caches (dense slot
cache or paged cache pool + continuous-batching scheduler)."""
from .engine import ServeEngine, Request
from .scheduler import ContinuousBatchingScheduler, QueueEntry
