"""Batched serving engine with hierarchical KV caches."""
from .engine import ServeEngine, Request
