"""Continuous-batching scheduler for the serving engine.

Replaces the rigid "admit whatever shares the head-of-queue's bucket"
FIFO loop with a per-tick plan:

* **token budget** -- each tick spends at most ``token_budget`` tokens
  of model work: one per active (decoding or prompt-feeding) slot plus
  the prefill-chunk length of every admission.  ``None`` = unlimited,
  which reproduces the legacy admission behavior exactly (the dense
  parity oracle's schedule).
* **chunked prefill** -- prompts longer than ``prefill_chunk`` are
  admitted on their first ``prefill_chunk`` tokens only; the remainder
  streams through the regular batched DECODE ticks (the slot is in a
  "feeding" state: its next input token comes from the prompt and the
  logits are discarded until the prompt is exhausted), so one huge
  prompt no longer stalls every running decode for a full-prompt
  prefill.
* **lookahead** -- a bounded skip-ahead window: when the head of the
  queue does not fit (budget or page availability), up to ``lookahead``
  later requests may be admitted first.  FIFO order is preserved inside
  the window scan, so starvation is bounded by the window size.
* **preemption** -- when the paged pool is exhausted mid-tick the
  engine asks :func:`choose_victim` for a slot to release; the victim is
  requeued at the HEAD of the queue (recompute-on-resume) per
  :class:`QueueEntry`'s resume fields.

The scheduler is pure host-side bookkeeping: it never touches device
state and knows nothing about the model.  The engine supplies callbacks
for bucketing and admission feasibility (the paged pool's availability
probe; always-true for the dense path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class QueueEntry:
    """One queued unit of work.  ``prompt`` is the ADMITTED prompt (may
    be tail-truncated by the engine's overflow policy, or extended with
    already-generated tokens on preemption-resume).  ``resume_token``,
    when set, is the already-sampled next input token: at re-admission
    the engine discards the prefill's sampled token (it would re-sample
    and, for non-greedy requests, diverge) and feeds this one instead."""
    req: Any
    prompt: np.ndarray
    resume_token: Optional[int] = None
    # preemption mode 'swap': host-side page snapshot + resume state
    # ({'pos', 'tok', 'feed', 'pages'}); restored bit-exact without any
    # recompute (serve/paged_cache.snapshot_slot / restore_slot)
    restore: Optional[dict] = None


@dataclasses.dataclass
class AdmitGroup:
    """One batched prefill call: entries whose prefill chunks share a
    padded-length bucket."""
    entries: List[QueueEntry]
    chunks: List[np.ndarray]       # per entry: prompt[:chunk_len]
    bucket: int                    # shared padded chunk length


class ContinuousBatchingScheduler:
    def __init__(self, *, token_budget: Optional[int] = None,
                 lookahead: int = 0, prefill_chunk: Optional[int] = None):
        if token_budget is not None and token_budget < 1:
            raise ValueError("token_budget must be >= 1 or None")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 or None")
        self.token_budget = token_budget
        self.lookahead = int(lookahead)
        self.prefill_chunk = prefill_chunk

    def chunk_len(self, S: int) -> int:
        if self.prefill_chunk is None:
            return S
        return min(S, self.prefill_chunk)

    def plan(self, queue: List[QueueEntry], free_slots: int, n_active: int,
             bucket_len: Callable[[int], int],
             can_admit: Callable[[QueueEntry], bool],
             ) -> Tuple[List[AdmitGroup], List[QueueEntry]]:
        """Plan this tick's admissions.

        Returns ``(groups, remaining_queue)``.  Each group is one
        batched prefill; the union of group entries is removed from the
        queue.  With ``token_budget=None``, ``lookahead=0`` and no
        chunking this reduces exactly to the legacy loop: pop the head,
        pull consecutive same-bucket entries up to the free-slot count,
        repeat."""
        queue = list(queue)
        budget = (np.inf if self.token_budget is None
                  else max(self.token_budget - n_active, 0))
        groups: List[AdmitGroup] = []

        def fits(entry: QueueEntry, is_first_pick: bool) -> bool:
            cost = self.chunk_len(len(entry.prompt))
            if cost > budget:
                # anti-starvation: an otherwise idle engine always
                # admits its first pick, however long the chunk
                if not (is_first_pick and n_active == 0 and not groups):
                    return False
            return can_admit(entry)

        while free_slots > 0 and queue:
            window = min(len(queue), self.lookahead + 1)
            pick = next((j for j in range(window)
                         if fits(queue[j], is_first_pick=True)), None)
            if pick is None:
                break
            head = queue.pop(pick)
            chunk = self.chunk_len(len(head.prompt))
            Lb = bucket_len(chunk)
            group = AdmitGroup(entries=[head],
                               chunks=[head.prompt[:chunk]], bucket=Lb)
            budget -= chunk
            free_slots -= 1
            j = 0
            while j < min(len(queue), self.lookahead + 1) and free_slots > 0:
                e = queue[j]
                c = self.chunk_len(len(e.prompt))
                if bucket_len(c) == Lb and fits(e, is_first_pick=False):
                    queue.pop(j)
                    group.entries.append(e)
                    group.chunks.append(e.prompt[:c])
                    budget -= c
                    free_slots -= 1
                elif self.lookahead == 0:
                    break          # legacy semantics: consecutive only
                else:
                    j += 1
            groups.append(group)
            if budget <= 0:
                break
        return groups, queue

    # ------------------------------------------------------------------
    @staticmethod
    def choose_victim(admit_serial: Dict[int, int],
                      exclude: Sequence[int] = ()) -> Optional[int]:
        """Preemption victim: the most recently admitted active slot
        (LIFO -- oldest work keeps its pages, so total recompute waste
        is bounded), excluding ``exclude`` (e.g. the slot currently
        being provisioned when it is the only one left)."""
        cands = [(serial, s) for s, serial in admit_serial.items()
                 if s not in exclude]
        if not cands:
            return None
        return max(cands)[1]
