"""Batched serving engine: fixed-slot continuous batching over the
unified model API (prefill + single-token decode with hierarchical KV
caches).

Design points for scale (DESIGN.md):
* decode state is a pure pytree -- slots join/leave by writing rows, the
  jit'd step never retraces;
* admission pads prompts to power-of-two length buckets, so prefill
  compiles O(log max_len) shapes, not one per distinct prompt length;
* per-tick bookkeeping reads a host-side numpy mirror of the slot
  positions -- one device sync per step (the sampled tokens), not one
  per active slot;
* the hierarchical H1D cache gives O(nr log L) attention per token, so
  long-context decode cost is flat in practice;
* the engine is deployment-shaped (request queue, slot map, step loop)
  while staying single-host here; the multi-pod serve driver shards the
  slot dim over DP axes (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 512, greedy: bool = True, seed: int = 0):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "ServeEngine targets decoder-only families; enc-dec serving "
                "goes through launch/serve.py with per-request encoder runs")
        from repro.models.transformer import _stacked_caches
        self.cfg = cfg
        self.params = params
        self.fns = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._slot_axis = 1 if _stacked_caches(cfg) else 0

        self.caches = self.fns.init_caches(params, cfg, slots, max_len)
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.pos = jnp.zeros((slots,), jnp.int32)
        # host-side mirror of ``pos``: the decode loop reads positions
        # every tick (done checks); keeping a numpy twin avoids a device
        # sync per active slot per step.
        self.pos_host = np.zeros((slots,), np.int64)
        self.active = np.zeros((slots,), bool)
        self.req: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []

        # Prompt length bucketing: right-pad prompts to the next power of
        # two (capped at max_len) so _prefill1 compiles O(log max_len)
        # shapes instead of one per distinct prompt length.  Only safe
        # when the padded tail cannot reach the true-position logits or
        # the decode-visible cache, so gated off for:
        #  * recurrent families (ssm/hybrid): the SSM prefill scan over
        #    pad tokens corrupts the state (and encdec never gets here);
        #  * sliding-window configs: the rolling local cache keeps only
        #    the LAST 2*window rows, so pads evict real in-window keys;
        #  * h1d coarse-q: coarse QUERY means average pad embeddings
        #    across cluster boundaries (the documented leak, DESIGN.md
        #    1.2), shifting logits at the true last token.
        self._bucket = (cfg.family not in ("ssm", "hybrid", "encdec")
                        and cfg.sliding_window == 0
                        and (cfg.attention != "h1d"
                             or cfg.causal_mode == "fine-q"))

        self._decode = jax.jit(
            lambda p, c, tok, t: self.fns.decode_step(p, cfg, c, tok, t))
        self._prefill1 = jax.jit(
            lambda p, batch, n: self.fns.prefill(p, cfg, batch, max_len,
                                                 true_len=n))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _admit(self):
        """Prefill queued requests into free slots, one at a time, with
        prompts right-padded to power-of-two length buckets -- the jit
        cache holds O(log max_len) prefill shapes, not one per distinct
        prompt length (batched prefill within a bucket is a trivial
        extension from here)."""
        for s in range(self.slots):
            if self.active[s] or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = np.asarray(req.prompt)
            S = int(prompt.shape[0])
            if self._bucket:
                # cap at max_len; an over-long prompt keeps its own
                # length (admitted as before, done check ends it fast)
                Lb = max(S, min(1 << max(S - 1, 0).bit_length(),
                                self.max_len))
                prompt = np.pad(prompt, (0, Lb - S))
            batch = {"tokens": jnp.asarray(prompt)[None]}
            logits, caches, pos = self._prefill1(self.params, batch, S)
            nxt = int(jnp.argmax(logits[0]))
            # Write slot s.  The slot dim (0, or 1 for scanned layer
            # stacks) may fold kv-heads into the batch (h1d caches:
            # B*Hkv rows), so slot s spans rows [s*r, (s+1)*r) with
            # r = full_rows // slots == rows of the B=1 prefill cache.
            ax = self._slot_axis

            def write(full, one):
                r = full.shape[ax] // self.slots
                idx = [slice(None)] * full.ndim
                idx[ax] = slice(s * r, (s + 1) * r)
                return full.at[tuple(idx)].set(one)

            self.caches = jax.tree.map(write, self.caches, caches)
            self.tokens = self.tokens.at[s].set(nxt)
            self.pos = self.pos.at[s].set(S)   # == pos[0], known on host
            self.pos_host[s] = S
            self.active[s] = True
            self.req[s] = req
            req.out_tokens.append(nxt)

    def step(self) -> int:
        """One engine tick: admit + one decode step for all active slots.
        Returns number of active slots."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.caches = self._decode(self.params, self.caches,
                                           self.tokens, self.pos)
        if self.greedy:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            self.key, k = jax.random.split(self.key)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        self.tokens = nxt
        self.pos = self.pos + 1
        self.pos_host += 1       # mirrors the device update exactly
        nxt_host = np.asarray(nxt)
        for s in range(self.slots):
            if not self.active[s]:
                continue
            req = self.req[s]
            req.out_tokens.append(int(nxt_host[s]))
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or int(self.pos_host[s]) >= self.max_len - 1)
            if done:
                self.active[s] = False
                self.req[s] = None
        return int(self.active.sum())

    def run(self) -> None:
        while self.queue or self.active.any():
            self.step()
