"""Batched serving engine: continuous batching over the unified model
API (prefill + single-token decode with hierarchical KV caches).

Design points for scale (DESIGN.md):
* decode state is a pure pytree -- slots join/leave by writing rows, the
  jit'd step never retraces;
* admission is planned per tick by a continuous-batching scheduler
  (``serve/scheduler.py``): per-tick token budget, chunked prefill
  (long prompts stream their tail through the regular decode ticks),
  bounded lookahead past a head-of-queue that does not fit, and
  requeue-on-preemption -- with the default knobs reproducing the
  legacy FIFO bucket grouping exactly;
* admission pads prompts to power-of-two length buckets, so prefill
  compiles O(log max_len) shapes, not one per distinct prompt length,
  and admits ALL planned requests sharing a bucket in one batched
  prefill call (per-row ``true_len``, row count padded to a power of
  two) so admission cost amortizes under load while the prefill jit
  cache stays O(log slots * log max_len);
* prompts longer than ``max_len - 1`` are rejected (or tail-truncated)
  at ``submit`` -- see ``ServeEngine.overflow``;
* generation ends at ``max_new_tokens``, a full cache, or any of the
  request's ``stop_tokens`` (the stop token is kept in ``out_tokens``);
* finished slots are frozen (their ``pos`` stops advancing) so the
  clamped cache writes of an idle slot never walk out of range;
* per-tick bookkeeping reads a host-side numpy mirror of the slot
  positions -- one device sync per step (the sampled tokens), not one
  per active slot;
* the hierarchical H1D cache gives O(nr log L) attention per token --
  with ``decode_impl='pallas'`` the whole tick's attend runs as ONE
  fused kernel launch (and the ancestor update as one more), so
  long-context decode cost is flat in practice;
* ``paged=True`` swaps the per-slot dense cache for the PAGED pool
  (``serve/paged_cache.py``): HBM is bounded by ``pool_pages``, not
  ``slots * max_len``, pages are prefix-shared across requests with
  copy-on-write, and pool exhaustion preempts the newest request
  (requeued; swap-mode page snapshots restore it bit-exact) instead of
  failing -- the dense slot path stays as the bit-parity oracle;
* the engine is deployment-shaped (request queue, slot map, step loop)
  while staying single-host here; the multi-pod serve driver shards the
  slot dim over DP axes (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import ModelConfig, get_model
from .scheduler import ContinuousBatchingScheduler, QueueEntry


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    stop_tokens: Optional[Sequence[int]] = None
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    """``overflow`` policy for prompts longer than ``max_len - 1`` (the
    cache needs >= 1 free position to generate anything): ``'error'``
    rejects at ``submit()``; ``'truncate'`` keeps the LAST
    ``max_len - 1`` prompt tokens (most recent context) and serves the
    rest of the request normally.  Silent admission used to prefill a
    cache longer than the slot rows, corrupting neighbouring slots.

    ``decode_impl`` overrides ``cfg.decode_impl`` (``'auto'`` |
    ``'jnp'`` | ``'pallas'`` | ``'pallas_interpret'``): ``'pallas'``
    runs each decode tick through the fused single-launch
    hierarchical-KV kernels (``kernels/h1d_decode_kernel``); ``'auto'``
    lets the process ``KernelPolicy`` resolve per backend.

    ``mesh`` enables sequence-parallel serving: the hierarchical cache
    shards its sequence axis over ``mesh[sp_axis]`` and every decode
    tick runs the fused kernels per shard under ``shard_map``
    (``repro.parallel.sp_attention``) -- the configuration that used to
    force ``impl='jnp'``.  Requires ``attention='h1d'`` and a padded
    ``max_len`` of at least ``data_axis_size * nr`` (one level-0 block
    per shard).

    ``paged=True`` serves from the paged hierarchical cache pool
    (``serve/paged_cache.py``): per-layer pools of ``pool_pages``
    nr-row pages (plus proportionally sized coarse-level pools) replace
    the ``slots * max_len`` dense slabs.  Requires ``attention='h1d'``
    without sliding-window layers and is host-local (``mesh`` must be
    None).  ``prefix_sharing`` maps bit-identical prompt-prefix pages
    (and their coarse ancestors) once across requests, copy-on-write.
    ``token_budget`` / ``lookahead`` / ``prefill_chunk`` tune the
    continuous-batching scheduler for either path.

    ``cache_dtype`` (default from ``cfg.cache_dtype``) selects the
    paged pool's page storage: ``'fp32'`` keeps the bit-parity oracle
    path; ``'int8'`` stores pages as int8 with per-row scales
    (``core.quantization``) and decodes through the quantized kernels
    -- ~4x more pages at fixed HBM.  ``quant_levels`` (default
    ``cfg.cache_quant_levels``) restricts quantization to hierarchy
    levels ``[0, n)``; -1 = all levels.  int8 requires ``paged=True``
    (the dense slab cache has no scale side-band)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 512, greedy: bool = True, seed: int = 0,
                 overflow: str = "error", decode_impl: Optional[str] = None,
                 mesh=None, sp_axis: str = "data", paged: bool = False,
                 pool_pages: Optional[int] = None, prefix_sharing: bool = True,
                 token_budget: Optional[int] = None, lookahead: int = 0,
                 prefill_chunk: Optional[int] = None,
                 preempt_mode: str = "swap",
                 cache_dtype: Optional[str] = None,
                 quant_levels: Optional[int] = None):
        if preempt_mode not in ("swap", "recompute"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r}")
        if cache_dtype is None:
            cache_dtype = cfg.cache_dtype
        if cache_dtype not in ("fp32", "int8"):
            raise ValueError(f"unknown cache_dtype {cache_dtype!r}")
        if quant_levels is None:
            quant_levels = cfg.cache_quant_levels
        if cache_dtype == "int8" and not paged:
            raise ValueError("cache_dtype='int8' requires paged=True: the "
                             "dense slab cache has no per-page scale "
                             "side-band")
        self.cache_dtype = cache_dtype
        self.quant_levels = quant_levels
        if cfg.family == "encdec":
            raise NotImplementedError(
                "ServeEngine targets decoder-only families; enc-dec serving "
                "goes through launch/serve.py with per-request encoder runs")
        if overflow not in ("error", "truncate"):
            raise ValueError(f"unknown overflow policy {overflow!r}")
        if decode_impl is not None and decode_impl != cfg.decode_impl:
            cfg = dataclasses.replace(cfg, decode_impl=decode_impl)
        # validate against the canonical impl enum up front: a typo'd
        # decode_impl must fail at engine construction, not mid-serve
        from repro.kernels.tuning import canonical_impl
        canonical_impl(cfg.decode_impl)
        from repro.models.transformer import _stacked_caches
        from repro.parallel.sp_attention import sp_scope
        self.cfg = cfg
        self.overflow = overflow
        self.params = params
        self.fns = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._slot_axis = 1 if _stacked_caches(cfg) else 0
        self._stacked = _stacked_caches(cfg)

        self.mesh = mesh
        self.sp_axis = sp_axis
        sp_d = dict(mesh.shape).get(sp_axis, 1) if mesh is not None else 1
        if sp_d > 1:
            if cfg.attention != "h1d":
                raise ValueError(
                    "SP serving shards the hierarchical cache's sequence "
                    f"axis; attention={cfg.attention!r} has no such cache")
            from repro.core import hierarchy as hc
            Lp = hc.padded_length(max_len, cfg.nr)
            if Lp < sp_d * cfg.nr or Lp % (sp_d * cfg.nr):
                raise ValueError(
                    f"SP serving: padded max_len {Lp} cannot keep one "
                    f"nr={cfg.nr} block per shard on a {sp_d}-way "
                    f"'{sp_axis}' axis; use fewer shards or a longer "
                    f"max_len")

        self.sched = ContinuousBatchingScheduler(
            token_budget=token_budget, lookahead=lookahead,
            prefill_chunk=prefill_chunk)

        self.paged = paged
        self.pool = None
        if paged:
            from . import paged_cache as pc
            if mesh is not None:
                raise ValueError("paged serving is host-local: the page "
                                 "tables are host state; use either "
                                 "paged=True or mesh=, not both")
            if (cfg.attention != "h1d" or cfg.sliding_window > 0
                    or cfg.global_every > 0
                    or cfg.family not in ("dense", "moe", "vlm")):
                raise ValueError(
                    "paged serving requires a uniform h1d attention stack "
                    f"(family={cfg.family!r}, attention={cfg.attention!r}, "
                    f"sliding_window={cfg.sliding_window}, "
                    f"global_every={cfg.global_every})")
            self._pc = pc
            from repro.core import hierarchy as hc
            Lp = hc.padded_length(max_len, cfg.nr)
            if pool_pages is None:
                pool_pages = slots * (Lp // cfg.nr)   # dense-equivalent
            self.pool = pc.PagePool(
                slots=slots, max_len=max_len, nr=cfg.nr,
                pool_pages=pool_pages,
                quant_levels=(quant_levels if cache_dtype == "int8" else 0))
            self.prefix_sharing = prefix_sharing
            self.preempt_mode = preempt_mode
            self.caches = pc.init_paged_caches(cfg, self.pool)
        else:
            self.caches = self.fns.init_caches(params, cfg, slots, max_len)
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.pos = jnp.zeros((slots,), jnp.int32)
        # host-side mirror of ``pos``: the decode loop reads positions
        # every tick (done checks); keeping a numpy twin avoids a device
        # sync per active slot per step.
        self.pos_host = np.zeros((slots,), np.int64)
        self.active = np.zeros((slots,), bool)
        self.req: List[Optional[Request]] = [None] * slots
        # chunked prefill: tokens still to stream through decode ticks
        # per slot (outputs discarded while non-empty)
        self.feed: List[List[int]] = [[] for _ in range(slots)]
        # admission prompt per slot (preemption rebuilds the resume
        # prompt from it) and admission serial (preemption victim order)
        self._admitted: List[Optional[np.ndarray]] = [None] * slots
        self._admit_serial: Dict[int, int] = {}
        self._serial = 0
        self.preemptions = 0
        self.queue: List[QueueEntry] = []
        # telemetry bookkeeping (repro.obs): per-request wall-clock
        # marks keyed by id(req) -- submit time (TTFT) and last-token
        # time (inter-token latency) -- plus a per-tick prefill-token
        # accumulator (token-budget utilization) and the last-seen pool
        # stats (mirrored into obs counters as deltas).  All writes are
        # behind ``obs.enabled()`` so the disabled path stays free.
        self._t_submit: Dict[int, float] = {}
        self._t_last: Dict[int, float] = {}
        self._tick_prefill_tokens = 0
        self._pool_seen: Dict[str, int] = {}

        # Prompt length bucketing: right-pad prompts to the next power of
        # two (capped at max_len) so _prefill1 compiles O(log max_len)
        # shapes instead of one per distinct prompt length.  Only safe
        # when the padded tail cannot reach the true-position logits or
        # the decode-visible cache, so gated off for:
        #  * recurrent families (ssm/hybrid): the SSM prefill scan over
        #    pad tokens corrupts the state (and encdec never gets here);
        #  * sliding-window configs: the rolling local cache keeps only
        #    the LAST 2*window rows, so pads evict real in-window keys;
        #  * h1d coarse-q: coarse QUERY means average pad embeddings
        #    across cluster boundaries (the documented leak, DESIGN.md
        #    1.2), shifting logits at the true last token.
        self._bucket = (cfg.family not in ("ssm", "hybrid", "encdec")
                        and cfg.sliding_window == 0
                        and (cfg.attention != "h1d"
                             or cfg.causal_mode == "fine-q"))

        # the sp_scope context is entered at TRACE time (jit traces the
        # wrapper synchronously), so the h1d decode/attention entry
        # points see the mesh and route through the shard_map'd kernels
        def _decode_traced(p, c, tok, t):
            with sp_scope(self.mesh, self.sp_axis):
                return self.fns.decode_step(p, cfg, c, tok, t)

        def _decode_paged_traced(p, c, tok, t, tabs):
            return self.fns.decode_step(p, cfg, c, tok, t, page_tables=tabs)

        def _prefill_traced(p, batch, n):
            with sp_scope(self.mesh, self.sp_axis):
                return self.fns.prefill(p, cfg, batch, max_len, true_len=n)

        self._decode = jax.jit(_decode_paged_traced if paged
                               else _decode_traced)
        self._prefill1 = jax.jit(_prefill_traced)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Queue a request.  Prompts longer than ``max_len - 1`` (no
        room left to generate) are rejected or tail-truncated per the
        engine's ``overflow`` policy -- silently admitting them used to
        prefill an over-long cache whose slot write sliced into
        neighbouring slots' rows."""
        prompt = np.asarray(req.prompt, np.int32)
        S = int(prompt.shape[0])
        limit = self.max_len - 1
        if S > limit:
            if self.overflow == "truncate":
                # truncate a private copy -- the caller's Request object
                # is left intact (it may be logged or re-submitted to an
                # engine with a larger max_len)
                prompt = prompt[-limit:]
            else:
                raise ValueError(
                    f"prompt length {S} > max_len - 1 = {limit}; shorten "
                    f"the prompt or construct the engine with "
                    f"overflow='truncate'")
        req.out_tokens = []
        self.queue.append(QueueEntry(req=req, prompt=prompt))
        if obs.enabled():
            self._t_submit[id(req)] = time.perf_counter()
            obs.counter("serve.requests").inc()

    # -- telemetry -----------------------------------------------------
    def _note_token(self, req: Request) -> None:
        """TTFT on the first generated token, inter-token latency on
        every later one (both survive preemption: the marks are keyed
        by request, not slot)."""
        now = time.perf_counter()
        rid = id(req)
        if len(req.out_tokens) == 1:
            t0 = self._t_submit.get(rid)
            if t0 is not None:
                obs.histogram("serve.ttft_s").observe(now - t0)
        else:
            last = self._t_last.get(rid)
            if last is not None:
                obs.histogram("serve.itl_s").observe(now - last)
        self._t_last[rid] = now

    def _note_finish(self, req: Request) -> None:
        obs.counter("serve.finished").inc()
        rid = id(req)
        self._t_last.pop(rid, None)
        t0 = self._t_submit.pop(rid, None)
        if t0 is not None:
            obs.histogram("serve.request_latency_s").observe(
                time.perf_counter() - t0)

    def _tick_obs(self, n_active: int) -> None:
        """Per-tick gauges/counters (called only when telemetry is on)."""
        obs.counter("serve.ticks").inc()
        obs.gauge("serve.queue_depth").set(len(self.queue))
        obs.gauge("serve.active_slots").set(n_active)
        budget = self.sched.token_budget
        if budget:
            used = self._tick_prefill_tokens + n_active
            obs.gauge("serve.token_budget_util").set(used / budget)
        self._tick_prefill_tokens = 0
        if self.paged:
            obs.gauge("pool.occupancy").set(self.pool.occupancy())
            for k, v in self.pool.stats.snapshot().items():
                delta = v - self._pool_seen.get(k, 0)
                if delta:
                    obs.counter(f"pool.{k}").inc(delta)
                    self._pool_seen[k] = v

    def _bucket_len(self, S: int) -> int:
        """Padded prompt length: next power of two capped at max_len
        (identity when bucketing is gated off for this config)."""
        if not self._bucket:
            return S
        return max(S, min(1 << max(S - 1, 0).bit_length(), self.max_len))

    def _stopped(self, req: Request, tok: int) -> bool:
        return bool(req.stop_tokens) and tok in req.stop_tokens

    # -- admission -----------------------------------------------------
    def _can_admit_fn(self) -> Callable[[QueueEntry], bool]:
        """Admission feasibility for the scheduler.  The paged probe
        commits its per-level net page need on success, so entries
        planned earlier in the SAME tick count against later ones (the
        scheduler only calls it once per picked entry)."""
        if not self.paged:
            return lambda e: True
        planned = [0] * self.pool.M

        def can(e: QueueEntry) -> bool:
            chunk = e.prompt[:self.sched.chunk_len(len(e.prompt))]
            need = self.pool.net_need(np.asarray(chunk, np.int32),
                                      share=self.prefix_sharing)
            if all(need[l] + planned[l] <= self.pool.available(l)
                   for l in range(self.pool.M)):
                for l in range(self.pool.M):
                    planned[l] += need[l]
                return True
            return False

        return can

    def _admit(self):
        """Plan this tick's admissions with the scheduler and run one
        batched prefill per planned bucket group.  Swap-preempted
        entries restore first (no prefill needed, their pages scatter
        straight back), scanned over the same lookahead window."""
        free = [s for s in range(self.slots) if not self.active[s]]
        if not free or not self.queue:
            return
        j = 0
        while free and j < min(len(self.queue), self.sched.lookahead + 1):
            entry = self.queue[j]
            if entry.restore is not None and self._try_restore(entry,
                                                               free[0]):
                free.pop(0)
                self.queue.pop(j)
            else:
                j += 1
        if not free or not self.queue:
            return
        can = self._can_admit_fn()
        groups, self.queue = self.sched.plan(
            self.queue, len(free), int(self.active.sum()),
            self._bucket_len,
            lambda e: e.restore is None and can(e))
        for group in groups:
            self._admit_group(group, free)

    def _admit_group(self, group, free: List[int]):
        """One batched prefill: every entry in ``group`` shares the
        padded chunk-length bucket ``group.bucket``.  The row count is
        padded to a power of two as well (dummy rows discarded), keeping
        the prefill jit cache at O(log slots * log max_len) shapes."""
        g = len(group.entries)
        Lb = group.bucket
        gp = 1 << (g - 1).bit_length()       # pow2 row count
        prompts = np.zeros((gp, Lb), np.int32)
        ns = np.ones((gp,), np.int32)        # dummy rows: true_len 1
        for i, chunk in enumerate(group.chunks):
            prompts[i, :len(chunk)] = chunk
            ns[i] = len(chunk)
        batch = {"tokens": jnp.asarray(prompts)}
        logits, caches, pos = self._prefill1(self.params, batch,
                                             jnp.asarray(ns))
        dst = free[:g]
        del free[:g]

        kept = [True] * g
        if self.paged:
            kept = self._paged_admit_writes(group, dst, caches)
            if not any(kept):
                return

        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        else:
            # Sample the first generated token with PER-ROW keys:
            # one split per batched call, then each row folds in its
            # DESTINATION SLOT index (dummy pad rows use indices past
            # the slot range).  A single categorical over the padded
            # (gp, V) logits drew one gumbel tensor shaped by gp, so
            # the same request could sample a DIFFERENT first token
            # depending on how many dummy rows its bucket happened
            # to get -- sampling must be invariant to padding.
            self.key, kbase = jax.random.split(self.key)
            row_ids = jnp.asarray(
                np.array(dst + list(range(self.slots,
                                          self.slots + gp - g)),
                         np.int32))
            keys = jax.vmap(jax.random.fold_in, (None, 0))(kbase,
                                                           row_ids)
            nxt = np.asarray(jax.vmap(jax.random.categorical)(
                keys, logits)).astype(np.int32)

        if not self.paged:
            # Write the whole group into its slots with ONE tree.map
            # pass (contiguous free slots collapse to a single slice
            # write).  The slot dim (0, or 1 for scanned layer stacks)
            # may fold kv-heads into the batch (h1d caches: B*Hkv
            # rows), so slot s spans rows [s*r, (s+1)*r) with
            # r = full_rows // slots == rows per request of the batched
            # prefill cache.
            ax = self._slot_axis
            contig = dst == list(range(dst[0], dst[0] + g))

            def write(full, one):
                r = full.shape[ax] // self.slots
                src = [slice(None)] * one.ndim
                src[ax] = slice(0, g * r)
                idx = [slice(None)] * full.ndim
                if contig:
                    # slice write lowers to one dynamic_update_slice
                    idx[ax] = slice(dst[0] * r, (dst[0] + g) * r)
                else:
                    # one row-index scatter -- NOT one full-cache copy
                    # per destination slot
                    rows = np.concatenate([np.arange(s * r, (s + 1) * r)
                                           for s in dst])
                    idx[ax] = jnp.asarray(rows)
                return full.at[tuple(idx)].set(one[tuple(src)])

            self.caches = jax.tree.map(write, self.caches, caches)
        # batched token/pos scatter: 2 dispatches per group, not 2g
        slot_w: List[int] = []
        tok_w: List[int] = []
        pos_w: List[int] = []
        for i, entry in enumerate(group.entries):
            if not kept[i]:
                continue
            s = dst[i]
            req = entry.req
            chunk_n = int(ns[i])
            if obs.enabled():
                obs.counter("serve.admissions").inc()
                self._tick_prefill_tokens += chunk_n
            self.pos_host[s] = chunk_n
            self._admitted[s] = entry.prompt
            slot_w.append(s)
            pos_w.append(chunk_n)
            remainder = list(entry.prompt[chunk_n:].tolist())
            if entry.resume_token is not None:
                # preemption-resume: the next input was already sampled
                # before the preemption -- never re-sample it
                remainder.append(int(entry.resume_token))
            if remainder:
                # chunked prefill (or resume): the next input token is
                # known; the prefill's sampled token is discarded and
                # the tail streams through the decode ticks
                tok_w.append(remainder[0])
                self.feed[s] = remainder[1:]
                self.req[s] = req
                self.active[s] = True
                self._serial += 1
                self._admit_serial[s] = self._serial
                continue
            tok_w.append(int(nxt[i]))
            self.feed[s] = []
            self.req[s] = req
            req.out_tokens.append(int(nxt[i]))
            if obs.enabled():
                self._note_token(req)
            # done-check at admission: the first sampled token may
            # already satisfy max_new_tokens, a stop token, or a full
            # cache -- the slot then never activates, so no decode tick
            # is wasted and max_new_tokens is a hard cap (regression:
            # every request used to get >= 2 tokens).
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or chunk_n >= self.max_len - 1
                    or self._stopped(req, int(nxt[i])))
            if done:
                if obs.enabled():
                    self._note_finish(req)
                self._release(s)
            else:
                self.active[s] = True
                self._serial += 1
                self._admit_serial[s] = self._serial
        idx = jnp.asarray(np.array(slot_w, np.int32))
        self.tokens = self.tokens.at[idx].set(
            jnp.asarray(np.array(tok_w, np.int32)))
        self.pos = self.pos.at[idx].set(
            jnp.asarray(np.array(pos_w, np.int32)))

    def _paged_admit_writes(self, group, dst, caches) -> List[bool]:
        """Map pool pages for every entry (prefix-sharing aware) and
        scatter the freshly prefilled blocks into the registry-missed
        pages.  An entry the pool cannot hold (availability-estimate
        races inside one tick) is unwound and requeued at the head.
        Returns the per-entry kept mask; dense prefill rows keep their
        original indices, so no remapping is needed for the scatter."""
        pc = self._pc
        writes = []
        kept = [False] * len(group.entries)
        failed = []
        for i, (entry, chunk) in enumerate(zip(group.entries,
                                               group.chunks)):
            s = dst[i]
            try:
                w = self.pool.admit(s, np.asarray(chunk, np.int32),
                                    share=self.prefix_sharing)
                writes.append((i, w))
                kept[i] = True
            except pc.PoolExhausted:
                self.pool.release_slot(s)
                failed.append(entry)
        # requeue unwound entries as a block, preserving arrival order
        # (per-entry insert(0, ...) reversed them)
        self.queue[:0] = failed
        if writes:
            self.caches = pc.scatter_prefill(
                self.caches, caches, writes, self.cfg.num_kv_heads,
                self.cfg.nr, self._stacked)
        return kept

    # -- release / preemption ------------------------------------------
    def _release(self, s: int):
        """Finish a slot: free paged pages, clear bookkeeping."""
        self.active[s] = False
        self.req[s] = None
        self.feed[s] = []
        self._admitted[s] = None
        self._admit_serial.pop(s, None)
        if self.paged:
            self.pool.release_slot(s)

    def _preempt(self, victim: int):
        """Evict a running request from its slot (pool pressure) and
        requeue it at the HEAD.

        ``preempt_mode='swap'`` (default) snapshots the victim's pages
        to host memory and restores them bit-exact at re-admission --
        greedy token streams stay IDENTICAL to the dense engine's.
        ``'recompute'`` folds generated tokens into a resume prompt and
        re-prefills on re-admission (no host memory, but the recomputed
        cache matches the decode-built one only to ~1e-6, so greedy
        continuations may drift at argmax near-ties); the already
        sampled next input rides along as ``resume_token`` so non-greedy
        requests never re-roll it."""
        req = self.req[victim]
        base = self._admitted[victim]
        if self.preempt_mode == "swap":
            snap = self._pc.snapshot_slot(self.caches, self.pool, victim,
                                          self.cfg.num_kv_heads,
                                          self._stacked)
            tok = int(np.asarray(self.tokens)[victim])
            entry = QueueEntry(
                req=req, prompt=base,
                restore={"pos": int(self.pos_host[victim]), "tok": tok,
                         "feed": list(self.feed[victim]), "pages": snap})
        elif req.out_tokens:
            prompt = np.concatenate(
                [base, np.asarray(req.out_tokens[:-1], np.int32)])
            entry = QueueEntry(req=req, prompt=prompt.astype(np.int32),
                               resume_token=int(req.out_tokens[-1]))
        else:
            # recompute mode, still prefilling: redo the whole prompt
            entry = QueueEntry(req=req, prompt=base)
        self.queue.insert(0, entry)
        self._release(victim)
        self.preemptions += 1
        obs.counter("serve.preemptions").inc()

    def _try_restore(self, entry: QueueEntry, s: int) -> bool:
        """Swap-in a preempted entry into free slot ``s``; False when
        the pool cannot hold its pages yet."""
        pc = self._pc
        snap = entry.restore["pages"]
        need = {l: len(entry_l[0]) for l, entry_l in snap.items()}
        if any(n > self.pool.available(l) for l, n in need.items()):
            return False
        try:
            self.caches = pc.restore_slot(self.caches, self.pool, s, snap,
                                          self.cfg.num_kv_heads,
                                          self._stacked)
        except pc.PoolExhausted:       # estimate raced; unwind
            self.pool.release_slot(s)
            return False
        self.req[s] = entry.req
        self._admitted[s] = entry.prompt
        self.feed[s] = list(entry.restore["feed"])
        self.pos_host[s] = entry.restore["pos"]
        idx = jnp.asarray(np.array([s], np.int32))
        self.tokens = self.tokens.at[idx].set(int(entry.restore["tok"]))
        self.pos = self.pos.at[idx].set(int(entry.restore["pos"]))
        self.active[s] = True
        self._serial += 1
        self._admit_serial[s] = self._serial
        obs.counter("serve.restores").inc()
        return True

    def _paged_prepare(self):
        """Allocate / COW this tick's write-set pages for every active
        slot, preempting the newest request on pool exhaustion."""
        pc = self._pc
        copies: Dict[int, List[Tuple[int, int]]] = {}

        def flush():
            # preemption snapshots read self.caches: pending COW /
            # zero-init copies (possibly the victim's own) must land
            # first or the snapshot captures stale page bytes
            nonlocal copies
            if copies:
                self.caches = pc.apply_copies(self.caches, copies,
                                              self.cfg.num_kv_heads,
                                              self._stacked)
                copies = {}

        order = sorted((serial, s) for s, serial in
                       self._admit_serial.items())
        for _, s in order:
            if not self.active[s]:
                continue
            while True:
                try:
                    self.pool.prepare_tick(s, int(self.pos_host[s]),
                                           copies)
                    break
                except pc.PoolExhausted:
                    victim = self.sched.choose_victim(self._admit_serial)
                    if victim == s and len(self._admit_serial) == 1:
                        raise RuntimeError(
                            "page pool exhausted with a single active "
                            "request; increase pool_pages") from None
                    flush()
                    self._preempt(victim)
                    if victim == s:    # newest == self: requeued, move on
                        break
        flush()

    # -- tick ----------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one decode step for all active slots.
        Returns number of active slots."""
        with obs.span("serve.tick", tid=obs.TRACK_SERVE):
            n = self._step()
        if obs.enabled():
            self._tick_obs(n)
        return n

    def _step(self) -> int:
        with obs.span("serve.admit", tid=obs.TRACK_SERVE):
            self._admit()
        if not self.active.any():
            return 0
        if self.paged:
            with obs.span("serve.prepare", tid=obs.TRACK_SERVE):
                self._paged_prepare()
            if not self.active.any():        # everything preempted
                return 0
            tabs = self.pool.build_tables(self.pos_host, self.active,
                                          self.cfg.num_kv_heads)
            with obs.span("serve.decode", tid=obs.TRACK_SERVE):
                logits, self.caches = self._decode(self.params,
                                                   self.caches,
                                                   self.tokens, self.pos,
                                                   tabs)
        else:
            with obs.span("serve.decode", tid=obs.TRACK_SERVE):
                logits, self.caches = self._decode(self.params,
                                                   self.caches,
                                                   self.tokens, self.pos)
        if self.greedy:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            self.key, k = jax.random.split(self.key)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        self.tokens = nxt
        # Freeze finished/inactive slots: only slots active for THIS
        # decode advance.  A free-running pos eventually walks past the
        # cache rows, where the clamped cache writes would grind on the
        # last row every tick (and pos itself overflows); pinning t
        # keeps every write in range until the slot is re-admitted.
        act = self.active.astype(np.int32)
        self.pos = self.pos + jnp.asarray(act)
        self.pos_host += act     # mirrors the device update exactly
        nxt_host = np.asarray(nxt)
        feed_idx: List[int] = []
        feed_tok: List[int] = []
        for s in range(self.slots):
            if not self.active[s]:
                continue
            if self.feed[s]:
                # chunked prefill in flight: the model just absorbed one
                # prompt token; the next input is known, logits dropped
                feed_idx.append(s)
                feed_tok.append(self.feed[s].pop(0))
                continue
            req = self.req[s]
            req.out_tokens.append(int(nxt_host[s]))
            if obs.enabled():
                self._note_token(req)
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or int(self.pos_host[s]) >= self.max_len - 1
                    or self._stopped(req, int(nxt_host[s])))
            if done:
                if obs.enabled():
                    self._note_finish(req)
                self._release(s)
        if feed_idx:
            self.tokens = self.tokens.at[jnp.asarray(
                np.array(feed_idx, np.int32))].set(
                jnp.asarray(np.array(feed_tok, np.int32)))
        return int(self.active.sum())

    def run(self) -> None:
        while self.queue or self.active.any():
            self.step()
