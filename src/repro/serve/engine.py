"""Batched serving engine: fixed-slot continuous batching over the
unified model API (prefill + single-token decode with hierarchical KV
caches).

Design points for scale (DESIGN.md):
* decode state is a pure pytree -- slots join/leave by writing rows, the
  jit'd step never retraces;
* admission pads prompts to power-of-two length buckets, so prefill
  compiles O(log max_len) shapes, not one per distinct prompt length,
  and admits ALL queued requests sharing a bucket in one batched
  prefill call (per-row ``true_len``, row count padded to a power of
  two) so admission cost amortizes under load while the prefill jit
  cache stays O(log slots * log max_len);
* prompts longer than ``max_len - 1`` are rejected (or tail-truncated)
  at ``submit`` -- see ``ServeEngine.overflow``;
* finished slots are frozen (their ``pos`` stops advancing) so the
  clamped cache writes of an idle slot never walk out of range;
* per-tick bookkeeping reads a host-side numpy mirror of the slot
  positions -- one device sync per step (the sampled tokens), not one
  per active slot;
* the hierarchical H1D cache gives O(nr log L) attention per token --
  with ``decode_impl='pallas'`` the whole tick's attend runs as ONE
  fused kernel launch (and the ancestor update as one more), so
  long-context decode cost is flat in practice;
* the engine is deployment-shaped (request queue, slot map, step loop)
  while staying single-host here; the multi-pod serve driver shards the
  slot dim over DP axes (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    """``overflow`` policy for prompts longer than ``max_len - 1`` (the
    cache needs >= 1 free position to generate anything): ``'error'``
    rejects at ``submit()``; ``'truncate'`` keeps the LAST
    ``max_len - 1`` prompt tokens (most recent context) and serves the
    rest of the request normally.  Silent admission used to prefill a
    cache longer than the slot rows, corrupting neighbouring slots.

    ``decode_impl`` overrides ``cfg.decode_impl`` (``'jnp'`` |
    ``'pallas'`` | ``'pallas_interpret'``): ``'pallas'`` runs each
    decode tick through the fused single-launch hierarchical-KV kernels
    (``kernels/h1d_decode_kernel``).

    ``mesh`` enables sequence-parallel serving: the hierarchical cache
    shards its sequence axis over ``mesh[sp_axis]`` and every decode
    tick runs the fused kernels per shard under ``shard_map``
    (``repro.parallel.sp_attention``) -- the configuration that used to
    force ``impl='jnp'``.  Requires ``attention='h1d'`` and a padded
    ``max_len`` of at least ``data_axis_size * nr`` (one level-0 block
    per shard)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 512, greedy: bool = True, seed: int = 0,
                 overflow: str = "error", decode_impl: Optional[str] = None,
                 mesh=None, sp_axis: str = "data"):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "ServeEngine targets decoder-only families; enc-dec serving "
                "goes through launch/serve.py with per-request encoder runs")
        if overflow not in ("error", "truncate"):
            raise ValueError(f"unknown overflow policy {overflow!r}")
        if decode_impl is not None and decode_impl != cfg.decode_impl:
            cfg = dataclasses.replace(cfg, decode_impl=decode_impl)
        from repro.models.transformer import _stacked_caches
        from repro.parallel.sp_attention import sp_scope
        self.cfg = cfg
        self.overflow = overflow
        self.params = params
        self.fns = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._slot_axis = 1 if _stacked_caches(cfg) else 0

        self.mesh = mesh
        self.sp_axis = sp_axis
        sp_d = dict(mesh.shape).get(sp_axis, 1) if mesh is not None else 1
        if sp_d > 1:
            if cfg.attention != "h1d":
                raise ValueError(
                    "SP serving shards the hierarchical cache's sequence "
                    f"axis; attention={cfg.attention!r} has no such cache")
            from repro.core import hierarchy as hc
            Lp = hc.padded_length(max_len, cfg.nr)
            if Lp < sp_d * cfg.nr or Lp % (sp_d * cfg.nr):
                raise ValueError(
                    f"SP serving: padded max_len {Lp} cannot keep one "
                    f"nr={cfg.nr} block per shard on a {sp_d}-way "
                    f"'{sp_axis}' axis; use fewer shards or a longer "
                    f"max_len")

        self.caches = self.fns.init_caches(params, cfg, slots, max_len)
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.pos = jnp.zeros((slots,), jnp.int32)
        # host-side mirror of ``pos``: the decode loop reads positions
        # every tick (done checks); keeping a numpy twin avoids a device
        # sync per active slot per step.
        self.pos_host = np.zeros((slots,), np.int64)
        self.active = np.zeros((slots,), bool)
        self.req: List[Optional[Request]] = [None] * slots
        # queued (request, admitted-prompt) pairs: the prompt copy may be
        # tail-truncated (overflow='truncate') without touching req.prompt
        self.queue: List[Tuple[Request, np.ndarray]] = []

        # Prompt length bucketing: right-pad prompts to the next power of
        # two (capped at max_len) so _prefill1 compiles O(log max_len)
        # shapes instead of one per distinct prompt length.  Only safe
        # when the padded tail cannot reach the true-position logits or
        # the decode-visible cache, so gated off for:
        #  * recurrent families (ssm/hybrid): the SSM prefill scan over
        #    pad tokens corrupts the state (and encdec never gets here);
        #  * sliding-window configs: the rolling local cache keeps only
        #    the LAST 2*window rows, so pads evict real in-window keys;
        #  * h1d coarse-q: coarse QUERY means average pad embeddings
        #    across cluster boundaries (the documented leak, DESIGN.md
        #    1.2), shifting logits at the true last token.
        self._bucket = (cfg.family not in ("ssm", "hybrid", "encdec")
                        and cfg.sliding_window == 0
                        and (cfg.attention != "h1d"
                             or cfg.causal_mode == "fine-q"))

        # the sp_scope context is entered at TRACE time (jit traces the
        # wrapper synchronously), so the h1d decode/attention entry
        # points see the mesh and route through the shard_map'd kernels
        def _decode_traced(p, c, tok, t):
            with sp_scope(self.mesh, self.sp_axis):
                return self.fns.decode_step(p, cfg, c, tok, t)

        def _prefill_traced(p, batch, n):
            with sp_scope(self.mesh, self.sp_axis):
                return self.fns.prefill(p, cfg, batch, max_len, true_len=n)

        self._decode = jax.jit(_decode_traced)
        self._prefill1 = jax.jit(_prefill_traced)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Queue a request.  Prompts longer than ``max_len - 1`` (no
        room left to generate) are rejected or tail-truncated per the
        engine's ``overflow`` policy -- silently admitting them used to
        prefill an over-long cache whose slot write sliced into
        neighbouring slots' rows."""
        prompt = np.asarray(req.prompt, np.int32)
        S = int(prompt.shape[0])
        limit = self.max_len - 1
        if S > limit:
            if self.overflow == "truncate":
                # truncate a private copy -- the caller's Request object
                # is left intact (it may be logged or re-submitted to an
                # engine with a larger max_len)
                prompt = prompt[-limit:]
            else:
                raise ValueError(
                    f"prompt length {S} > max_len - 1 = {limit}; shorten "
                    f"the prompt or construct the engine with "
                    f"overflow='truncate'")
        req.out_tokens = []
        self.queue.append((req, prompt))

    def _bucket_len(self, S: int) -> int:
        """Padded prompt length: next power of two capped at max_len
        (identity when bucketing is gated off for this config)."""
        if not self._bucket:
            return S
        return max(S, min(1 << max(S - 1, 0).bit_length(), self.max_len))

    def _admit(self):
        """Prefill queued requests into free slots.  Requests are taken
        in FIFO order and grouped by padded-length bucket: every queued
        request sharing the head-of-queue's bucket (up to the number of
        free slots) prefills in ONE batched ``_prefill1`` call with a
        per-row ``true_len`` vector, so admission under load costs one
        forward per bucket instead of one per request.  The row count is
        padded to a power of two as well (dummy rows discarded), keeping
        the prefill jit cache at O(log slots * log max_len) shapes."""
        while self.queue:
            free = [s for s in range(self.slots) if not self.active[s]]
            if not free:
                return
            Lb = self._bucket_len(len(self.queue[0][1]))
            group: List[Request] = []
            plist: List[np.ndarray] = []
            while (self.queue and len(group) < len(free)
                   and self._bucket_len(len(self.queue[0][1])) == Lb):
                r, p = self.queue.pop(0)
                group.append(r)
                plist.append(p)
            g = len(group)
            gp = 1 << (g - 1).bit_length()       # pow2 row count
            prompts = np.zeros((gp, Lb), np.int32)
            ns = np.ones((gp,), np.int32)        # dummy rows: true_len 1
            for i, p in enumerate(plist):
                prompts[i, :len(p)] = p
                ns[i] = len(p)
            batch = {"tokens": jnp.asarray(prompts)}
            logits, caches, pos = self._prefill1(self.params, batch,
                                                 jnp.asarray(ns))
            dst = free[:g]
            if self.greedy:
                nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            else:
                # Sample the first generated token with PER-ROW keys:
                # one split per batched call, then each row folds in its
                # DESTINATION SLOT index (dummy pad rows use indices past
                # the slot range).  A single categorical over the padded
                # (gp, V) logits drew one gumbel tensor shaped by gp, so
                # the same request could sample a DIFFERENT first token
                # depending on how many dummy rows its bucket happened
                # to get -- sampling must be invariant to padding.
                self.key, kbase = jax.random.split(self.key)
                row_ids = jnp.asarray(
                    np.array(dst + list(range(self.slots,
                                              self.slots + gp - g)),
                             np.int32))
                keys = jax.vmap(jax.random.fold_in, (None, 0))(kbase,
                                                               row_ids)
                nxt = np.asarray(jax.vmap(jax.random.categorical)(
                    keys, logits)).astype(np.int32)
            # Write the whole group into its slots with ONE tree.map
            # pass (contiguous free slots collapse to a single slice
            # write).  The slot dim (0, or 1 for scanned layer stacks)
            # may fold kv-heads into the batch (h1d caches: B*Hkv
            # rows), so slot s spans rows [s*r, (s+1)*r) with
            # r = full_rows // slots == rows per request of the batched
            # prefill cache.
            ax = self._slot_axis
            contig = dst == list(range(dst[0], dst[0] + g))

            def write(full, one):
                r = full.shape[ax] // self.slots
                src = [slice(None)] * one.ndim
                src[ax] = slice(0, g * r)
                idx = [slice(None)] * full.ndim
                if contig:
                    # slice write lowers to one dynamic_update_slice
                    idx[ax] = slice(dst[0] * r, (dst[0] + g) * r)
                else:
                    # one row-index scatter -- NOT one full-cache copy
                    # per destination slot
                    rows = np.concatenate([np.arange(s * r, (s + 1) * r)
                                           for s in dst])
                    idx[ax] = jnp.asarray(rows)
                return full.at[tuple(idx)].set(one[tuple(src)])

            self.caches = jax.tree.map(write, self.caches, caches)
            # batched token/pos scatter: 2 dispatches per group, not 2g
            idx = jnp.asarray(np.array(dst, np.int32))
            self.tokens = self.tokens.at[idx].set(jnp.asarray(nxt[:g]))
            self.pos = self.pos.at[idx].set(jnp.asarray(ns[:g]))
            for i, req in enumerate(group):
                s = dst[i]
                self.pos_host[s] = int(ns[i])
                self.req[s] = req
                req.out_tokens.append(int(nxt[i]))
                # done-check at admission: the first sampled token may
                # already satisfy max_new_tokens (or the prompt already
                # fills the cache) -- the slot then never activates, so
                # no decode tick is wasted and max_new_tokens is a hard
                # cap (regression: every request used to get >= 2
                # tokens).
                done = (len(req.out_tokens) >= req.max_new_tokens
                        or int(ns[i]) >= self.max_len - 1)
                if done:
                    self.req[s] = None
                else:
                    self.active[s] = True

    def step(self) -> int:
        """One engine tick: admit + one decode step for all active slots.
        Returns number of active slots."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.caches = self._decode(self.params, self.caches,
                                           self.tokens, self.pos)
        if self.greedy:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            self.key, k = jax.random.split(self.key)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        self.tokens = nxt
        # Freeze finished/inactive slots: only slots active for THIS
        # decode advance.  A free-running pos eventually walks past the
        # cache rows, where the clamped cache writes would grind on the
        # last row every tick (and pos itself overflows); pinning t
        # keeps every write in range until the slot is re-admitted.
        act = self.active.astype(np.int32)
        self.pos = self.pos + jnp.asarray(act)
        self.pos_host += act     # mirrors the device update exactly
        nxt_host = np.asarray(nxt)
        for s in range(self.slots):
            if not self.active[s]:
                continue
            req = self.req[s]
            req.out_tokens.append(int(nxt_host[s]))
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or int(self.pos_host[s]) >= self.max_len - 1)
            if done:
                self.active[s] = False
                self.req[s] = None
        return int(self.active.sum())

    def run(self) -> None:
        while self.queue or self.active.any():
            self.step()
