"""Pure-JAX optimizers, schedules, and gradient compression."""
from .adamw import (adamw, adafactor, apply_updates, cosine_schedule,
                    linear_schedule, clip_by_global_norm, global_norm,
                    Optimizer, AdamWState, AdafactorState)
from .compression import (init_error_feedback, int8_compress, topk_compress,
                          EFState)
