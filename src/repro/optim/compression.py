"""Gradient compression with error feedback for cross-pod all-reduce.

At 2+ pods the data-center interconnect (DCI) between pods is the
scarcest bandwidth; compressing the *cross-pod* gradient reduction is the
standard distributed-optimization trick.  We implement:

* ``int8_compress`` -- per-tensor scale int8 quantization (4x for f32,
  2x for bf16) with error-feedback residual accumulation, and
* ``topk_compress`` -- magnitude top-k sparsification (k as a fraction)
  with error feedback.

Both are *reduction-compatible*: the compressed representation is
all-reduced (psum of dequantized values inside shard_map over the
``pod`` axis), and the quantization error is carried to the next step, so
SGD-style convergence is preserved (Karimireddy et al., 2019).

Usage (see ``repro.train.loop``): wrap the gradient tree between the
in-pod reduction (done by pjit's sharding of the batch over ``data``)
and the optimizer update.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..core.quantization import quantize_int8, dequantize_int8


class EFState(NamedTuple):
    residual: Any


def init_error_feedback(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


# Single rounding rule shared with the int8 paged KV-cache -- see
# core/quantization.py.  Per-tensor scale (axis=None) is the wire
# format here.
_quantize_int8 = quantize_int8
_dequantize_int8 = dequantize_int8


def int8_compress(grads, ef: EFState) -> Tuple[Any, EFState]:
    """Returns (compressed-then-decompressed grads, new error feedback).
    The int8 payload is what would cross the pod link; the caller
    all-reduces the dequantized values (numerically identical, and lets
    XLA fuse; the wire format is documented for a real deployment)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize_int8(x)
        deq = _dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            EFState(tdef.unflatten([o[1] for o in out])))


def topk_compress(grads, ef: EFState, frac: float = 0.05
                  ) -> Tuple[Any, EFState]:
    """Keep the top ``frac`` fraction of entries by magnitude."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        flat = x.reshape(-1)
        k = max(1, int(flat.shape[0] * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(x) >= thresh, x, 0.0)
        return kept.astype(g.dtype), x - kept

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            EFState(tdef.unflatten([o[1] for o in out])))
