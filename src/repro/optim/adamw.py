"""Pure-JAX optimizers (optax is unavailable offline): AdamW and
Adafactor, with global-norm clipping and LR schedules.

API mirrors the (init, update) gradient-transformation convention:

    opt = adamw(lr_schedule, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(peak_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        dec = peak_lr * jnp.clip(1.0 - (step - warmup)
                                 / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, dec)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw(lr: Callable, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(f32, params),
                          jax.tree.map(f32, params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        lr_t = lr(step)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = -(lr_t * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                          + weight_decay * p.astype(jnp.float32)))
            return u, m, v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return updates, AdamWState(step, mu, nu)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory-lean for 10B+ params)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any     # row factors (or full v for <2D)
    vc: Any     # col factors


def adafactor(lr: Callable, decay=0.8, eps=1e-30,
              clip_threshold=1.0) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vc_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vr_init, params),
                              jax.tree.map(vc_init, params))

    def update(grads, state, params):
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr(step)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                )[..., None]
                cfac = jax.lax.rsqrt(vc)[..., None, :]
                u = g * rfac * cfac
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(vr)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, vr, vc

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(state.vr)
        flat_c = tdef.flatten_up_to(state.vc)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, r, c, p) for g, r, c, p
               in zip(flat_g, flat_r, flat_c, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        vr = tdef.unflatten([o[1] for o in out])
        vc = tdef.unflatten([o[2] for o in out])
        return updates, AdafactorState(step, vr, vc)

    return Optimizer(init, update)
