"""repro: H-Transformer-1D hierarchical attention as a production JAX framework."""
__version__ = "0.1.0"
