"""Shared model components: config, param init with sharding specs, norms,
RoPE, activation-sharding helpers.

Parameter handling is pure JAX: ``init`` functions return
``(params, specs)`` twin pytrees, where ``specs`` holds a
``jax.sharding.PartitionSpec`` per array.  Spec generation is
divisibility-aware: an axis is sharded over the tensor-parallel mesh axis
only if its size divides evenly (else replicated), so every assigned
architecture lowers cleanly on the production mesh.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | encdec | vlm | audio | ssm | hybrid
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    # --- attention ---------------------------------------------------------
    attention: str = "h1d"       # h1d | full | paper's baseline comparison
    nr: int = 16                 # N_r, the paper's single hyper-parameter
    causal_mode: str = "fine-q"  # fine-q (leak-free) | coarse-q (paper-faithful)
    attn_impl: str = "jnp"       # auto | jnp | pallas | pallas_interpret
                                 # ('auto': kernels.tuning.KernelPolicy
                                 # resolves per backend)
    attn_tq: Optional[int] = None  # Pallas query-tile rows override
                                 # (multiple of nr); None = the policy's
                                 # tuning table picks per launch
    decode_impl: str = "jnp"     # serving decode tick: auto | jnp | pallas
                                 # | pallas_interpret (fused single-launch
                                 # hierarchical-KV attend + ancestor update)
    cache_dtype: str = "fp32"    # paged KV-page storage: fp32 | int8
                                 # (int8: symmetric per-row scales, see
                                 # core.quantization; paged engine only)
    cache_quant_levels: int = -1  # with cache_dtype='int8': quantize
                                 # hierarchy levels [0, n); -1 = all
                                 # levels (coarse rows are pairwise
                                 # means -> ever-shrinking dynamic
                                 # range, so all-level is the default)
    qkv_bias: bool = False       # qwen2.x
    qk_norm: bool = False        # gemma3
    sliding_window: int = 0      # >0: local layers use block-local attention
    global_every: int = 0        # gemma3: layer i is global iff i % global_every == global_every-1
    rope_theta: float = 10_000.0
    # --- FFN / MoE ---------------------------------------------------------
    mlp_activation: str = "swiglu"   # swiglu | geglu
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_d_ff: int = 0     # qwen2-moe shared expert width
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_capacity_factor: float = 1.25
    moe_aux_loss: float = 0.01
    # --- SSM (mamba2 / hybrid) ---------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 64
    hybrid_attn_every: int = 6   # zamba2: shared attention block cadence
    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0
    # --- frontends (stubs per assignment) -----------------------------------
    prefix_len: int = 0          # vlm: number of patch embeddings
    # --- numerics / misc ----------------------------------------------------
    dtype: str = "float32"
    tie_embeddings: bool = False
    remat: bool = False          # activation checkpointing per layer
    force_loop: bool = False     # disable scan-over-layers (roofline
                                 # accounting: XLA cost_analysis counts
                                 # while bodies once)
    seq_parallel_residual: bool = True  # Megatron-style SP: shard the
                                 # residual sequence axis over "model"
                                 # (memory win, pays per-layer gathers)
    remat_policy: str = "dots"   # full | dots | none -- "dots" saves
                                 # matmul operands so the backward pass
                                 # does not re-gather TP activations

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_uses_global_attn(self, i: int) -> bool:
        if self.global_every <= 0:
            return True
        return i % self.global_every == self.global_every - 1

    def layer_is_attn(self, i: int) -> bool:
        """hybrid (zamba2): which layers run the shared attention block."""
        return (i % self.hybrid_attn_every) == self.hybrid_attn_every - 1


# ---------------------------------------------------------------------------
# tensor-parallel axis helpers
# ---------------------------------------------------------------------------

_TP_AXIS = "model"
_DP_AXES = ("pod", "data")

_state = threading.local()


def set_mesh_axes(tp_size: Optional[int]) -> None:
    """Record the tensor-parallel degree for divisibility-aware specs.
    ``None`` disables sharding decisions (single-device tests)."""
    _state.tp = tp_size


def tp_size() -> Optional[int]:
    return getattr(_state, "tp", None)


def shard_if_divisible(size: int) -> Optional[str]:
    tp = tp_size()
    if tp and size % tp == 0:
        return _TP_AXIS
    return None


def logical(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """Activation sharding constraint; no-op outside a mesh context."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        names = set(mesh.axis_names)
        clean = []
        for a in axes:
            if a is None:
                clean.append(None)
            elif isinstance(a, str):
                clean.append(a if a in names else None)
            else:
                sub = tuple(s for s in a if s in names)
                clean.append(sub if sub else None)
        return jax.lax.with_sharding_constraint(x, P(*clean))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# initializers (params + spec twins)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, *, out_shard: bool = True,
               in_shard: bool = False, bias: bool = False,
               scale: Optional[float] = None):
    """2D projection.  Returns (params, specs)."""
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(s, dtype)
    spec_in = shard_if_divisible(d_in) if in_shard else None
    spec_out = shard_if_divisible(d_out) if out_shard else None
    params = {"w": w}
    specs = {"w": P(spec_in, spec_out)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        specs["b"] = P(spec_out)
    return params, specs


def dense_apply(p, x):
    # Explicit accumulator dtype = activation dtype: GSPMD then
    # all-reduces TP matmul partials in bf16 instead of f32 (the MXU
    # still accumulates f32 internally per tile) -- halves TP wire bytes.
    y = jax.lax.dot_general(
        x, p["w"].astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"w": w}, {"w": P(shard_if_divisible(vocab), None)}


def rmsnorm_init(d: int, dtype):
    return {"g": jnp.ones((d,), dtype)}, {"g": P(None)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def grad_dtype_boundary(x, dtype=None):
    """Identity in the forward pass; casts the COTANGENT to ``dtype``
    (default: x.dtype) in the backward pass.  Placed between the layer
    stack and the f32 loss head so backward TP all-reduces run in bf16
    (standard mixed-precision practice; halves backward wire bytes)."""
    dt = dtype or x.dtype

    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, ct):
        return (ct.astype(dt),)

    f.defvjp(fwd, bwd)
    return f(x)


def activation(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "geglu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)
