"""Mamba2 mixer with the SSD (state-space duality) chunked algorithm.

The paper's H1D attention is inapplicable to this attention-free family
(DESIGN.md section 5); we implement the SSD algorithm faithfully --
itself block-structured, which composes naturally with the rest of the
framework.  Shapes follow Dao & Gu (2024):

  x  : (B, S, H, Ph)   -- H heads of head-dim Ph (d_inner = H * Ph)
  dt : (B, S, H)       -- softplus-activated step sizes
  A  : (H,)            -- negative decay rates
  Bm, Cm : (B, S, G, N) -- input/output projections (G groups, state N)

``ssd_chunked`` computes the exact linear recurrence
``h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T; y_t = C_t h_t + D x_t``
in chunks: quadratic attention-like intra-chunk term + an inter-chunk
state scan.  ``ssd_reference`` is the naive per-step oracle for tests.
``ssd_step`` is the O(1) decode update (used for decode_32k/long_500k).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (
    ModelConfig, dense_init, dense_apply, rmsnorm_init, rmsnorm_apply,
    shard_if_divisible)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(x):
    """x: (..., Q).  Returns (..., Q, Q) with out[i, j] = sum_{j<t<=i} x_t
    for i >= j, -inf otherwise (log of the decay matrix)."""
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, h0=None):
    """Returns (y, h_final).  See module docstring for shapes.
    h0: optional initial state (B, H, N, Ph)."""
    Bsz, S, H, Ph = x.shape
    G, N = Bm.shape[-2:]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G
    f32 = jnp.float32

    xc = x.reshape(Bsz, nc, chunk, H, Ph).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3).astype(f32)

    dA = dtc * A.astype(f32)                          # (B, nc, Q, H) (<= 0)
    dA_cs = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum

    # ---- intra-chunk (diagonal) term -------------------------------------
    Ldec = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)  # (B, nc, H, Q, Q)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp",
                        scores * Ldec, dtc, xc)

    # ---- chunk states ------------------------------------------------------
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)   # (B, nc, Q, H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchnp",
                        Bc, decay_states, dtc, xc)        # (B, nc, H, N, Ph)

    # ---- inter-chunk scan --------------------------------------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (B, nc, H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, Ph), f32)

    def scan_fn(h, inp):
        st, dec = inp                                      # (B,H,N,P), (B,H)
        h_prev = h
        h = h * dec[..., None, None] + st
        return h, h_prev

    hs, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B, nc, H, N, Ph)

    # ---- inter-chunk (off-diagonal) output ---------------------------------
    state_decay_out = jnp.exp(dA_cs)                       # (B, nc, Q, H)
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       Cc, h_prevs, state_decay_out)

    y = (y_diag + y_off).reshape(Bsz, S, H, Ph)
    return y.astype(x.dtype), hs


def ssd_reference(x, dt, A, Bm, Cm, *, h0=None):
    """Naive per-step recurrence (oracle)."""
    Bsz, S, H, Ph = x.shape
    G, N = Bm.shape[-2:]
    rep = H // G
    f32 = jnp.float32
    Bf = jnp.repeat(Bm, rep, axis=2).astype(f32)
    Cf = jnp.repeat(Cm, rep, axis=2).astype(f32)
    h = (jnp.zeros((Bsz, H, N, Ph), f32) if h0 is None else h0)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        dec = jnp.exp(dtt * A.astype(f32))                  # (B, H)
        h = h * dec[..., None, None] + jnp.einsum(
            "bhn,bh,bhp->bhnp", Bt, dtt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    h, ys = jax.lax.scan(
        step, h,
        (x.transpose(1, 0, 2, 3).astype(f32), dt.transpose(1, 0, 2).astype(f32),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


def ssd_step(h, xt, dtt, A, Bt, Ct):
    """Single decode step.  h: (B, H, N, Ph); xt: (B, H, Ph);
    dtt: (B, H); Bt/Ct: (B, G, N).  Returns (y (B, H, Ph), h)."""
    H = xt.shape[1]
    rep = H // Bt.shape[1]
    f32 = jnp.float32
    Bf = jnp.repeat(Bt, rep, axis=1).astype(f32)
    Cf = jnp.repeat(Ct, rep, axis=1).astype(f32)
    dec = jnp.exp(dtt.astype(f32) * A.astype(f32))
    h = h * dec[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bf, dtt.astype(f32), xt.astype(f32))
    y = jnp.einsum("bhn,bhnp->bhp", Cf, h)
    return y.astype(xt.dtype), h


# ---------------------------------------------------------------------------
# Mamba2 mixer layer
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    G = 1
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * G * N
    return d_inner, H, G, N, conv_dim


def mamba2_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner, H, G, N, conv_dim = mamba2_dims(cfg)
    keys = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * G * N + H
    p_in, s_in = dense_init(keys[0], d, d_in_proj, dtype)
    p_out, s_out = dense_init(keys[1], d_inner, d, dtype, in_shard=True,
                              out_shard=False)
    nrm, nrm_s = rmsnorm_init(d_inner, dtype)
    params = {
        "in_proj": p_in,
        "out_proj": p_out,
        "conv_w": jax.random.normal(keys[2], (cfg.ssm_conv_width, conv_dim),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(H), H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": nrm,
    }
    specs = {
        "in_proj": s_in,
        "out_proj": s_out,
        "conv_w": P(None, shard_if_divisible(conv_dim)),
        "conv_b": P(shard_if_divisible(conv_dim)),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": nrm_s,
    }
    return params, specs


def _split_in_proj(cfg, zxbcdt):
    d_inner, H, G, N, _ = mamba2_dims(cfg)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + G * N,
                 2 * d_inner + 2 * G * N], axis=-1)
    return z, xin, Bm, Cm, dt


def _causal_conv(u, w, b, prev=None):
    """Depthwise causal conv.  u: (B, S, C); w: (W, C); prev: (B, W-1, C)."""
    W = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], W - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([prev, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i][None, None] for i in range(W))
    return jax.nn.silu(out + b[None, None]), up[:, -(W - 1):]


def mamba2_apply(p, cfg: ModelConfig, x, *, h0=None, conv0=None,
                 return_state=False):
    """x: (B, S, d).  Returns out or (out, (h, conv_state))."""
    B, S, d = x.shape
    d_inner, H, G, N, conv_dim = mamba2_dims(cfg)
    zxbcdt = dense_apply(p["in_proj"], x)
    z, xin, Bm, Cm, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), conv0)
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xh = xin.reshape(B, S, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:
        chunk = math.gcd(S, chunk) or 1
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)
    if return_state:
        return out, (h, conv_state)
    return out


def mamba2_decode(p, cfg: ModelConfig, x, state):
    """Single-token decode.  x: (B, 1, d); state: (h, conv_state)."""
    B = x.shape[0]
    d_inner, H, G, N, conv_dim = mamba2_dims(cfg)
    h, conv_prev = state
    zxbcdt = dense_apply(p["in_proj"], x)
    z, xin, Bm, Cm, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype), conv_prev)
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xh = xin.reshape(B, H, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_step(h, xh, dt, A, Bm.reshape(B, G, N), Cm.reshape(B, G, N))
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    return dense_apply(p["out_proj"], y), (h, conv_state)
