"""Attention layer: H1D (paper), full (baseline), block-local (sliding
window) -- with train, prefill and single-token decode paths.

Cache layouts (per layer):
  * h1d     -- ``repro.core.h1d_decode.H1DCache`` with batch*kv_heads
               folded into the leading dim (hierarchical coarse levels).
  * full    -- dict(k=(B, L, Hkv, D), v=..., )
  * local   -- same as full but logically a ring of the last 2*window
               tokens (stored full-size for simplicity of paging;
               the serve engine may allocate only 2*window).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import (h1d_attention, h1d_attention_mha, dense_attention,
                        h1d_decode, fold_kv_heads, unfold_kv_heads)
from repro.core import hierarchy as hc
from repro.kernels import band_attention
from .common import (
    ModelConfig, dense_init, dense_apply, rmsnorm_init, rmsnorm_apply,
    apply_rope, logical, tp_size)


def attn_init(key, cfg: ModelConfig, dtype):
    keys = jax.random.split(key, 4)
    hq, hkv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    params, specs = {}, {}
    # K and V are fused into one projection (the split point hkv*hd is a
    # multiple of the 2*hkv*hd/TP shard size, so GSPMD splits cleanly);
    # fusing Q too would break shard alignment under GQA.  One fewer
    # backward all-reduce per layer.
    #
    # Head-aware sharding: project outputs are sharded over "model" only
    # when the HEAD count divides the TP degree -- otherwise the
    # (B,S,H,hd) reshape is inexpressible for GSPMD and every layer pays
    # an all-gather (EXPERIMENTS.md P13).  Replicating the (small) KV
    # projection is cheaper than gathering (B,S,Hkv,hd) activations.
    tp = tp_size() or 1
    p, s = dense_init(keys[0], d, hq * hd, dtype, bias=cfg.qkv_bias,
                      out_shard=hq % tp == 0)
    params["wq"], specs["wq"] = p, s
    p, s = dense_init(keys[1], d, 2 * hkv * hd, dtype, bias=cfg.qkv_bias,
                      out_shard=hkv % tp == 0)
    params["wkv"], specs["wkv"] = p, s
    p, s = dense_init(keys[3], hq * hd, d, dtype, in_shard=True,
                      out_shard=False, scale=1.0 / math.sqrt(hq * hd))
    params["wo"], specs["wo"] = p, s
    if cfg.qk_norm:
        for n in ("qn", "kn"):
            p, s = rmsnorm_init(hd, dtype)
            params[n], specs[n] = p, s
    return params, specs


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, S, hq, hd)
    kv = dense_apply(p["wkv"], x)
    k, v = jnp.split(kv, 2, axis=-1)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["qn"], q)
        k = rmsnorm_apply(p["kn"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    tp = tp_size() or 1
    qax = "model" if hq % tp == 0 else None
    kax = "model" if hkv % tp == 0 else None
    q = logical(q, ("pod", "data"), None, qax, None)
    k = logical(k, ("pod", "data"), None, kax, None)
    v = logical(v, ("pod", "data"), None, kax, None)
    return q, k, v


def _heads_as_g(q, k, v):
    """GSPMD-friendly multi-head layout: q (B, L, Hq, D),
    k/v (B, L, Hkv, D) -> (B, Hq, L, D) for all three (KV repeated to Hq).

    The head axis becomes the core's G dim and flows through every einsum
    unchanged -- no sharded-dim splits/merges or size-1 batch dims, so
    the SPMD partitioner never falls back to full rematerialization.
    On real TPU the Pallas path instead folds GQA into the kernel grid
    (BlockSpec index maps broadcast KV without repeats).
    """
    Hq, Hkv = q.shape[2], k.shape[2]
    if Hq != Hkv:
        G = Hq // Hkv
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    perm = (0, 2, 1, 3)
    return q.transpose(perm), k.transpose(perm), v.transpose(perm)


def _local_attention(q, k, v, window: int, causal: bool, kv_weight, impl,
                     tq: Optional[int] = None):
    """Block-local sliding-window attention via the band kernel with
    block size = window (the paper's 'Local Attention' baseline)."""
    from repro.kernels.tuning import get_policy
    policy = get_policy()
    impl = policy.resolve_impl(impl)
    B, L, Hq, D = q.shape
    if impl != "jnp":
        if tq is None:
            # tile hint from the policy's tuning table (window is nr here)
            tq = policy.band_tq(L=L, nr=window,
                                mode="l0_causal" if causal else "l0_bidir",
                                dtype=str(q.dtype))
        if tq % window:
            # kernel tiling needs tq % nr == 0: shrink the tile hint to
            # the largest window multiple instead of silently abandoning
            # the kernel path (band_attention refines it further)
            tq = max(window, (tq // window) * window)
    # kernel tiling also needs L % tq == 0; tq is a multiple of window
    # here, so padding to the tile unit keeps the block structure intact
    unit = window if impl == "jnp" else tq
    Lp = ((L + unit - 1) // unit) * unit
    pad = Lp - L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    w = jnp.ones((B, Lp), jnp.float32)
    if kv_weight is not None:
        w = w * jnp.pad(kv_weight, ((0, 0), (0, pad)))
    elif pad:
        w = w.at[:, L:].set(0.0)
    scale = 1.0 / math.sqrt(D)
    mode = "l0_causal" if causal else "l0_bidir"
    if impl == "jnp":
        # GSPMD-friendly layout: heads as the core G dim, per-head 4-D KV.
        qh, kh, vh = _heads_as_g(q, k, v)
        y, dn, _ = band_attention(qh * scale, kh, vh * w[:, None, :, None],
                                  w, nr=window, mode=mode, impl="jnp")
        z = (y / jnp.maximum(dn, 1e-9)[..., None]).astype(q.dtype)
        return z.transpose(0, 2, 1, 3)[:, :L]
    # kernel path: fold kv-heads into batch, GQA group into G (3-D KV --
    # the Pallas grid broadcasts KV across G without replication).
    qh, kh, vh, fold = fold_kv_heads(q, k, v)
    wr = jnp.repeat(w, fold[1], axis=0)
    y, dn, _ = band_attention(qh * scale, kh, vh * wr[..., None], wr,
                              nr=window, mode=mode, impl=impl, tq=tq)
    z = (y / jnp.maximum(dn, 1e-9)[..., None]).astype(q.dtype)
    return unfold_kv_heads(z, fold)[:, :L]


def attn_apply(p, cfg: ModelConfig, x, positions, *, causal=True,
               kv_weight=None, layer_global=True):
    """Training/encoding attention.  x: (B, S, d); positions: (B, S)."""
    B, S, _ = x.shape
    from repro.kernels.tuning import get_policy
    impl = get_policy().resolve_impl(cfg.attn_impl)
    q, k, v = _project_qkv(p, cfg, x, positions)
    use_local = cfg.sliding_window > 0 and not layer_global
    if use_local:
        z = _local_attention(q, k, v, cfg.sliding_window, causal, kv_weight,
                             impl, tq=cfg.attn_tq)
    elif cfg.attention == "h1d":
        if impl in ("pallas", "pallas_interpret"):
            # kernel path: heads fold into the pallas grid.  Every level
            # is fused -- level 0 via the symmetric band modes, and (for
            # causal_mode='fine-q') each coarse level via mode='sub', so
            # a causal train step never leaves the kernel path.
            Lp = hc.padded_length(S, cfg.nr)
            pad = Lp - S
            if pad:
                q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            w = jnp.ones((B, Lp), jnp.float32)
            if kv_weight is not None:
                w = w * jnp.pad(kv_weight, ((0, 0), (0, pad)))
            elif pad:
                w = w.at[:, S:].set(0.0)
            z = h1d_attention_mha(q, k, v, nr=cfg.nr, causal=causal,
                                  causal_mode=cfg.causal_mode, kv_weight=w,
                                  impl=impl, tq=cfg.attn_tq)[:, :S]
        else:
            Lp = hc.padded_length(S, cfg.nr)
            pad = Lp - S
            if pad:
                q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            w = jnp.ones((B, Lp), jnp.float32)
            if kv_weight is not None:
                w = w * jnp.pad(kv_weight, ((0, 0), (0, pad)))
            elif pad:
                w = w.at[:, S:].set(0.0)
            qh, kh, vh = _heads_as_g(q, k, v)
            z = h1d_attention(qh, kh, vh, nr=cfg.nr, causal=causal,
                              causal_mode=cfg.causal_mode, kv_weight=w,
                              impl=impl, tq=cfg.attn_tq)
            z = z.transpose(0, 2, 1, 3)[:, :S]
    elif cfg.attention == "full":
        qh, kh, vh = _heads_as_g(q, k, v)
        z = dense_attention(qh, kh, vh, causal=causal, kv_weight=kv_weight)
        z = z.transpose(0, 2, 1, 3)
    else:
        raise ValueError(cfg.attention)
    # NOTE: kept "model" even for non-divisible head counts: GSPMD pads
    # (56->64) and pays backward all-gathers, but replicating instead
    # doubles the memory term (EXPERIMENTS.md P19, a wash on the max
    # term and worse on HBM capacity).
    z = logical(z, ("pod", "data"), None, "model", None)
    return dense_apply(p["wo"], z.reshape(B, S, -1))


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, B: int, Lmax: int, *, layer_global=True,
                      dtype=jnp.float32):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    local = cfg.sliding_window > 0 and not layer_global
    if cfg.attention == "h1d" and not local:
        Lmax = hc.padded_length(Lmax, cfg.nr)   # needs nr * 2**k
        return h1d_decode.init_cache(B * hkv, Lmax, hd, hd, cfg.nr, dtype)
    Lc = min(Lmax, 2 * cfg.sliding_window) if local else Lmax
    return {
        "k": jnp.zeros((B, Lc, hkv, hd), dtype),
        "v": jnp.zeros((B, Lc, hkv, hd), dtype),
        "pos": jnp.full((B, Lc), -1, jnp.int32),
    }


def attn_decode(p, cfg: ModelConfig, x, t, cache, *, layer_global=True,
                page_tables=None):
    """Single-token decode.  x: (B, 1, d); t: (B,) current position.
    Returns (out (B, 1, d), new_cache).

    ``page_tables`` (``core.h1d_decode.PageTables``) switches the h1d
    path to the PAGED cache pool: ``cache`` is then a ``PagedH1DCache``
    of nr-row pages and the per-tick indirection tables route every
    block read/write (serve/paged_cache.py builds them host-side).  A
    ``QuantPagedH1DCache`` (``cache_dtype='int8'``) rides the same two
    calls -- the core entry points dispatch on the pool type, so the
    quantized kernels (per-row dequant at the gathers, in-place
    requantize of the sibling-pair writes) need no model-layer
    plumbing beyond the cache pytree itself."""
    B = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = hq // hkv
    q, k, v = _project_qkv(p, cfg, x, t[:, None])
    q1 = q[:, 0].reshape(B, hkv, G, hd).reshape(B * hkv, G, hd)
    k1 = k[:, 0].reshape(B * hkv, hd)
    v1 = v[:, 0].reshape(B * hkv, hd)
    local = cfg.sliding_window > 0 and not layer_global

    if cfg.attention == "h1d" and not local:
        impl = cfg.decode_impl
        if page_tables is not None:
            tt = jnp.repeat(t, hkv, axis=0)
            cache = h1d_decode.update_cache_paged(
                cache, k1, v1, tt, page_tables.update, impl=impl)
            z = h1d_decode.decode_attend_paged(
                cache, q1, tt, page_tables.attend, nr=cfg.nr, impl=impl)
        elif B == 1:
            # uniform-position fast path: scalar t keeps the jnp cache
            # reads as dynamic-slices on the sharded sequence dim (P21);
            # the kernel path specializes the same fused kernel to a
            # broadcast scalar t, and inside an sp_scope(mesh) a
            # sequence-sharded cache stays fused too (shard_map'd
            # sharded index maps, parallel/sp_attention -- P26).
            cache = h1d_decode.update_cache_uniform(cache, k1, v1, t[0],
                                                    impl=impl)
            z = h1d_decode.decode_attend_uniform(cache, q1, t[0], nr=cfg.nr,
                                                 impl=impl)
        else:
            tt = jnp.repeat(t, hkv, axis=0)
            cache = h1d_decode.update_cache(cache, k1, v1, tt, impl=impl)
            z = h1d_decode.decode_attend(cache, q1, tt, nr=cfg.nr, impl=impl)
        z = z.reshape(B, hkv, G, hd).reshape(B, 1, hq * hd)
    else:
        Lc = cache["k"].shape[1]
        slot = (t % Lc).astype(jnp.int32)
        kc = jax.vmap(lambda c, kn, s: jax.lax.dynamic_update_slice(
            c, kn[None], (s, 0, 0)))(cache["k"], k[:, 0], slot)
        vc = jax.vmap(lambda c, vn, s: jax.lax.dynamic_update_slice(
            c, vn[None], (s, 0, 0)))(cache["v"], v[:, 0], slot)
        pos = jax.vmap(lambda c, tt_, s: jax.lax.dynamic_update_slice(
            c, tt_[None], (s,)))(cache["pos"], t, slot)
        cache = {"k": kc, "v": vc, "pos": pos}
        dist = t[:, None] - pos                      # (B, Lc)
        valid = (pos >= 0) & (dist >= 0)
        if local:
            valid = valid & (dist < cfg.sliding_window)
        s = jnp.einsum("bhgd,blhd->bhgl",
                       q1.reshape(B, hkv, G, hd).astype(jnp.float32),
                       kc.astype(jnp.float32),
                       preferred_element_type=jnp.float32) / math.sqrt(hd)
        s = jnp.where(valid[:, None, None, :], s, hc.NEG_INF)
        m = jnp.maximum(s.max(-1, keepdims=True), -1e30)
        a = jnp.exp(s - m)
        z = jnp.einsum("bhgl,blhd->bhgd", a, vc.astype(jnp.float32))
        z = z / jnp.maximum(a.sum(-1), 1e-9)[..., None]
        z = z.astype(x.dtype).reshape(B, 1, hq * hd)
    return dense_apply(p["wo"], z), cache


def prefill_into_cache(p, cfg: ModelConfig, x, positions, Lmax,
                       *, layer_global=True):
    """Run attention over a prefix AND build the decode cache.
    Returns (out (B, S, d), cache)."""
    B, S, _ = x.shape
    out = attn_apply(p, cfg, x, positions, causal=True,
                     layer_global=layer_global)
    q, k, v = _project_qkv(p, cfg, x, positions)
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    local = cfg.sliding_window > 0 and not layer_global
    if cfg.attention == "h1d" and not local:
        kf = k.transpose(0, 2, 1, 3).reshape(B * hkv, S, hd)
        vf = v.transpose(0, 2, 1, 3).reshape(B * hkv, S, hd)
        cache = h1d_decode.prefill_cache(kf, vf,
                                         hc.padded_length(Lmax, cfg.nr),
                                         cfg.nr)
    else:
        cache = init_decode_cache(cfg, B, Lmax, layer_global=layer_global,
                                  dtype=k.dtype)
        Lc = cache["k"].shape[1]
        take = min(S, Lc)
        ksrc = k[:, S - take:]
        vsrc = v[:, S - take:]
        psrc = jnp.broadcast_to(jnp.arange(S - take, S)[None], (B, take))
        slots = psrc[0] % Lc                          # same for all batch rows
        kc = cache["k"].at[:, slots].set(ksrc)
        vc = cache["v"].at[:, slots].set(vsrc)
        posc = cache["pos"].at[:, slots].set(psrc)
        cache = {"k": kc, "v": vc, "pos": posc}
    return out, cache
