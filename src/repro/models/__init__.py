"""Model zoo: dense GQA, MoE, encoder-decoder, VLM, SSM (mamba2), hybrid."""
from .common import ModelConfig, set_mesh_axes
from .registry import get_model, ModelFns
