"""Unified model API over the zoo: init / loss / prefill / decode_step.

Every architecture exposes the same four pure functions so the training
loop, serving engine, dry-run and benchmarks are family-agnostic.
"""
from __future__ import annotations

from typing import Callable, NamedTuple


from .common import ModelConfig
from . import transformer as T
from . import encdec as ED


class ModelFns(NamedTuple):
    init: Callable          # (key, cfg) -> (params, specs)
    loss: Callable          # (params, cfg, batch) -> (loss, metrics)
    prefill: Callable       # (params, cfg, batch, Lmax, *, true_len=None)
                            #   -> (logits, caches, pos); true_len is the
                            #   logical prompt length (scalar, or per-row
                            #   (B,) vector for batched in-bucket
                            #   admission) when tokens are right-padded
                            #   to a length bucket
    decode_step: Callable   # (params, cfg, caches, token, t) -> (logits,
                            #   caches); decoder-only stacks also accept
                            #   page_tables= (core.h1d_decode.PageTables)
                            #   to run h1d layers on the paged serve
                            #   cache pool (serve/paged_cache.py)
    init_caches: Callable   # (params, cfg, B, Lmax) -> caches


def _lm_prefill(params, cfg, batch, Lmax, *, true_len=None):
    return T.lm_prefill(params, cfg, batch["tokens"], Lmax,
                        prefix_embeds=batch.get("patch_embeds"),
                        true_len=true_len)


def _ed_prefill(params, cfg, batch, Lmax, *, true_len=None):
    # enc-dec prefill has no bucketed-prompt support: true_len is
    # accepted for signature parity but must equal the token length
    # (the engine's bucket gate excludes the encdec family; a traced
    # true_len cannot be validated here).
    return ED.encdec_prefill(params, cfg, batch["frames"], batch["tokens"],
                             Lmax)


def _ed_init_caches(params, cfg, B, Lmax):
    raise NotImplementedError(
        "enc-dec caches are built by prefill (need encoder memory)")


def get_model(cfg: ModelConfig) -> ModelFns:
    if cfg.family == "encdec":
        return ModelFns(
            init=ED.encdec_init,
            loss=ED.encdec_loss,
            prefill=_ed_prefill,
            decode_step=ED.encdec_decode_step,
            init_caches=_ed_init_caches,
        )
    return ModelFns(
        init=T.lm_init,
        loss=T.lm_loss,
        prefill=_lm_prefill,
        decode_step=T.lm_decode_step,
        init_caches=T.lm_init_decode_caches,
    )
