"""Feed-forward layers: gated MLP (SwiGLU/GeGLU) and Mixture-of-Experts.

The MoE uses a sort-based, fixed-capacity dispatch (megablocks-style but
static-shaped, per batch row so the data-parallel sharding of the token
dim survives routing):

  1. top-k routing per token (softmax gates, renormalized top-k weights);
  2. per sequence: flatten (S*k) assignments, argsort by expert id,
     rank-in-expert via bincount prefix sums (O(S*k + E) memory -- no
     (tokens, E, capacity) one-hot anywhere);
  3. scatter into an (E, C, d) buffer, batched expert einsum (experts
     sharded over the ``model`` mesh axis = expert parallelism),
     weighted scatter-add back.

Variants: shared-expert branch (qwen2-moe) and dense residual branch
(arctic) in parallel with the routed experts.  Returns the auxiliary
load-balancing loss alongside the output.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (ModelConfig, dense_init, dense_apply, activation,
                     shard_if_divisible, logical)


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    params, specs = {}, {}
    # NOTE: gate/up kept as separate dots -- a fused (d, 2*d_ff) variant
    # measured +53% memory under the dots remat policy (the fused output
    # AND its two split halves get saved) for no collective win
    # (EXPERIMENTS.md P11, refuted).
    for n, k_, din, dout, insh in (("wg", k1, d, d_ff, False),
                                   ("wu", k2, d, d_ff, False),
                                   ("wd", k3, d_ff, d, True)):
        p, s = dense_init(k_, din, dout, dtype, in_shard=insh,
                          out_shard=not insh)
        params[n], specs[n] = p, s
    return params, specs


def mlp_apply(p, x, act_name: str):
    act = activation(act_name)
    h = act(dense_apply(p["wg"], x)) * dense_apply(p["wu"], x)
    h = logical(h, ("pod", "data"), None, "model")
    return dense_apply(p["wd"], h)


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype):
    E, d, ff = cfg.moe_experts, cfg.d_model, cfg.moe_d_ff
    keys = jax.random.split(key, 6)
    e_ax = shard_if_divisible(E)
    sc = 1.0 / math.sqrt(d)
    params = {
        "router": jax.random.normal(keys[0], (d, E), jnp.float32) * sc,
        "w1": jax.random.normal(keys[1], (E, d, ff), dtype) * sc,
        "w3": jax.random.normal(keys[2], (E, d, ff), dtype) * sc,
        "w2": jax.random.normal(keys[3], (E, ff, d), dtype)
              * (1.0 / math.sqrt(ff)),
    }
    specs = {
        "router": P(None, None),
        "w1": P(e_ax, None, None),
        "w3": P(e_ax, None, None),
        "w2": P(e_ax, None, None),
    }
    if cfg.moe_shared_d_ff:
        p, s = mlp_init(keys[4], d, cfg.moe_shared_d_ff, dtype)
        params["shared"], specs["shared"] = p, s
        params["shared_gate"] = jnp.zeros((d, 1), dtype)
        specs["shared_gate"] = P(None, None)
    if cfg.moe_dense_residual:
        p, s = mlp_init(keys[5], d, cfg.d_ff, dtype)
        params["residual"], specs["residual"] = p, s
    return params, specs


def _dispatch_one(x, ids, wts, E: int, C: int):
    """Per-sequence dispatch.  x: (S, d); ids/wts: (S, k).
    Returns (buffer (E*C, d), slot (S*k,), tok (S*k,), keepw (S*k,))."""
    S, k = ids.shape
    e_flat = ids.reshape(-1)
    tok = jnp.repeat(jnp.arange(S), k)
    w_flat = wts.reshape(-1)
    order = jnp.argsort(e_flat)
    es, ts, ws = e_flat[order], tok[order], w_flat[order]
    counts = jnp.bincount(es, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(S * k) - starts[es]
    keep = rank < C
    slot = es * C + jnp.minimum(rank, C - 1)
    buf = jnp.zeros((E * C, x.shape[-1]), x.dtype)
    buf = buf.at[slot].add(x[ts] * keep[:, None].astype(x.dtype))
    return buf, slot, ts, ws * keep


def _route(p, cfg: ModelConfig, x):
    """Shared routing: returns (top_w, top_i, rank, aux, C)."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    C = max(1, int(math.ceil(S * k / E * cfg.moe_capacity_factor)))
    gates = jax.nn.softmax(
        (x.astype(jnp.float32) @ p["router"]), axis=-1)      # (B, S, E)
    top_w, top_i = jax.lax.top_k(gates, k)                   # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    pe = gates.mean(axis=(0, 1))                             # (E,)
    fe = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (B * S * k))
    aux = cfg.moe_aux_loss * E * jnp.sum(fe * pe)

    def rank_one(ids):
        """ids: (S, k) -> capacity rank of each assignment (S, k)."""
        e_flat = ids.reshape(-1)
        order = jnp.argsort(e_flat)
        es = e_flat[order]
        counts = jnp.bincount(es, length=E)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(S * k) - starts[es]
        rank = jnp.zeros((S * k,), jnp.int32).at[order].set(rank_sorted)
        return rank.reshape(S, k)

    rank = jax.vmap(rank_one)(top_i)
    return top_w, top_i, rank, aux, C


def moe_apply(p, cfg: ModelConfig, x, act_name: str = "swiglu"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d).  Returns (out, aux_loss).

    GShard-style one-hot einsum dispatch/combine: the (B,S,E,C) dispatch
    tensor is built as an outer product of one-hots (no scatter over the
    expert dim) and every einsum contracts with E sharded over "model"
    (EP) -- GSPMD never replicates the (B, E*C, d) buffer, unlike the
    sort/scatter variant kept below as the test oracle (EXPERIMENTS.md
    P18: 12x collective-byte difference on arctic).
    """
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    act = activation(act_name)
    top_w, top_i, rank, aux, C = _route(p, cfg, x)

    cdt = x.dtype
    oh_e = jax.nn.one_hot(top_i, E, dtype=cdt)               # (B,S,k,E)
    oh_c = jax.nn.one_hot(rank, C, dtype=cdt)                # 0-row if dropped
    dispatch = jnp.einsum("bske,bskc->bsec", oh_e, oh_c)
    dispatch = logical(dispatch, ("pod", "data"), None, "model", None)
    combine = jnp.einsum("bsec,bsk,bske->bsec", dispatch,
                         top_w.astype(cdt), oh_e)
    combine = logical(combine, ("pod", "data"), None, "model", None)

    buf = jnp.einsum("bsec,bsd->becd", dispatch, x)
    buf = logical(buf, ("pod", "data"), "model", None, None)
    h = act(jnp.einsum("becd,edf->becf", buf, p["w1"].astype(buf.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(buf.dtype))
    y = jnp.einsum("becf,efd->becd", h, p["w2"].astype(buf.dtype))
    y = logical(y, ("pod", "data"), "model", None, None)
    out = jnp.einsum("becd,bsec->bsd", y, combine)

    if "shared" in p:
        g = jax.nn.sigmoid(x @ p["shared_gate"].astype(x.dtype))
        out = out + g * mlp_apply(p["shared"], x, act_name)
    if "residual" in p:
        out = out + mlp_apply(p["residual"], x, act_name)
    return out.astype(x.dtype), aux


def _moe_apply_scatter(p, cfg: ModelConfig, x, act_name: str = "swiglu"
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort/scatter dispatch (memory-lean single-device; GSPMD-hostile --
    see moe_apply).  Kept as the independent oracle for tests."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    act = activation(act_name)
    top_w, top_i, rank, aux, C = _route(p, cfg, x)

    buf, slot, ts, ws = jax.vmap(
        lambda xx, ii, ww: _dispatch_one(xx, ii, ww, E, C))(x, top_i, top_w)
    buf = buf.reshape(B, E, C, d)

    h = act(jnp.einsum("becd,edf->becf", buf, p["w1"].astype(buf.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(buf.dtype))
    y = jnp.einsum("becf,efd->becd", h, p["w2"].astype(buf.dtype))
    y = y.reshape(B, E * C, d)

    def _combine(yb, slot_b, ts_b, ws_b):
        out = jnp.zeros((S, d), yb.dtype)
        return out.at[ts_b].add(yb[slot_b] * ws_b[:, None].astype(yb.dtype))

    out = jax.vmap(_combine)(y, slot, ts, ws)

    if "shared" in p:
        g = jax.nn.sigmoid(x @ p["shared_gate"].astype(x.dtype))
        out = out + g * mlp_apply(p["shared"], x, act_name)
    if "residual" in p:
        out = out + mlp_apply(p["residual"], x, act_name)
    return out.astype(x.dtype), aux
