"""Encoder classifier for LRA-style tasks (paper section 8.1).

Bidirectional encoder (H1D / full / local attention per config) + mean
pooling + linear head -- the configuration the paper uses on the Long
Range Arena benchmark.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import (
    ModelConfig, dense_init, dense_apply, embed_init, rmsnorm_init,
    rmsnorm_apply)
from .attention import attn_init, attn_apply
from .ffn import mlp_init, mlp_apply


def classifier_init(key, cfg: ModelConfig, num_classes: int):
    dtype = cfg.jdtype
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    p, s = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    params["embed"], specs["embed"] = p, s
    layers, lspecs = [], []
    for i in range(cfg.num_layers):
        k1, k2 = jax.random.split(keys[i + 1])
        lp, ls = {}, {}
        lp["ln1"], ls["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        lp["attn"], ls["attn"] = attn_init(k1, cfg, dtype)
        lp["ln2"], ls["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        lp["mlp"], ls["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
        layers.append(lp)
        lspecs.append(ls)
    params["layers"], specs["layers"] = layers, lspecs
    p, s = rmsnorm_init(cfg.d_model, dtype)
    params["final_norm"], specs["final_norm"] = p, s
    p, s = dense_init(keys[-1], cfg.d_model, num_classes, dtype,
                      out_shard=False)
    params["head"], specs["head"] = p, s
    return params, specs


def classifier_logits(params, cfg: ModelConfig, tokens, mask=None):
    B, S = tokens.shape
    h = params["embed"]["w"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kv_weight = mask if mask is not None else None
    for lp in params["layers"]:
        a = attn_apply(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], h),
                       positions, causal=False, kv_weight=kv_weight)
        h = h + a
        h = h + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], h),
                          cfg.mlp_activation)
    h = rmsnorm_apply(params["final_norm"], h)
    if mask is not None:
        w = mask[..., None].astype(h.dtype)
        pooled = (h * w).sum(1) / jnp.maximum(w.sum(1), 1.0)
    else:
        pooled = h.mean(1)
    return dense_apply(params["head"], pooled).astype(jnp.float32)


def classifier_loss(params, cfg: ModelConfig, batch):
    logits = classifier_logits(params, cfg, batch["tokens"],
                               batch.get("mask"))
    labels = batch["label"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = (logz - gold).mean()
    acc = (jnp.argmax(logits, -1) == labels).mean()
    return loss, {"acc": acc}
