"""Decoder-only transformer LM covering the dense / MoE / VLM / local-global
(gemma3) / hybrid (zamba2) families, with train, prefill and decode paths.

Layer stacks are ``lax.scan``-ned over stacked params whenever all layers
are structurally identical (dense, moe, ssm uniform stacks) -- this keeps
compile time and HLO size flat in depth for the big assigned archs
(llava-next 60L, arctic 35L, mamba2 48L).  Heterogeneous cadences
(gemma3 local:global, zamba2 shared-attention) use a python loop.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (ModelConfig, dense_init, dense_apply, embed_init,
                     rmsnorm_init, rmsnorm_apply, logical,
                     grad_dtype_boundary)


def _remat(cfg, fn):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
from .attention import (attn_init, attn_apply, attn_decode, init_decode_cache,
                        prefill_into_cache)
from .ffn import mlp_init, mlp_apply, moe_init, moe_apply
from .ssm import mamba2_init, mamba2_apply, mamba2_decode, mamba2_dims


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def block_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.family in ("ssm",):
        return "ssm"
    if cfg.family == "hybrid":
        return "ssm"          # attention is the *shared* block, applied extra
    if cfg.moe_experts > 0:
        return "moe"
    return "dense"


def block_init(key, cfg: ModelConfig, kind: str):
    dtype = cfg.jdtype
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if kind == "ssm":
        k1, = jax.random.split(key, 1)
        p, s = rmsnorm_init(cfg.d_model, dtype)
        params["ln"], specs["ln"] = p, s
        p, s = mamba2_init(k1, cfg, dtype)
        params["mixer"], specs["mixer"] = p, s
        return params, specs
    k1, k2 = jax.random.split(key)
    p, s = rmsnorm_init(cfg.d_model, dtype)
    params["ln1"], specs["ln1"] = p, s
    p, s = attn_init(k1, cfg, dtype)
    params["attn"], specs["attn"] = p, s
    p, s = rmsnorm_init(cfg.d_model, dtype)
    params["ln2"], specs["ln2"] = p, s
    if kind == "moe":
        p, s = moe_init(k2, cfg, dtype)
        params["moe"], specs["moe"] = p, s
    else:
        p, s = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
        params["mlp"], specs["mlp"] = p, s
    return params, specs


def block_apply(p, cfg: ModelConfig, kind: str, h, positions, *,
                layer_global=True, kv_weight=None, causal=True):
    """Returns (h, aux_loss)."""
    # Re-anchor the residual sharding every layer: GSPMD propagation is
    # weak across while-loop (scan) bodies without explicit constraints.
    # With seq_parallel_residual the sequence axis shards over "model"
    # (Megatron-style SP): the per-layer saved residual stack shrinks by
    # the TP degree, paying per-layer gathers at attention/MLP entry.
    h = logical(h, ("pod", "data"),
                "model" if cfg.seq_parallel_residual else None, None)
    if kind == "ssm":
        return h + mamba2_apply(p["mixer"], cfg, rmsnorm_apply(p["ln"], h)), 0.0
    a = attn_apply(p["attn"], cfg, rmsnorm_apply(p["ln1"], h), positions,
                   causal=causal, kv_weight=kv_weight,
                   layer_global=layer_global)
    h = h + a
    if kind == "moe":
        m, aux = moe_apply(p["moe"], cfg, rmsnorm_apply(p["ln2"], h),
                           cfg.mlp_activation)
    else:
        m = mlp_apply(p["mlp"], rmsnorm_apply(p["ln2"], h),
                      cfg.mlp_activation)
        aux = 0.0
    return h + m, aux


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def _uses_scan(cfg: ModelConfig) -> bool:
    """Layer params are stacked and the train forward scans over them.
    True for every uniform-structure stack (incl. gemma3's local:global
    cadence, handled with a lax.cond inside the scan body)."""
    return (cfg.family in ("dense", "moe", "vlm", "ssm")
            and not cfg.force_loop)


def _stacked_caches(cfg: ModelConfig) -> bool:
    """Decode caches are a stacked pytree (scan over layers at decode).
    Requires structurally identical caches per layer -- false for the
    local:global cadence (ring caches vs hierarchical caches)."""
    return _uses_scan(cfg) and cfg.global_every <= 0


def lm_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    p, s = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    params["embed"], specs["embed"] = p, s
    p, s = rmsnorm_init(cfg.d_model, dtype)
    params["final_norm"], specs["final_norm"] = p, s
    if not cfg.tie_embeddings:
        p, s = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype,
                          scale=0.02)
        params["lm_head"], specs["lm_head"] = p, s

    kinds = [block_kind(cfg, i) for i in range(cfg.num_layers)]
    lkeys = jax.random.split(keys[2], cfg.num_layers)
    if _uses_scan(cfg):
        kind = kinds[0]
        _, spec1 = block_init(lkeys[0], cfg, kind)
        stacked = jax.vmap(lambda k: block_init(k, cfg, kind)[0])(lkeys)
        params["layers"] = stacked
        specs["layers"] = jax.tree.map(
            lambda sp: P(None, *sp), spec1,
            is_leaf=lambda x: isinstance(x, P))
    else:
        ps, ss = [], []
        for i in range(cfg.num_layers):
            p, s = block_init(lkeys[i], cfg, kinds[i])
            ps.append(p)
            ss.append(s)
        params["layers"] = ps
        specs["layers"] = ss

    if cfg.family == "hybrid":
        # zamba2: one shared attention+MLP block, re-invoked on a cadence,
        # each invocation with its own (h, embed0)->d input projection.
        p, s = block_init(keys[3], cfg, "dense")
        params["shared"], specs["shared"] = p, s
        n_inv = sum(1 for i in range(cfg.num_layers) if cfg.layer_is_attn(i))
        pkeys = jax.random.split(keys[4], max(n_inv, 1))
        projs, pspecs = [], []
        for i in range(n_inv):
            p, s = dense_init(pkeys[i], 2 * cfg.d_model, cfg.d_model, dtype)
            projs.append(p)
            pspecs.append(s)
        params["shared_proj"], specs["shared_proj"] = projs, pspecs
    return params, specs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens):
    e = params["embed"]["w"]
    h = e[tokens]                                      # gather (B, S, d)
    return h.astype(cfg.jdtype)


def _logits(params, cfg, h):
    h = grad_dtype_boundary(h)   # backward ARs in bf16, loss in f32
    h = rmsnorm_apply(params["final_norm"], h)
    if cfg.tie_embeddings:
        w = params["embed"]["w"].astype(h.dtype)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
    else:
        logits = dense_apply(params["lm_head"], h)
    return logical(logits.astype(jnp.float32),
                   ("pod", "data"), None, "model")


def lm_forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
               kv_weight=None):
    """Returns (logits (B, St, V) over token positions only, aux_loss)."""
    B, S = tokens.shape
    h = _embed_tokens(params, cfg, tokens)
    pfx = 0
    if prefix_embeds is not None:
        pfx = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    L = h.shape[1]
    h = logical(h, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    aux_total = 0.0
    if _uses_scan(cfg):
        kind = block_kind(cfg, 0)
        if cfg.global_every > 0:
            # local:global cadence (gemma3): one scan body with a
            # lax.cond on a per-layer flag -- compile cost stays flat in
            # depth instead of unrolling 34 layers.
            flags = jnp.array([cfg.layer_uses_global_attn(i)
                               for i in range(cfg.num_layers)])

            def body(carry, xs):
                hh, aux = carry
                lp, flag = xs

                def branch(glob):
                    def f(h_):
                        h2, a = block_apply(lp, cfg, kind, h_, positions,
                                            kv_weight=kv_weight,
                                            layer_global=glob)
                        return h2, jnp.asarray(a, jnp.float32)
                    return f

                hh, a = jax.lax.cond(flag, branch(True), branch(False), hh)
                return (hh, aux + a), None

            xs = (params["layers"], flags)
        else:
            def body(carry, lp):
                hh, aux = carry
                hh, a = block_apply(lp, cfg, kind, hh, positions,
                                    kv_weight=kv_weight)
                return (hh, aux + a), None

            xs = params["layers"]

        body_fn = _remat(cfg, body) if cfg.remat else body
        (h, aux_total), _ = jax.lax.scan(body_fn, (h, 0.0), xs)
    else:
        inv = 0
        e0 = h
        for i, lp in enumerate(params["layers"]):
            kind = block_kind(cfg, i)

            def body(hh, lp=lp, kind=kind, i=i):
                return block_apply(lp, cfg, kind, hh, positions,
                                   kv_weight=kv_weight,
                                   layer_global=cfg.layer_uses_global_attn(i))

            if cfg.remat:
                h2, aux = _remat(cfg, body)(h)
            else:
                h2, aux = body(h)
            h = h2
            aux_total = aux_total + aux
            if cfg.family == "hybrid" and cfg.layer_is_attn(i):
                xin = dense_apply(params["shared_proj"][inv],
                                  jnp.concatenate([h, e0], axis=-1))
                h2, _ = block_apply(params["shared"], cfg, "dense", xin,
                                    positions, kv_weight=kv_weight)
                h = h + (h2 - xin)   # residual of the shared block only
                inv += 1
    logits = _logits(params, cfg, h)
    if pfx:
        logits = logits[:, pfx:]
    return logits, aux_total


def lm_loss(params, cfg: ModelConfig, batch):
    """batch: tokens (B, S) [+ patch_embeds / frames, loss_mask].
    Next-token CE; returns (loss, metrics)."""
    tokens = batch["tokens"]
    logits, aux = lm_forward(params, cfg, tokens,
                             prefix_embeds=batch.get("patch_embeds"))
    tgt = tokens[:, 1:]
    lgt = logits[:, :-1]
    mask = batch.get("loss_mask")
    mask = (jnp.ones_like(tgt, jnp.float32) if mask is None
            else mask[:, 1:].astype(jnp.float32))
    logz = jax.nn.logsumexp(lgt, axis=-1)
    # gold logit via one-hot contraction: shards cleanly over the
    # model-parallel vocab axis (take_along_axis would gather the full
    # unsharded logits)
    onehot = jax.nn.one_hot(tgt, lgt.shape[-1], dtype=lgt.dtype)
    onehot = logical(onehot, ("pod", "data"), None, "model")
    gold = jnp.einsum("bsv,bsv->bs", lgt, onehot)
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + aux
    return loss, {"nll": nll.sum() / denom, "aux": aux,
                  "ntok": mask.sum()}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def _block_prefill(p, cfg, kind, h, positions, Lmax, *, layer_global=True):
    if kind == "ssm":
        out, st = mamba2_apply(p["mixer"], cfg, rmsnorm_apply(p["ln"], h),
                               return_state=True)
        return h + out, st
    a, cache = prefill_into_cache(p["attn"], cfg, rmsnorm_apply(p["ln1"], h),
                                  positions, Lmax, layer_global=layer_global)
    h = h + a
    if kind == "moe":
        m, _ = moe_apply(p["moe"], cfg, rmsnorm_apply(p["ln2"], h),
                         cfg.mlp_activation)
    else:
        m = mlp_apply(p["mlp"], rmsnorm_apply(p["ln2"], h),
                      cfg.mlp_activation)
    return h + m, cache


def _block_decode(p, cfg, kind, h, t, cache, *, layer_global=True,
                  page_tables=None):
    if kind == "ssm":
        out, st = mamba2_decode(p["mixer"], cfg, rmsnorm_apply(p["ln"], h),
                                cache)
        return h + out, st
    a, cache = attn_decode(p["attn"], cfg, rmsnorm_apply(p["ln1"], h), t,
                           cache, layer_global=layer_global,
                           page_tables=page_tables)
    h = h + a
    if kind == "moe":
        m, _ = moe_apply(p["moe"], cfg, rmsnorm_apply(p["ln2"], h),
                         cfg.mlp_activation)
    else:
        m = mlp_apply(p["mlp"], rmsnorm_apply(p["ln2"], h),
                      cfg.mlp_activation)
    return h + m, cache


def lm_prefill(params, cfg: ModelConfig, tokens, Lmax: int, *,
               prefix_embeds=None, true_len=None):
    """Teacher-forced pass over the prompt building decode caches.
    Returns (last_logits (B, V), caches, next_pos (B,)).

    ``true_len`` (scalar or per-row (B,) vector, may be traced): logical
    prompt length(s) when ``tokens`` is right-padded to a length bucket
    (ServeEngine pads to powers of two so jit compiles O(log Lmax)
    prefill shapes instead of one per distinct prompt length; batched
    in-bucket admission prefills several requests of DIFFERENT true
    lengths in one call, hence the vector form).  The returned
    logits/next_pos then refer to position ``true_len - 1`` per row; the
    padded tail positions are never attended by decode (causal attention
    + position-gated caches), and each is overwritten by ``decode_step``
    before its turn comes up.
    """
    B, S = tokens.shape
    h = _embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    L = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    if _stacked_caches(cfg):
        kind = block_kind(cfg, 0)

        def body(hh, lp):
            hh, cache = _block_prefill(lp, cfg, kind, hh, positions, Lmax)
            return hh, cache

        h, caches = jax.lax.scan(body, h, params["layers"])
    else:
        caches = []
        inv = 0
        e0 = h
        stacked = _uses_scan(cfg)
        for i in range(cfg.num_layers):
            lp = (jax.tree.map(lambda x, i=i: x[i], params["layers"])
                  if stacked else params["layers"][i])
            kind = block_kind(cfg, i)
            h, cache = _block_prefill(lp, cfg, kind, h, positions, Lmax,
                                      layer_global=cfg.layer_uses_global_attn(i))
            caches.append(cache)
            if cfg.family == "hybrid" and cfg.layer_is_attn(i):
                xin = dense_apply(params["shared_proj"][inv],
                                  jnp.concatenate([h, e0], axis=-1))
                h2, shared_cache = _block_prefill(
                    params["shared"], cfg, "dense", xin, positions, Lmax)
                h = h + (h2 - xin)
                caches.append(shared_cache)
                inv += 1
        caches = list(caches)
    if true_len is None:
        last = h[:, -1:]
        next_pos = jnp.full((B,), L, jnp.int32)
    else:
        if prefix_embeds is not None:
            true_len = true_len + prefix_embeds.shape[1]
        tl = jnp.asarray(true_len, jnp.int32)
        if tl.ndim == 0:
            last = jax.lax.dynamic_slice_in_dim(h, true_len - 1, 1, axis=1)
            next_pos = jnp.full((B,), true_len, jnp.int32)
        else:
            # per-row logical lengths (batched in-bucket admission):
            # gather each row's last true token
            last = jnp.take_along_axis(h, (tl - 1)[:, None, None], axis=1)
            next_pos = tl
    logits = _logits(params, cfg, last)[:, 0]
    return logits, caches, next_pos


def lm_decode_step(params, cfg: ModelConfig, caches, token, t, *,
                   page_tables=None):
    """One decode step.  token: (B,) int32; t: (B,) positions.
    Returns (logits (B, V), new_caches).

    ``page_tables`` (``core.h1d_decode.PageTables``) switches the h1d
    attention layers onto the paged cache pool (``caches`` leaves are
    then ``PagedH1DCache`` pools); every layer writes the same
    positions, so ONE table pair serves the whole stack and rides
    through the layer scan as a closure, not a scanned operand."""
    h = _embed_tokens(params, cfg, token[:, None])

    if _stacked_caches(cfg):
        kind = block_kind(cfg, 0)

        def body(hh, xs):
            lp, cache = xs
            hh, cache = _block_decode(lp, cfg, kind, hh, t, cache,
                                      page_tables=page_tables)
            return hh, cache

        h, caches = jax.lax.scan(body, h, (params["layers"], caches))
    else:
        new_caches = []
        ci = 0
        inv = 0
        e0 = h
        stacked = _uses_scan(cfg)
        for i in range(cfg.num_layers):
            lp = (jax.tree.map(lambda x, i=i: x[i], params["layers"])
                  if stacked else params["layers"][i])
            kind = block_kind(cfg, i)
            h, cache = _block_decode(lp, cfg, kind, h, t, caches[ci],
                                     layer_global=cfg.layer_uses_global_attn(i),
                                     page_tables=page_tables)
            new_caches.append(cache)
            ci += 1
            if cfg.family == "hybrid" and cfg.layer_is_attn(i):
                xin = dense_apply(params["shared_proj"][inv],
                                  jnp.concatenate([h, e0], axis=-1))
                h2, cache = _block_decode(params["shared"], cfg, "dense",
                                          xin, t, caches[ci])
                h = h + (h2 - xin)
                new_caches.append(cache)
                ci += 1
                inv += 1
        caches = new_caches
    logits = _logits(params, cfg, h)[:, 0]
    return logits, caches


def lm_init_decode_caches(params, cfg: ModelConfig, B: int, Lmax: int):
    """Fresh (empty) decode caches matching lm_decode_step's structure."""
    caches = []
    d_inner, H, G, N, conv_dim = (mamba2_dims(cfg) if cfg.family in
                                  ("ssm", "hybrid") else (0,) * 5)
    for i in range(cfg.num_layers):
        kind = block_kind(cfg, i)
        if kind == "ssm":
            caches.append((
                jnp.zeros((B, H, N, cfg.ssm_head_dim), jnp.float32),
                jnp.zeros((B, cfg.ssm_conv_width - 1, conv_dim), cfg.jdtype),
            ))
        else:
            caches.append(init_decode_cache(
                cfg, B, Lmax, layer_global=cfg.layer_uses_global_attn(i),
                dtype=cfg.jdtype))
        if cfg.family == "hybrid" and cfg.layer_is_attn(i):
            caches.append(init_decode_cache(cfg, B, Lmax, dtype=cfg.jdtype))
    if _stacked_caches(cfg):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    return caches
