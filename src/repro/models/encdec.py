"""Encoder-decoder transformer (seamless-m4t backbone).

Audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, Se, d).  Encoder self-attention is
*bidirectional* H1D (the paper's encoder use-case); decoder
self-attention is causal H1D; cross-attention stays dense -- the paper
explicitly defers a cross-attention inductive bias to future work
(section 9), and with a short decoder the cost is O(Sd * Se) = linear in
the long (audio) axis.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import (
    ModelConfig, dense_init, dense_apply, embed_init, rmsnorm_init,
    rmsnorm_apply, logical)
from .attention import attn_init, attn_apply, attn_decode, prefill_into_cache
from .ffn import mlp_init, mlp_apply
from repro.core import dense_attention


def _xattn_init(key, cfg: ModelConfig, dtype):
    return attn_init(key, cfg, dtype)   # same projection structure


def _xattn_apply(p, cfg: ModelConfig, x, mem_k, mem_v, *, mem_weight=None):
    """Cross attention.  x: (B, Sd, d); mem_k/v: (B, Se, Hkv, hd)."""
    B, Sd, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = hq // hkv
    q = dense_apply(p["wq"], x).reshape(B, Sd, hq, hd)
    qh = q.reshape(B, Sd, hkv, G, hd).transpose(0, 2, 3, 1, 4)
    qh = qh.reshape(B * hkv, G, Sd, hd)
    kh = mem_k.transpose(0, 2, 1, 3).reshape(B * hkv, -1, hd)
    vh = mem_v.transpose(0, 2, 1, 3).reshape(B * hkv, -1, hd)
    kw = (jnp.repeat(mem_weight, hkv, axis=0)
          if mem_weight is not None else None)
    z = dense_attention(qh, kh, vh, causal=False, kv_weight=kw)
    z = z.reshape(B, hkv, G, Sd, hd).transpose(0, 3, 1, 2, 4)
    return dense_apply(p["wo"], z.reshape(B, Sd, hq * hd))


def _xattn_memory(p, cfg: ModelConfig, enc_h):
    B, Se, _ = enc_h.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    kv = dense_apply(p["wkv"], enc_h)
    k, v = jnp.split(kv, 2, axis=-1)
    return k.reshape(B, Se, hkv, hd), v.reshape(B, Se, hkv, hd)


def encdec_init(key, cfg: ModelConfig):
    dtype = cfg.jdtype
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    p, s = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    params["embed"], specs["embed"] = p, s
    p, s = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype, scale=0.02)
    params["lm_head"], specs["lm_head"] = p, s
    for n in ("enc_norm", "dec_norm"):
        p, s = rmsnorm_init(cfg.d_model, dtype)
        params[n], specs[n] = p, s

    def enc_layer(k_):
        k1, k2 = jax.random.split(k_)
        pr, sr = {}, {}
        pr["ln1"], sr["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        pr["attn"], sr["attn"] = attn_init(k1, cfg, dtype)
        pr["ln2"], sr["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        pr["mlp"], sr["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
        return pr, sr

    def dec_layer(k_):
        k1, k2, k3 = jax.random.split(k_, 3)
        pr, sr = {}, {}
        pr["ln1"], sr["ln1"] = rmsnorm_init(cfg.d_model, dtype)
        pr["attn"], sr["attn"] = attn_init(k1, cfg, dtype)
        pr["lnx"], sr["lnx"] = rmsnorm_init(cfg.d_model, dtype)
        pr["xattn"], sr["xattn"] = _xattn_init(k2, cfg, dtype)
        pr["ln2"], sr["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        pr["mlp"], sr["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)
        return pr, sr

    eks = jax.random.split(keys[2], cfg.encoder_layers)
    dks = jax.random.split(keys[3], cfg.num_layers)
    enc, enc_s = zip(*[enc_layer(k_) for k_ in eks])
    dec, dec_s = zip(*[dec_layer(k_) for k_ in dks])
    params["encoder"], specs["encoder"] = list(enc), list(enc_s)
    params["decoder"], specs["decoder"] = list(dec), list(dec_s)
    return params, specs


def encode(params, cfg: ModelConfig, frames, *, frame_weight=None):
    """frames: (B, Se, d) stubbed frontend embeddings."""
    B, Se, _ = frames.shape
    h = frames.astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    for lp in params["encoder"]:
        def body(hh, lp=lp):
            hh = logical(hh, ("pod", "data"), "model", None)
            a = attn_apply(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], hh),
                           positions, causal=False, kv_weight=frame_weight)
            hh = hh + a
            return hh + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], hh),
                                  cfg.mlp_activation)
        h = jax.checkpoint(body)(h) if cfg.remat else body(h)
    return rmsnorm_apply(params["enc_norm"], h)


def decode_train(params, cfg: ModelConfig, tokens, enc_h, *,
                 enc_weight=None):
    """Teacher-forced decoder.  Returns logits (B, Sd, V)."""
    B, Sd = tokens.shape
    h = params["embed"]["w"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    for lp in params["decoder"]:
        def body(hh, lp=lp):
            hh = logical(hh, ("pod", "data"), "model", None)
            a = attn_apply(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], hh),
                           positions, causal=True)
            hh = hh + a
            mk, mv = _xattn_memory(lp["xattn"], cfg, enc_h)
            hh = hh + _xattn_apply(lp["xattn"], cfg,
                                   rmsnorm_apply(lp["lnx"], hh), mk, mv,
                                   mem_weight=enc_weight)
            return hh + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], hh),
                                  cfg.mlp_activation)
        h = jax.checkpoint(body)(h) if cfg.remat else body(h)
    h = rmsnorm_apply(params["dec_norm"], h)
    logits = dense_apply(params["lm_head"], h).astype(jnp.float32)
    return logical(logits, ("pod", "data"), None, "model")


def encdec_loss(params, cfg: ModelConfig, batch):
    enc_h = encode(params, cfg, batch["frames"],
                   frame_weight=batch.get("frame_weight"))
    logits = decode_train(params, cfg, batch["tokens"], enc_h,
                          enc_weight=batch.get("frame_weight"))
    tgt = batch["tokens"][:, 1:]
    lgt = logits[:, :-1]
    logz = jax.nn.logsumexp(lgt, axis=-1)
    onehot = jax.nn.one_hot(tgt, lgt.shape[-1], dtype=lgt.dtype)
    onehot = logical(onehot, ("pod", "data"), None, "model")
    gold = jnp.einsum("bsv,bsv->bs", lgt, onehot)
    nll = (logz - gold).mean()
    return nll, {"nll": nll}


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, Lmax):
    """Encode + decoder prefill.  Returns (logits, caches, next_pos)."""
    enc_h = encode(params, cfg, frames)
    B, Sd = tokens.shape
    h = params["embed"]["w"][tokens].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    caches = []
    for lp in params["decoder"]:
        a, cache = prefill_into_cache(lp["attn"], cfg,
                                      rmsnorm_apply(lp["ln1"], h),
                                      positions, Lmax)
        h = h + a
        mk, mv = _xattn_memory(lp["xattn"], cfg, enc_h)
        h = h + _xattn_apply(lp["xattn"], cfg, rmsnorm_apply(lp["lnx"], h),
                             mk, mv)
        h = h + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], h),
                          cfg.mlp_activation)
        caches.append({"self": cache, "mem_k": mk, "mem_v": mv})
    h = rmsnorm_apply(params["dec_norm"], h[:, -1:])
    logits = dense_apply(params["lm_head"], h)[:, 0].astype(jnp.float32)
    return logits, caches, jnp.full((B,), Sd, jnp.int32)


def encdec_decode_step(params, cfg: ModelConfig, caches, token, t):
    h = params["embed"]["w"][token[:, None]].astype(cfg.jdtype)
    new_caches = []
    for lp, cache in zip(params["decoder"], caches):
        a, self_cache = attn_decode(lp["attn"], cfg,
                                    rmsnorm_apply(lp["ln1"], h), t,
                                    cache["self"])
        h = h + a
        h = h + _xattn_apply(lp["xattn"], cfg, rmsnorm_apply(lp["lnx"], h),
                             cache["mem_k"], cache["mem_v"])
        h = h + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], h),
                          cfg.mlp_activation)
        new_caches.append({"self": self_cache, "mem_k": cache["mem_k"],
                           "mem_v": cache["mem_v"]})
    h = rmsnorm_apply(params["dec_norm"], h)
    logits = dense_apply(params["lm_head"], h)[:, 0].astype(jnp.float32)
    return logits, new_caches
